"""``resilient/`` bench family: what checkpointed legs cost over ``run``.

A campaign (``run_resumable``) executes the identical sweep schedule as
the plain ``run`` call — the overhead is per-leg: one fused health
probe + host sync, one ``jax.device_get`` snapshot, and the async store
write it overlaps with the next leg.  Rows time a full campaign against
the uninterrupted ``run`` on the same program:

    resilient/<spec>-T<T>-every<k>  us_per_call
        derived: plain_us|overhead|legs=<n>|ckpts=<n>

The tracked quantity is the *ratio* trend across PRs, not its absolute
value: interpret-mode legs finish in microseconds, so the disk write
dominates and the ratio is wildly pessimistic vs a real accelerator run
(where a leg is seconds of compute against the same few-ms save).
Raising ``every`` amortizes the per-leg cost — visible even here.
CSV-only — this family is not persisted or gated.
"""
from __future__ import annotations

import shutil
import tempfile

CASES = (
    # name, shape, t, T, every
    ("j2d5pt", (128, 256), 4, 32, 1),
    ("j2d5pt", (128, 256), 4, 32, 2),
    ("j3d7pt", (24, 32, 16), 2, 16, 2),
)


def rows():
    from benchmarks.common import time_fn
    from repro.api.program import compile_stencil
    from repro.core.stencil_spec import get
    from repro.resilient import CampaignStore
    from repro.stencils.data import init_domain

    out = []
    for name, shape, t, total, every in CASES:
        spec = get(name)
        prog = compile_stencil(spec, shape, t=t, interpret=True)
        x = init_domain(spec, shape)
        plain_us = time_fn(lambda: prog.run(x, total).block_until_ready())
        root = tempfile.mkdtemp(prefix="bench_resilient_")

        def campaign():
            shutil.rmtree(root, ignore_errors=True)
            rep = prog.run_resumable(x, total, store=CampaignStore(root),
                                     every=every, resume="never")
            rep.result.block_until_ready()
            return rep

        rep = campaign()                      # warm caches + count legs
        camp_us = time_fn(campaign)
        shutil.rmtree(root, ignore_errors=True)
        overhead = camp_us / plain_us - 1.0 if plain_us else 0.0
        out.append((
            f"resilient/{name}-T{total}-every{every}",
            camp_us,
            f"plain_us={plain_us:.1f}|overhead={overhead:+.1%}|"
            f"legs={rep.legs_total}|ckpts={rep.checkpoints_written}"))
    return out


if __name__ == "__main__":
    from benchmarks.common import emit
    print("name,us_per_call,derived")
    emit(rows())
