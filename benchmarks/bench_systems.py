"""``systems/`` family: fused multi-field trapezoid chain vs lockstep.

For each shipped system the fused :class:`~repro.systems.SystemProgram`
chain (one jitted dispatch for all fields and all ``T`` steps) is timed
INTERLEAVED against ``run_lockstep`` (one separately jitted dispatch per
field per step — ``T·n_fields`` dispatches, the classic sync-everywhere
scheme).  ``time_pair`` keeps the ratio trustworthy on a noisy shared
CPU: a neighbor-load burst degrades both sides alike.

Acceptance tracking (ISSUE 9): ``speedup >= 1.0`` at ``t >= 4`` on at
least one system means fusing the coupling beat per-field-per-step
dispatch; both trajectories are the same numbers (asserted in
``tests/test_systems.py``), so the row is purely a scheduling
comparison.  Rows persist to ``BENCH_systems.json``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_pair
from repro.api import Boundary
from repro.systems import compile_system, get_system

# (system, shape, fused depth, total steps) — t >= 4 per the acceptance
# criterion; shapes sized so a row stays ~sub-second on a shared CPU
CASES = (("gray-scott", (96, 96), 4, 16),
         ("fdtd-acoustic", (96, 96), 4, 16),
         ("advection-diffusion", (96, 96), 6, 24))


def _fields(spec, shape):
    rng = np.random.default_rng(7)
    return {f: jnp.asarray(rng.uniform(0.2, 0.8, shape).astype(np.float32))
            for f in spec.fields}


def rows():
    out = []
    for name, shape, t, total in CASES:
        spec = get_system(name)
        prog = compile_system(spec, shape, t=t,
                              boundary=Boundary.periodic())
        x = _fields(spec, shape)
        # compile both paths outside the timed region
        prog.run(x, total), prog.run_lockstep(x, total)
        us_fused, us_lock = time_pair(lambda: prog.run(x, total),
                                      lambda: prog.run_lockstep(x, total))
        out.append((
            f"systems/{name}-t{t}-T{total}", us_fused,
            f"lockstep_us={us_lock:.0f}|"
            f"speedup={us_lock / us_fused:.2f}x|"
            f"fields={spec.nfields}|radius={spec.radius}|"
            f"dispatches={1}v{total * spec.nfields}|"
            f"note=fused-chain-vs-per-field-lockstep-interleaved"))
    return out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(rows())
