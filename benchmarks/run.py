"""Benchmark harness: one module per paper table/figure + the LM roofline.

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only <prefix>]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only modules whose name contains this")
    args = ap.parse_args()

    from benchmarks import (bench_kernels, fig7_speedups, fig8_resources,
                            fig9_breakdown, lm_roofline, table2_suite,
                            table3_depths)
    from benchmarks.common import emit

    modules = [
        ("table2", table2_suite),
        ("table3", table3_depths),
        ("fig7", fig7_speedups),
        ("fig8", fig8_resources),
        ("fig9", fig9_breakdown),
        ("kernels", bench_kernels),
        ("lm_roofline", lm_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        try:
            emit(mod.rows())
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
