"""Benchmark harness: one module per paper table/figure + the LM roofline.

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only <prefix>]

Kernel rows are additionally persisted (appended) to ``BENCH_kernels.json``
and serving rows to ``BENCH_serve.json`` at the repo root so the perf
trajectory is tracked across PRs (``scripts/bench_gate.py --file ...``
compares the newest two entries of either file).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_kernels.json")
# per-family persistence: families absent here print CSV only
PERSIST_FILES = {"kernels": BENCH_JSON,
                 "serve": os.path.join(_ROOT, "BENCH_serve.json"),
                 "tuned": os.path.join(_ROOT, "BENCH_tuned.json"),
                 "systems": os.path.join(_ROOT, "BENCH_systems.json"),
                 "attention": os.path.join(_ROOT, "BENCH_attention.json")}


def _git_rev() -> str:
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=_ROOT, capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
        dirty = subprocess.run(["git", "status", "--porcelain"],
                               cwd=_ROOT, capture_output=True, text=True,
                               timeout=10).stdout.strip()
        return f"{rev}-dirty" if dirty else rev
    except Exception:  # noqa: BLE001
        return "unknown"


def persist_rows(rows, path: str = BENCH_JSON) -> None:
    """Append this run's rows to a bench-history JSON (history kept)."""
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f).get("entries", [])
        except (OSError, ValueError):
            hist = []
    entry = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "rev": _git_rev(),
        "rows": {name: {"us_per_call": round(float(us), 1),
                        "derived": derived}
                 for name, us, derived in rows},
    }
    hist.append(entry)
    with open(path, "w") as f:
        json.dump({"entries": hist}, f, indent=2)
        f.write("\n")


# back-compat alias (tier1 docs/scripts referenced the kernel name)
def persist_kernel_rows(rows) -> None:
    persist_rows(rows, BENCH_JSON)


def min_merge(passes: list[list]) -> list:
    """Per-row min across repeated measurement passes.

    The tracked estimator is best-of-N wall time (see ``common.time_fn``:
    shared-CPU contamination is one-sided).  One tight pass can sit
    entirely inside a neighbor-load burst lasting minutes; re-measuring
    the same rows in several passes spread over the run and keeping each
    row's minimum (with that pass's derived column, so ratios stay
    internally consistent) is the same estimator over a wider, harder-to-
    contaminate sample."""
    best: dict = {}
    order: list = []
    for rows in passes:
        for name, us, derived in rows:
            if name not in best:
                order.append(name)
                best[name] = (us, derived)
            elif isinstance(us, (int, float)) and us < best[name][0]:
                best[name] = (us, derived)
    return [(name, *best[name]) for name in order]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only modules whose name contains this")
    ap.add_argument("--no-persist", action="store_true",
                    help="skip appending kernel rows to BENCH_kernels.json")
    ap.add_argument("--passes", type=int, default=1,
                    help="measurement passes per module, min-merged per row "
                         "(burst-resistant best-of-N on a noisy shared CPU)")
    args = ap.parse_args()
    if args.passes < 1:
        ap.error("--passes must be >= 1 (an empty entry would vacuously "
                 "pass the bench gate)")

    from benchmarks import (bench_attention, bench_kernels,
                            bench_resilient, bench_serve, bench_sharded,
                            bench_systems, bench_tuned, fig7_speedups,
                            fig8_resources, fig9_breakdown, lm_roofline,
                            table2_suite, table3_depths)
    from benchmarks.common import emit

    modules = [
        ("table2", table2_suite),
        ("table3", table3_depths),
        ("fig7", fig7_speedups),
        ("fig8", fig8_resources),
        ("fig9", fig9_breakdown),
        ("kernels", bench_kernels),
        ("sharded", bench_sharded),
        ("serve", bench_serve),
        ("resilient", bench_resilient),
        ("tuned", bench_tuned),
        ("systems", bench_systems),
        ("attention", bench_attention),
        ("lm_roofline", lm_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        try:
            rows = min_merge([mod.rows() for _ in range(args.passes)])
            emit(rows)
            if name in PERSIST_FILES and not args.no_persist:
                persist_rows(rows, PERSIST_FILES[name])
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
