"""Paper Table 2: the stencil suite — kernel timing + modeled TPU GCells/s.

us_per_call: wall time of the EBISU kernel (interpret mode, reduced domain).
derived: ``<plan-t>|<modeled GCells/s on v5e>|<bottleneck>|a_sm=<rst>/<worst>``.
"""
from __future__ import annotations

from benchmarks.common import time_fn
from repro.api import compile_stencil
from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2
from repro.stencils.data import init_domain, reduced_domain


def rows():
    out = []
    for name, spec in TABLE2.items():
        p = plan(spec, rl.TPU_V5E)
        shape = reduced_domain(spec, 96)
        x = init_domain(spec, shape)
        t = min(p.t, 4 if spec.ndim == 3 else 6)
        # per-call compile-and-apply (plan-less legacy tiles) — the same
        # dispatch the deprecated ops.ebisu_stencil shim measures, driven
        # through repro.api directly so the output is warning-clean
        us = time_fn(lambda: compile_stencil(spec, shape, t=t, plan=None,
                                             interpret=True).apply(x),
                     warmup=1, iters=3)
        derived = (f"t={p.t}|{p.pp.pp_cells_per_s/1e9:.0f}GCells/s|"
                   f"{p.pp.bottleneck}|a_sm={spec.a_sm_rst}/{spec.a_sm}")
        out.append((f"table2/{name}", us, derived))
    return out
