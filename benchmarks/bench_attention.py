"""``attention/`` bench family: the compile-once attention programs.

Flash (online-softmax, no S×S materialization) vs the dense oracle path,
through the same :class:`AttentionProgram` front door the model uses:

    attention/chunked-<case>   the jnp online-softmax program (the impl
        the LM dry-run cells lower); derived carries ``naive_us=`` (the
        dense control of the SAME run) and ``analytic_bytes=`` (the
        kernel-model HBM traffic: q,k,v read + o written once)
    attention/pallas-<case>    the Pallas flash kernel in interpret mode
        — tracked for trend only (interpret-mode wall time has nothing
        to do with TPU wall time; the traffic column is the claim)
    attention/dense-<case>     the untouched dense oracle — the
        naive control row (nobody optimizes it, so when it moves the
        machine moved): ``scripts/bench_gate.py`` divides the other
        rows by its drift before applying the regression threshold
    attention/grad-<case>      chunked VJP via the program's ``.grad``

The load-immune claim is the ``analytic_bytes`` ratio: dense round-trips
the S×Sk score block per head on top of q/k/v/o, flash streams k/v
through VMEM and writes o once — the §4.1/§4.3 "one tile resident,
stream the rest" discipline applied to the LM half.  Rows are persisted
to ``BENCH_attention.json`` by ``benchmarks/run.py`` (min-of-N across
``--passes``, same estimator as every family).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.api import compile_attention
from repro.kernels.flash_attention import attention_hbm_bytes

# (label, b, s, heads, kv_heads, head_dim, q_chunk, kv_chunk)
CASES = [
    ("s256-gqa2", 1, 256, 4, 2, 32, 64, 128),
    ("s512-mha", 1, 512, 4, 4, 32, 128, 128),
]


def _dense_bytes(b, s, h, hd, kv, itemsize=4) -> int:
    """Dense-path HBM model: q/k/v read + o written, PLUS the (h, s, s)
    score block written and re-read once per softmax pass."""
    return (attention_hbm_bytes(b, s, s, h, kv, hd, bytes_per_el=itemsize)
            + 2 * b * h * s * s * itemsize)


def rows() -> list:
    out = []
    for label, b, s, h, kv, hd, qc, kc in CASES:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
        progs = {impl: compile_attention(
            heads=h, kv_heads=kv, head_dim=hd, q_chunk=qc, kv_chunk=kc,
            impl=impl, interpret=True) for impl in
            ("chunked", "pallas", "dense")}

        flash_bytes = attention_hbm_bytes(b, s, s, h, kv, hd,
                                          bytes_per_el=4)
        dense_bytes = _dense_bytes(b, s, h, hd, kv)
        naive_us = time_fn(progs["dense"].apply, q, k, v)
        chunked_us = time_fn(progs["chunked"].apply, q, k, v)
        pallas_us = time_fn(progs["pallas"].apply, q, k, v, iters=3)

        shared = (f"naive_us={naive_us:.1f}|"
                  f"traffic_ratio={dense_bytes / flash_bytes:.2f}")
        out.append((f"attention/chunked-{label}", chunked_us,
                    f"{shared}|analytic_bytes={flash_bytes}|"
                    f"note=online-softmax-no-SxS"))
        out.append((f"attention/pallas-{label}", pallas_us,
                    f"{shared}|analytic_bytes={flash_bytes}|"
                    f"note=interpret-mode-trend-only"))
        out.append((f"attention/dense-{label}", naive_us,
                    f"analytic_bytes={dense_bytes}|note=naive-control"))

        do = jnp.ones_like(q)
        grad_us = time_fn(progs["chunked"].grad, q, k, v, do)
        out.append((f"attention/grad-{label}", grad_us,
                    f"naive_us={naive_us:.1f}|note=chunked-vjp"))
    return out
