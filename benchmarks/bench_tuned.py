"""``tuned/`` family: the measured winner vs the analytic §6 plan.

For each case a fresh tiny-budget ``repro.tuning`` search runs into a
throwaway plan DB, then ``compile_stencil(..., mode="tuned")`` replays
the winner and is timed INTERLEAVED with the pure analytic-plan program
(``time_pair`` — a neighbor-load burst degrades both sides alike, so
the ``speedup=`` ratio is the trustworthy number).  ``naive_us=`` is
the untouched reference control ``scripts/bench_gate.py`` normalizes
with, and ``analytic_bytes=`` the lowered-HLO traffic its load-immune
gate compares.

Acceptance tracking (ISSUE 8): ``speedup >= 1.0`` means the tuned plan
met or beat the analytic plan on this Table-2 spec; interpret-mode wall
time on a shared CPU makes parity (within noise) the common outcome
when the analytic seed wins its own neighborhood — the row records the
ratio either way.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import time_fn, time_pair
from repro.api import compile_stencil
from repro.core.stencil_spec import get
from repro.kernels import ref
from repro.stencils.data import init_domain
from repro.tuning import PlanDB, analytic_bytes_per_step, tune

# one 2-D and one 3-D Table-2 spec; shapes sized for interpret mode
CASES = (("j2d5pt", (128, 128), 20),
         ("j3d7pt", (24, 16, 24), 8))

BUDGET = 24            # timing calls per search (tiny: ~2 rounds)
CANDIDATES = 8


def rows():
    out = []
    for name, shape, total in CASES:
        spec = get(name)
        x = init_domain(spec, shape)
        db = PlanDB(tempfile.mkdtemp(prefix="plandb_bench_"))
        res = tune(spec, shape, db=db, budget=BUDGET,
                   max_candidates=CANDIDATES, total_t=total)
        tuned = compile_stencil(spec, shape, mode="tuned", plan_db=db)
        analytic = compile_stencil(spec, shape, interpret=True)
        # compile both chains outside the timed region
        tuned.run(x, total), analytic.run(x, total)
        us_tuned, us_analytic = time_pair(lambda: tuned.run(x, total),
                                          lambda: analytic.run(x, total))
        us_naive = time_fn(lambda: ref.reference(x, spec, total))
        out.append((
            f"tuned/{name}-T{total}", us_tuned,
            f"analytic_plan_us={us_analytic:.0f}|"
            f"naive_us={us_naive:.0f}|"
            f"speedup={us_analytic / us_tuned:.2f}x|"
            f"winner={res.winner.label()}|"
            f"source={(tuned.tuned or {}).get('source')}|"
            f"analytic_bytes={analytic_bytes_per_step(tuned, total):.0f}|"
            f"note=measured-winner-vs-analytic-plan-interleaved"))
    return out
