"""Paper Fig. 7: EBISU speedup over baselines.

The CUDA SOTA baselines cannot run in this container, and a throughput model
cannot capture their implementation-level losses (register spills, occupancy
ceilings) — so this benchmark reproduces Fig. 7's *structure* with baselines
implemented in THIS framework, all evaluated with the same §5 model:

  naive     — no temporal blocking (t=1): one HBM round-trip per step;
  shallow   — DRSTENCIL/STENCILGEN-regime: overlapped SM tiling at their
              published Table-3 depths, no register streaming;
  ebisu     — the §6 planner's streaming schedule (deep t + RST + CMQ).

Validation anchors against the paper's own measured EBISU numbers (A100):
  j2d5pt: 440 GCells/s @ t=7, 482 @ t=12 (§6.2.1); j3d7pt: 197 w/ device
  tiling (§6.3.2); our A100-model prediction for the same configs is printed
  alongside (model-vs-measured, the §7.4.7 '80-88% of PP' effect included).
"""
from __future__ import annotations

import math

from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2, TABLE3_DEPTHS


def _naive(spec, hw):
    return rl.attainable(spec, 1, hw, rst=False).pp_cells_per_s


def _shallow(spec, hw, t):
    if not t:
        return 0.0
    tile = (256, 256) if spec.ndim == 2 else (32, 32)
    v = max(0.05, rl.v_smtile(spec, t, tile))
    return rl.attainable(spec, t, hw, rst=False, v=v).pp_cells_per_s


def rows():
    out = []
    sp_naive, sp_shallow = [], []
    sp_naive_a, sp_shallow_a = [], []
    for name, spec in TABLE2.items():
        d = TABLE3_DEPTHS[name]
        t_shallow = max(v for k, v in d.items() if k != "ebisu" and v)
        for hw, tag in ((rl.A100_FP64, "a100"), (rl.TPU_V5E, "v5e")):
            ebisu = plan(spec, hw).pp.pp_cells_per_s
            nv = _naive(spec, hw)
            sh = _shallow(spec, hw, t_shallow)
            if tag == "v5e":
                sp_naive.append(ebisu / nv)
                sp_shallow.append(ebisu / sh)
            else:
                sp_naive_a.append(ebisu / nv)
                sp_shallow_a.append(ebisu / sh)
            out.append((f"fig7/{name}/{tag}", 0.0,
                        f"ebisu={ebisu/1e9:.0f}G|naive={nv/1e9:.0f}G|"
                        f"shallow(t={t_shallow})={sh/1e9:.0f}G|"
                        f"speedup_vs_naive={ebisu/nv:.2f}x|"
                        f"vs_shallow={ebisu/sh:.2f}x"))
    geo = lambda xs: math.exp(sum(map(math.log, xs)) / len(xs))  # noqa: E731
    out.append(("fig7/geomean-a100", 0.0,
                f"vs_naive={geo(sp_naive_a):.2f}x|"
                f"vs_shallow={geo(sp_shallow_a):.2f}x|"
                f"paper_vs_best_sota=1.49x(measured) <- the reproduction "
                f"anchor"))
    out.append(("fig7/geomean-v5e", 0.0,
                f"vs_naive={geo(sp_naive):.2f}x|"
                f"vs_shallow={geo(sp_shallow):.2f}x|"
                f"note=VPU-bound earlier than A100 (DESIGN.md §2)"))
    # model-vs-paper-measured anchors
    s2 = TABLE2["j2d5pt"]
    for t, meas in ((7, 440), (12, 482)):
        pred = rl.attainable(s2, t, rl.A100_FP64, rst=True,
                             v=0.95).pp_cells_per_s / 1e9
        out.append((f"fig7/anchor-j2d5pt-t{t}", 0.0,
                    f"model={pred:.0f}G|paper_measured={meas}G|"
                    f"ratio={meas/pred:.2f}"))
    s3 = TABLE2["j3d7pt"]
    pred = plan(s3, rl.A100_FP64).pp.pp_cells_per_s / 1e9
    out.append(("fig7/anchor-j3d7pt", 0.0,
                f"model={pred:.0f}G|paper_measured=197G(w/Dtile)|"
                f"note=per-SM-budget-model(paper shares 17MB device-wide)"))
    return out
