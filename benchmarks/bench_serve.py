"""``serve/`` bench family: the request path, measured end to end.

What coalescing buys is the measured ``run_batched`` win amortized over
a *request stream*: one vmapped dispatch per shape bucket instead of one
dispatch per request.  Rows drive the real :class:`ServiceCore` (real
monotonic clock — latencies here are wall time, unlike the CLI driver's
simulated clock) over a fixed seeded burst of requests:

    serve/coalesced-<spec>    us_per_call = wall us per request
        derived: rps|p99_latency_us|batches|note
    serve/unbatched-<spec>    the same burst at max_batch=1 (every
        request dispatches alone — the no-coalescing control)
    serve/degraded-<spec>     the same burst under injected faults
        (forced evictions + OOM above half width): the ladder must keep
        serving at reduced throughput, never stall — the row exists to
        track the *cost of degrading*, not to win

Interpret-mode wall time on a shared CPU is noisy (see DESIGN.md §14);
the tracked quantities are the coalesced/unbatched ratio and the
degraded row's completion — both load-resistant.  Rows are persisted to
``BENCH_serve.json`` by ``benchmarks/run.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.serve.faults import FaultConfig, FaultInjector
from repro.serve.stencil_service import (ServeRequest, ServiceConfig,
                                         ServiceCore)
from repro.stencils.data import init_domain
from repro.core.stencil_spec import get

# one 2-D case: service-path benches re-dispatch N_REQ requests per row,
# so the budget goes to stream length rather than spec breadth.  The
# shape/T/width regime is the one where the batched win was measured
# (PR 3's program/batch4 row): compute-bound enough that one vmapped
# dispatch beats a dispatch per request.  Width matters: vmap over the
# interpret-mode kernel scales superlinearly on CPU, so the raw win
# decays with width (measured here: 1.9x at 2, 1.6x at 4, gone by 8) —
# which is exactly why ``ServiceConfig.batch_widths`` is tunable.  T
# matters too: the request path itself is Python-bound (~constant
# us/request of submit/poll/resolve machinery either way), so T must be
# deep enough that compute dominates machinery or the ratio drowns —
# T=12 measures near-parity, T=24 a stable ~1.3x stream-level win.
CASE = ("j2d5pt", (128, 128), 24)    # name, shape, total_t
N_REQ = 24
MAX_BATCH = 4


def _drive(core: ServiceCore, spec, shape, total_t: int):
    """Submit the seeded burst, drain, return resolved tickets.

    Inputs are materialized BEFORE the first submit: the rps window runs
    first-admit -> last-resolve, and building domains inside it would add
    a constant per-request cost that drowns the batched-vs-solo delta."""
    fields = [init_domain(spec, shape, seed=i) for i in range(N_REQ)]
    tks = [core.submit(ServeRequest(spec, x, total_t=total_t))
           for x in fields]
    core.drain()
    return tks


def _row(label: str, core: ServiceCore, tickets) -> tuple:
    stats = core.stats()
    n_ok = sum(1 for tk in tickets if tk.ok)
    assert all(tk.done for tk in tickets), f"{label}: unresolved tickets"
    rps = stats.get("requests_per_sec", 0.0)
    us_per_req = 1e6 / rps if rps else float("inf")
    return (f"serve/{label}", us_per_req,
            f"rps={rps:.1f}|"
            f"p99_latency_us={stats.get('p99_latency_ms', 0) * 1e3:.0f}|"
            f"batches={stats.get('batches', 0)}|"
            f"ok={n_ok}/{len(tickets)}|"
            f"note=real-clock-request-stream")


def _best_rows(scenarios, spec, shape, total_t: int,
               repeats: int = 3) -> list:
    """Best-of-N over whole request streams, with the repeats
    INTERLEAVED across scenarios (same estimator as ``common.time_fn``:
    shared-CPU contamination is one-sided, so each scenario's
    minimum-elapsed stream is its least-contaminated one — and
    interleaving means a load burst hits all scenarios, not just
    whichever one was running, keeping the tracked ratio honest)."""
    best = {}
    for _ in range(repeats):
        for label, make_core, check in scenarios:
            core = make_core()
            tks = _drive(core, spec, shape, total_t)
            if check is not None:
                check(core, tks)
            row = _row(label, core, tks)
            if label not in best or row[1] < best[label][1]:
                best[label] = row
    return [best[label] for label, _, _ in scenarios]


def rows():
    name, shape, total_t = CASE
    spec = get(name)

    def fresh(max_batch: int, faults=None) -> ServiceCore:
        # window 0: every poll dispatches what has arrived — the burst
        # is fully enqueued before the first drain pass, so coalescing
        # still forms full batches
        return ServiceCore(ServiceConfig(max_batch=max_batch,
                                         batch_window_ms=0.0,
                                         max_queue=4 * N_REQ,
                                         max_inflight_per_tenant=4 * N_REQ),
                           faults=faults)

    # degraded mode: every batch wider than half OOMs, 30% of dispatches
    # hit an eviction race — the ladder narrows and retries but serves.
    # NOTE the eviction faults clear RUNNER_CACHE, so the degraded row
    # legitimately pays re-jit costs — that IS the degraded mode.
    def degraded_faults() -> FaultInjector:
        return FaultInjector(FaultConfig(seed=0, evict_rate=0.3,
                                         oom_batch_limit=MAX_BATCH // 2))

    # warm every dispatch width each scenario reaches (bench protocol:
    # steady-state serving, not first-compile) — the degraded warm pass
    # replays the same seeded fault sequence, so the ladder's narrower
    # widths and the solo path compile outside timing too.  It runs
    # FIRST: its injected evictions clear the runner cache, which would
    # un-warm anything warmed before it.
    for warm in (fresh(MAX_BATCH, faults=degraded_faults()),
                 fresh(MAX_BATCH), fresh(1)):
        _drive(warm, spec, shape, total_t)

    # the degraded row only earns its keep if every request resolved OK
    def _all_ok(core, tks):
        assert all(tk.ok for tk in tks), "degraded run dropped requests"
        s = core.stats()
        _all_ok.extra = (f"splits={s.get('ladder_splits', 0)}|"
                         f"retries={s.get('retries', 0)}|"
                         f"note=fault-injected-ladder-kept-serving")

    out = _best_rows(
        [(f"coalesced-{name}-T{total_t}",
          lambda: fresh(MAX_BATCH), None),
         (f"unbatched-{name}-T{total_t}",
          lambda: fresh(1), None),
         (f"degraded-{name}-T{total_t}",
          lambda: fresh(MAX_BATCH, faults=degraded_faults()), _all_ok)],
        spec, shape, total_t)
    r = out[-1]
    out[-1] = (r[0], r[1],
               r[2].replace("note=real-clock-request-stream",
                            _all_ok.extra))
    return out


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(rows())
