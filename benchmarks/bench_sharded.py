"""``sharded/`` bench family: deep-halo-per-block vs exchange-per-step.

What temporal blocking buys across a mesh is *fewer collective rounds*
at constant halo bytes (DESIGN.md §12): a ``T``-step run at block depth
``t`` performs ``ceil(T/t)`` ppermute rounds per sharded axis where the
classic ghost-exchange scheme performs ``T``.  Rows time
``run_sharded`` at the planned depth against the same program pinned to
``t=1`` (exchange every step) on a faked multi-device CPU mesh:

    sharded/<spec>-T<T>-mesh<MxN>  us_per_call
        derived: perstep_us|speedup|rounds=<blocked>/<perstep>|
                 halo_cells_per_round|note

``us_per_call`` is interpret-free jnp wall time (the per-shard compute
is the tap-engine chain), so the ratio — not the absolute time — is the
tracked quantity; rounds and halo cells are derived analytically from
the schedule and slab geometry.

Multi-device faking requires ``XLA_FLAGS=--xla_force_host_platform_
device_count`` *before* backend init, so ``rows()`` re-executes this
module as a child process (the same pattern as ``tests/multidev_*``)
and parses its CSV; run directly with ``--child`` inside such an
environment to see the rows without the wrapper.
"""
from __future__ import annotations

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASES = (
    # name, shape, mesh, t, T
    ("j2d5pt", (64, 256), (2, 4), 6, 24),
    ("j3d7pt", (32, 32, 16), (2, 4), 4, 16),
)

N_DEVICES = 8


def halo_cells_per_round(shape, mesh, h: int) -> int:
    """Cells moved by one deep-halo exchange round (both directions, all
    sharded axes, the sequential-extension corner slabs included)."""
    ext = list(s // n for s, n in zip(shape, mesh)) + list(shape[len(mesh):])
    total = 0
    for d, n in enumerate(mesh):
        if n == 1:
            continue
        other = 1
        for k, e in enumerate(ext):
            if k != d:
                other *= e
        total += 2 * h * other * n          # per-shard slabs x shards
        ext[d] += 2 * h                     # later axes carry the corners
    return total


def _child_rows():
    import jax.numpy as jnp

    from benchmarks.common import time_pair
    from repro.api import compile_stencil, planned_exchange_rounds
    from repro.core.stencil_spec import get
    from repro.stencils.data import init_domain

    out = []
    for name, shape, mesh, t, total in CASES:
        spec = get(name)
        x = init_domain(spec, shape)
        blocked = compile_stencil(spec, shape, t=t, mesh=mesh,
                                  interpret=True)
        perstep = compile_stencil(spec, shape, t=1, mesh=mesh,
                                  interpret=True)
        yb = blocked.run_sharded(x, total)          # compile outside timing
        yp = perstep.run_sharded(x, total)
        assert float(jnp.abs(yb - yp).max()) < 1e-4, name
        us_blocked, us_perstep = time_pair(
            lambda: blocked.run_sharded(x, total),
            lambda: perstep.run_sharded(x, total), iters=5)
        r_blk = planned_exchange_rounds(total, t)
        mesh_s = "x".join(map(str, mesh))
        h = spec.halo(t)
        out.append((f"sharded/{name}-T{total}-mesh{mesh_s}", us_blocked,
                    f"perstep_us={us_perstep:.0f}|"
                    f"speedup={us_perstep / us_blocked:.2f}x|"
                    f"rounds={r_blk}/{total}|"
                    f"halo_cells_per_round={halo_cells_per_round(shape, mesh, h)}|"
                    f"note=deep-halo-per-block-vs-exchange-per-step"))
    return out


def rows():
    """Spawn the faked-multi-device child and parse its CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"{env.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count={N_DEVICES}").strip()
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child"],
        cwd=_ROOT, env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench child failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    out = []
    for line in r.stdout.splitlines():
        if line.startswith("sharded/"):
            name, us, derived = line.split(",", 2)
            out.append((name, float(us), derived))
    return out


if __name__ == "__main__":
    if "--child" in sys.argv:
        from benchmarks.common import emit
        emit(_child_rows())
    else:
        from benchmarks.common import emit
        emit(rows())
