"""Paper Fig. 8: occupancy + on-chip resource use per benchmark.

TPU analogue: the VMEM footprint each EBISU plan claims (scratch rings +
strip buffers) as a fraction of the 128 MiB budget, plus the parallelism
setting (num_buffers × ILP — the Little's-law minimum, §6.1).
derived: ``vmem=<MiB>(<pct>)|buffers=<n>|ilp=<n>``.
"""
from __future__ import annotations

from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2


def rows():
    out = []
    for name, spec in TABLE2.items():
        p = plan(spec, rl.TPU_V5E)
        frac = p.vmem_bytes / rl.TPU_V5E.onchip_bytes
        out.append((f"fig8/{name}", 0.0,
                    f"vmem={p.vmem_bytes/2**20:.1f}MiB({frac:.0%})|"
                    f"buffers={p.parallelism.num_buffers}|"
                    f"ilp={p.parallelism.ilp}|tile={p.block}"))
    return out
