"""Kernel micro-benchmarks: blocked-vs-naive traffic, wall time (interpret).

derived: modeled HBM-traffic ratio naive/EBISU on v5e — the quantity the
paper's temporal blocking exists to improve.  Naive runs ``t`` full
load+store passes over the domain; the blocked kernel runs one pass whose
loads are inflated only by the halo-exact rim fetch.  The inflation is
derived from ``repro.api.resolve_geometry`` — the tile the launch
*actually* resolves (plan wiring, halo rounding and XY tiling included) —
not from the plan-less default tile constants.

``sweep/`` rows measure the zero-copy multi-sweep executor against the
naive driver loop (one fresh compile-and-apply per sweep, re-padding and
re-dispatching every ``t`` steps) at ``T`` total time steps.

``program/`` rows measure the compile-once front door: steady-state
per-call time of a held ``StencilProgram`` handle vs the per-call path
(re-resolving the program from the bounded caches on every call — what
the deprecated ``ops.ebisu_stencil`` shim does, minus its warning), and
one vmapped ``run_batched`` dispatch vs a Python loop of per-field
``run`` calls.

Everything here drives ``repro.api`` directly — no deprecated ``ops`` /
``sweep`` shims, so tier-1 and bench output stay DeprecationWarning-clean
while the measured dispatch paths are unchanged.
"""
from __future__ import annotations

from benchmarks.common import time_fn, time_pair
from repro.api import compile_stencil, define_stencil, resolve_geometry, \
    sweep_schedule
from repro.core.stencil_spec import StencilSpec, get
from repro.kernels import ref
from repro.stencils.data import init_domain


def reads_per_elem(spec: StencilSpec, t: int, shape: tuple[int, ...],
                   plan=None) -> float:
    """Input loads per output element per blocked sweep, halo-exact, for
    the tile geometry this launch resolves."""
    g = resolve_geometry(spec, t, shape, plan=plan)
    return g["fetched_cells"] / g["body_cells"]


def modeled_traffic_ratio(spec: StencilSpec, t: int, shape: tuple[int, ...],
                          plan=None) -> float:
    """Naive ``t``-step HBM traffic over the blocked kernel's traffic.

    a_gm = 2 is one load + one store per cell (§6.2).  Naive pays it every
    step; the blocked sweep pays halo-inflated loads plus stores once.
    """
    naive = t * spec.a_gm
    blocked = spec.a_gm / 2 * (reads_per_elem(spec, t, shape, plan) + 1)
    return naive / blocked


# Table-2 coverage: star and box, 2-D and 3-D, radius 1 and 2.
KERNEL_CASES = (("j2d5pt", (256, 256), 6),
                ("j2d9pt", (192, 192), 4),
                ("j3d7pt", (32, 24, 32), 4),
                ("j3d27pt", (24, 16, 24), 2))

SWEEP_CASES = (("j2d5pt", (256, 256), 6, 24),
               ("j3d7pt", (32, 24, 32), 4, 24))

PROGRAM_CASES = (("j2d5pt", (256, 256), 6),
                 ("j3d7pt", (32, 24, 32), 4))

BATCH_CASE = ("j2d5pt", (128, 128), 4, 12, 4)   # name, shape, t, T, batch

# A user-defined spec through the open definition layer (no registry, no
# Table-2 numbers): the anisotropic unnormalized 2-D 5-point.  Tracks that
# define_stencil programs pay no toll vs registry specs of the same shape.
CUSTOM_CASE = (define_stencil(
    (((0, 0), 0.55), ((0, 1), 0.2), ((0, -1), 0.1),
     ((1, 0), 0.08), ((-1, 0), 0.04)), name="aniso5"), (256, 256), 6)


def _percall_apply(spec, shape, t):
    """The per-call dispatch path (what the deprecated shim did, minus
    its warning): re-resolve the program from the bounded caches on
    every call, then apply — plan-less legacy tiles."""
    def call(x):
        return compile_stencil(spec, shape, t=t, plan=None,
                               interpret=True).apply(x)
    return call


def _program_rows():
    import jax.numpy as jnp

    out = []
    for name, shape, t in PROGRAM_CASES:
        spec = get(name)
        x = init_domain(spec, shape)
        # legacy tiles (plan=None) on both sides: the delta isolates
        # the per-call resolution overhead, not a tile change
        prog = compile_stencil(spec, shape, t=t, plan=None,
                               interpret=True)
        percall = _percall_apply(spec, shape, t)
        prog.apply(x)                       # compile outside timing
        us_prog, us_legacy = time_pair(
            lambda: prog.apply(x), lambda: percall(x))
        out.append((f"program/{name}-t{t}", us_prog,
                    f"legacy_percall_us={us_legacy:.0f}|"
                    f"overhead={us_legacy / us_prog - 1:+.1%}|"
                    f"note=held-handle-vs-legacy-shim-steady-state"))

    name, shape, t, total, nb = BATCH_CASE
    spec = get(name)
    xs = jnp.stack([init_domain(spec, shape, seed=i)
                    for i in range(nb)])
    prog = compile_stencil(spec, shape, t=t, interpret=True)
    prog.run_batched(xs, total)             # compile outside timing

    def looped():
        return [prog.run(xs[i], total) for i in range(nb)]

    us_batched, us_looped = time_pair(
        lambda: prog.run_batched(xs, total), looped)
    out.append((f"program/{name}-batch{nb}-T{total}", us_batched,
                f"looped_us={us_looped:.0f}|"
                f"speedup={us_looped / us_batched:.2f}x|"
                f"note=one-vmapped-dispatch-vs-python-loop-of-run"))

    # user-defined spec (open definition layer) vs the registry spec
    # of the same tap shape at the same tile/depth
    cspec, cshape, ct = CUSTOM_CASE
    xc = init_domain(cspec, cshape)
    cprog = compile_stencil(cspec, cshape, t=ct, plan=None,
                            interpret=True)
    rprog = compile_stencil(get("j2d5pt"), cshape, t=ct, plan=None,
                            interpret=True)
    cprog.apply(xc), rprog.apply(xc)        # compile outside timing
    us_custom, us_reg = time_pair(lambda: cprog.apply(xc),
                                  lambda: rprog.apply(xc))
    out.append((f"custom/{cspec.name}-t{ct}", us_custom,
                f"registry_j2d5pt_us={us_reg:.0f}|"
                f"overhead={us_custom / us_reg - 1:+.1%}|"
                f"note=define_stencil-vs-registry-same-shape"))
    return out


def rows():
    from repro.tuning.analytic import analytic_bytes_per_step

    out = []
    for name, shape, t in KERNEL_CASES:
        spec = get(name)
        x = init_domain(spec, shape)
        percall = _percall_apply(spec, shape, t)
        us_blocked = time_fn(lambda: percall(x))
        us_naive = time_fn(lambda: ref.reference(x, spec, t))
        grid = resolve_geometry(spec, t, shape)["grid"]
        # lowered-HLO HBM bytes per step of the same plan-less program
        # the wall-time row runs — deterministic, so scripts/bench_gate.py
        # can flag traffic regressions under any machine load
        ab = analytic_bytes_per_step(
            compile_stencil(spec, shape, t=t, plan=None, interpret=True), t)
        out.append((f"kernel/{name}-t{t}", us_blocked,
                    f"naive_us={us_naive:.0f}|"
                    f"analytic_bytes={ab:.0f}|"
                    f"hbm_traffic_ratio={modeled_traffic_ratio(spec, t, shape):.2f}x|"
                    f"reads_per_elem={reads_per_elem(spec, t, shape):.3f}|"
                    f"grid={'x'.join(map(str, grid))}|"
                    f"note=CPU-interpret-wall-time"))

    for name, shape, t, total in SWEEP_CASES:
        spec = get(name)
        x = init_domain(spec, shape)
        prog = compile_stencil(spec, shape, t=t, interpret=True)
        percall = _percall_apply(spec, shape, t)

        def loop():
            v = x
            for _ in range(total // t):
                v = percall(v)
            return v

        us_exec, us_loop = time_pair(
            lambda: prog.run(x, total), loop)
        out.append((f"sweep/{name}-T{total}", us_exec,
                    f"persweep_loop_us={us_loop:.0f}|"
                    f"speedup={us_loop / us_exec:.2f}x|"
                    f"sweeps={len(sweep_schedule(total, t))}|"
                    f"note=plan-wired-executor-vs-planless-persweep-calls"))

    out.extend(_program_rows())
    return out
