"""Kernel micro-benchmarks: blocked-vs-naive traffic, wall time (interpret).

derived: modeled HBM-traffic ratio naive/EBISU on v5e — the quantity the
paper's temporal blocking exists to improve.  Naive runs ``t`` full
load+store passes over the domain; the blocked kernel runs one pass whose
loads are inflated only by the halo-exact rim fetch (``(tile + 2·halo)/
tile`` on the blocked axis), so the real ratio is ``t·a_gm`` over
``a_gm·(1 + (tile + 2·halo)/tile)/2`` — not the degenerate ``t·a_gm/a_gm``.
"""
from __future__ import annotations

from benchmarks.common import time_fn
from repro.core import roofline as rl
from repro.core.stencil_spec import StencilSpec, get
from repro.kernels import ops
from repro.kernels.ops import DEFAULT_BH_2D, DEFAULT_ZC_3D
from repro.kernels.stencil2d import input_rows_per_strip
from repro.kernels.stencil3d import input_planes_per_chunk
from repro.stencils.data import init_domain


def reads_per_elem(spec: StencilSpec, t: int, tile: int) -> float:
    """Input loads per element per blocked sweep (halo-exact fetching)."""
    if spec.ndim == 2:
        fetched, body = input_rows_per_strip(spec, t, tile)
    else:
        fetched, body = input_planes_per_chunk(spec, t, tile)
    return fetched / body


def modeled_traffic_ratio(spec: StencilSpec, t: int, tile: int) -> float:
    """Naive ``t``-step HBM traffic over the blocked kernel's traffic.

    a_gm = 2 is one load + one store per cell (§6.2).  Naive pays it every
    step; the blocked sweep pays halo-inflated loads plus stores once.
    """
    naive = t * spec.a_gm
    blocked = spec.a_gm / 2 * (reads_per_elem(spec, t, tile) + 1)
    return naive / blocked


def rows():
    out = []
    for name, shape, t in (("j2d5pt", (256, 256), 6),
                           ("j3d7pt", (32, 24, 32), 4)):
        spec = get(name)
        x = init_domain(spec, shape)
        tile = DEFAULT_BH_2D if spec.ndim == 2 else DEFAULT_ZC_3D
        us_blocked = time_fn(
            lambda: ops.ebisu_stencil(x, spec, t, interpret=True))
        us_naive = time_fn(lambda: ops.naive_stencil(x, spec, t))
        ratio = modeled_traffic_ratio(spec, t, tile)
        out.append((f"kernel/{name}-t{t}", us_blocked,
                    f"naive_us={us_naive:.0f}|"
                    f"hbm_traffic_ratio={ratio:.2f}x|"
                    f"reads_per_elem={reads_per_elem(spec, t, tile):.3f}|"
                    f"note=CPU-interpret-wall-time"))
    return out
