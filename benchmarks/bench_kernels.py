"""Kernel micro-benchmarks: blocked-vs-naive traffic, wall time (interpret).

derived: modeled HBM-traffic ratio naive/EBISU on v5e — the quantity the
paper's temporal blocking exists to improve (t passes over the domain vs 1).
"""
from __future__ import annotations

from benchmarks.common import time_fn
from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import get
from repro.kernels import ops
from repro.stencils.data import init_domain


def rows():
    out = []
    for name, shape, t in (("j2d5pt", (256, 256), 6),
                           ("j3d7pt", (32, 24, 32), 4)):
        spec = get(name)
        x = init_domain(spec, shape)
        us_blocked = time_fn(
            lambda: ops.ebisu_stencil(x, spec, t, interpret=True))
        us_naive = time_fn(lambda: ops.naive_stencil(x, spec, t))
        # naive: 2 HBM accesses/cell/step; blocked: 2 per t steps (+halo)
        traffic_ratio = t * spec.a_gm / spec.a_gm
        out.append((f"kernel/{name}-t{t}", us_blocked,
                    f"naive_us={us_naive:.0f}|hbm_traffic_ratio={traffic_ratio:.1f}x|"
                    f"note=CPU-interpret-wall-time"))
    return out
