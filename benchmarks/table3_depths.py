"""Paper Table 3: temporal-blocking depth chosen per implementation.

derived: planner depth on A100/TPU vs the paper's EBISU depth — validates
that the §6 decision procedure lands in the paper's regime (the paper's own
fine-tuning moves depth by ~1.5-2x around the analytic value, §6.2.1).
"""
from __future__ import annotations

from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2, TABLE3_DEPTHS


def rows():
    out = []
    for name, spec in TABLE2.items():
        t_paper = TABLE3_DEPTHS[name]["ebisu"]
        t_a100 = plan(spec, rl.A100_FP64).t
        t_tpu = plan(spec, rl.TPU_V5E).t
        sota = max(v for k, v in TABLE3_DEPTHS[name].items()
                   if k != "ebisu" and v)
        out.append((f"table3/{name}", 0.0,
                    f"paper_ebisu={t_paper}|ours_a100={t_a100}|"
                    f"ours_tpu={t_tpu}|deepest_sota={sota}"))
    return out
