"""Paper Fig. 9: incremental optimization breakdown (BASE → +CMQ → +PRE →
+LST → +RST) on the 2d5pt / 3d7pt case studies, via the §5 model on v5e.

Mapping of each scheme to a model parameter (DESIGN.md §2):
  BASE: t=1, no queue, synchronous I/O (V_Dtile with n=t syncs);
  CMQ : deep t (planner) — moves OI right, shifting the gm bottleneck;
  PRE : pipelined DMA (num_buffers>=2) — removes the latency penalty
        (modeled as the Little's-law stall fraction);
  LST : one sync per tile instead of per plane-step (V_Dtile n: t -> 1);
  RST : a_sm w/ RST — cuts scratchpad traffic, raising the sm-bound.
derived: modeled GCells/s after each increment (paper's Fig. 9 shape:
monotone except LST-on-3D, which the paper also observed regressing).
"""
from __future__ import annotations

from repro.core import roofline as rl
from repro.core.planner import minimal_parallelism, plan
from repro.core.stencil_spec import get

HW = rl.TPU_V5E


def _stall_fraction(spec, hw, plane_cells):
    """Latency stall when not prefetching: one HBM latency per plane DMA."""
    par = minimal_parallelism(hw, plane_cells * hw.s_cell)
    t_plane = plane_cells * hw.s_cell / hw.b_gm
    return t_plane / (t_plane + hw.mem_latency)


def stages(name: str):
    spec = get(name)
    p = plan(spec, HW)
    tile_cells = (p.block[0] * p.block[1] if spec.ndim == 2
                  else p.block[0] * p.block[1] * p.block[2])
    plane = p.block[-1] * (p.block[-2] if spec.ndim == 3 else 1)

    def tile_time(t, rst):
        tg, ts, tc, _ = rl.component_times(spec, t, HW, rst=rst,
                                           d_all=tile_cells)
        return max(tg, ts, tc)

    out = []
    # BASE: t=1, per-plane sync, no prefetch
    v = rl.v_dtile(tile_time(1, False), HW, n_syncs=max(1, tile_cells // plane))
    base = rl.attainable(spec, 1, HW, rst=False, v=v * _stall_fraction(
        spec, HW, plane)).pp_cells_per_s
    out.append(("BASE", base))
    # +CMQ: deep temporal blocking via the circular multi-queue
    v = rl.v_dtile(tile_time(p.t, False), HW,
                   n_syncs=max(1, tile_cells // plane))
    cmq = rl.attainable(spec, p.t, HW, rst=False, v=v * _stall_fraction(
        spec, HW, plane)).pp_cells_per_s
    out.append(("+CMQ", cmq))
    # +PRE: pipelined DMA hides the latency stall
    pre = rl.attainable(spec, p.t, HW, rst=False, v=v).pp_cells_per_s
    out.append(("+PRE", pre))
    # +LST: one sync per tile
    v1 = rl.v_dtile(tile_time(p.t, False), HW, n_syncs=1)
    lst = rl.attainable(spec, p.t, HW, rst=False, v=v1).pp_cells_per_s
    out.append(("+LST", lst))
    # +RST: register streaming cuts a_sm
    rst = rl.attainable(spec, p.t, HW, rst=True, v=v1).pp_cells_per_s
    out.append(("+RST", rst))
    return out, spec


def rows():
    out = []
    for name in ("j2d5pt", "j3d7pt"):
        st, spec = stages(name)
        chain = "->".join(f"{k}:{v/1e9:.0f}G" for k, v in st)
        bound = rl.attainable(spec, plan(spec, HW).t, HW, rst=True,
                              v=1.0).p_cells_per_s
        out.append((f"fig9/{name}", 0.0,
                    f"{chain}|attainable={bound/1e9:.0f}G|"
                    f"final_frac={st[-1][1]/bound:.0%}"))
    return out
