"""Shared benchmark helpers: timing + CSV row convention.

Every benchmark module exposes ``rows() -> list[(name, us_per_call, derived)]``;
``benchmarks.run`` prints them as ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in µs (CPU wall time — the TPU-relevant
    numbers are the model/dry-run 'derived' column)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows):
    for name, us, derived in rows:
        us_s = f"{us:.1f}" if isinstance(us, (int, float)) else str(us)
        print(f"{name},{us_s},{derived}")
