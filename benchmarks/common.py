"""Shared benchmark helpers: timing + CSV row convention.

Every benchmark module exposes ``rows() -> list[(name, us_per_call, derived)]``;
``benchmarks.run`` prints them as ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Best wall-time per call in µs (CPU wall time — the TPU-relevant
    numbers are the model/dry-run 'derived' column).

    Best-of-N rather than median: interpret-mode wall time on a shared
    CPU is contaminated one-sidedly (scheduler preemption, GC), so the
    minimum is the stable estimator — medians were observed to swing
    ±60% between identical runs, which would make the bench-gate
    regression threshold meaningless."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def time_pair(fn_a, fn_b, warmup: int = 1, iters: int = 7):
    """Best wall-time per call in µs for two functions, iterations
    interleaved A/B so a burst of neighbor-CPU contention degrades both
    sides alike — use when the *ratio* of the two is the quantity of
    interest (e.g. executor vs per-sweep loop)."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e6, min(tb) * 1e6


def emit(rows):
    for name, us, derived in rows:
        us_s = f"{us:.1f}" if isinstance(us, (int, float)) else str(us)
        print(f"{name},{us_s},{derived}")
