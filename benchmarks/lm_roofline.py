"""The 40-cell LM roofline table (framework deliverable g).

Reads the dry-run JSONs from results/dryrun (produced by
``python -m repro.launch.dryrun``) and emits one row per (arch × shape)
single-pod cell: the three roofline terms, dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPs "useful compute" ratio, and the roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.environ.get("REPRO_DRYRUN_RESULTS", "results/dryrun")


def load(mesh="single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def rows():
    out = []
    recs = load("single")
    if not recs:
        return [("lm_roofline/missing", 0.0,
                 f"no dry-run results under {RESULTS} — run "
                 "`python -m repro.launch.dryrun` first")]
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r["status"] == "skipped":
            out.append((name, 0.0, f"skipped:{r['reason']}"))
            continue
        if r["status"] != "ok":
            out.append((name, 0.0, f"ERROR:{r.get('error','?')[:80]}"))
            continue
        t = r["terms"]
        step = max(t.values())
        out.append((name, step * 1e6,
                    f"cmp={t['compute_s']:.3f}s|mem={t['memory_s']:.3f}s|"
                    f"coll={t['collective_s']:.3f}s|dom={r['dominant']}|"
                    f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'],2)}|"
                    f"roofline={r['roofline_fraction'] and round(r['roofline_fraction'],4)}|"
                    f"hbm_ok={r['hbm_ok']}"))
    # multi-pod pass/fail summary
    multi = load("multi")
    ok = sum(1 for r in multi if r["status"] == "ok")
    skip = sum(1 for r in multi if r["status"] == "skipped")
    err = sum(1 for r in multi if r["status"] not in ("ok", "skipped"))
    out.append(("roofline/multi-pod-summary", 0.0,
                f"ok={ok}|skipped={skip}|errors={err} (512-chip mesh)"))
    return out
