"""Core NN layers: norms, RoPE, MLPs, embeddings, chunked cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamDef


def shard(x, *spec):
    """Sharding-constraint helper; no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


# ------------------------------------------------------------------- norms --
def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def norm_defs(d_model: int, kind: str):
    if kind == "ln":
        return {"scale": ParamDef((d_model,), P(), "ones"),
                "bias": ParamDef((d_model,), P(), "zeros")}
    return {"scale": ParamDef((d_model,), P(), "ones")}


def apply_norm(x, p, kind: str, eps=1e-6):
    if kind == "ln":
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p["scale"], eps)


# -------------------------------------------------------------------- RoPE --
def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) — half-rotation convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x32 = (x1.astype(jnp.float32), x2.astype(jnp.float32))
    return jnp.concatenate(
        [x32[0] * cos - x32[1] * sin, x32[1] * cos + x32[0] * sin],
        axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- MLP --
def mlp_defs(d_model: int, d_ff: int, act: str):
    defs = {"w_up": ParamDef((d_model, d_ff), P(None, "model")),
            "w_down": ParamDef((d_ff, d_model), P("model", None))}
    if act in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d_model, d_ff), P(None, "model"))
    return defs


def apply_mlp(x, p, act: str):
    up = x @ p["w_up"]
    if act == "swiglu":
        up = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "geglu":
        up = jax.nn.gelu(x @ p["w_gate"]) * up
    elif act == "gelu":
        up = jax.nn.gelu(up)
    else:
        up = jax.nn.silu(up)
    return up @ p["w_down"]


# -------------------------------------------------------------- embeddings --
def embed_defs(vocab: int, d_model: int):
    # 0.02 std (GPT-2 convention) keeps tied-embedding logits sane at init
    return {"table": ParamDef((vocab, d_model), P(None, "model"),
                              "normal", scale=0.02)}


def embed_lookup(tokens, table):
    return jnp.take(table, tokens, axis=0)


# --------------------------------------------------------- chunked CE loss --
def chunked_ce_loss(hidden, table, labels, mask=None, chunk: int = 512,
                    logit_pspec=("data", None, "model")):
    """Cross-entropy against tied-embedding logits, scanning over sequence
    chunks so the (B, S, V) logits tensor is never materialized whole.

    hidden: (B, S, d); table: (V, d); labels: (B, S) int32; mask: (B, S).
    """
    b, s, d = hidden.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def one(h_c, l_c, m_c):
        logits = jnp.einsum("bsd,vd->bsv", h_c.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = shard(logits, *logit_pspec)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m_c), jnp.sum(m_c)

    def body(carry, xs):
        h_c, l_c, m_c = xs
        tot, cnt = one(h_c, l_c, m_c)
        return (carry[0] + tot, carry[1] + cnt), ()

    if n > 0:
        hs = hidden[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
        ls = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
        ms = mask[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
        (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hs, ls, ms))
    else:
        tot, cnt = 0.0, 0.0
    if rem:
        t2, c2 = one(hidden[:, n * chunk:], labels[:, n * chunk:],
                     mask[:, n * chunk:])
        tot, cnt = tot + t2, cnt + c2
    return tot / jnp.maximum(cnt, 1.0)
