"""Model zoo: decoder LMs (dense/MoE/SSM/hybrid), encoders, VLM wrapper.

All architectures share one blocks-as-scanned-pytrees implementation:
per-layer parameters are stacked on a leading L dim and the layer loop is a
``lax.scan`` (keeps HLO size flat for the 94-layer MoE on the 512-device
dry-run).  Families:

  dense    — pre-norm attention + (Swi/Ge)GLU MLP        (danube/minicpm/gemma/qwen3)
  moe      — attention + top-k MoE FFN                   (qwen3-moe/granite-moe)
  ssm      — mamba2 SSD mixer only                       (mamba2-130m)
  hybrid   — mamba2 blocks + one *shared* attention+MLP
             block applied every ``attn_every`` layers   (zamba2)
  encoder  — bidirectional attention, LayerNorm, masked-
             prediction head (frames stub input)         (hubert)
  vlm      — decoder LM with patch-embedding stub prefix (internvl2)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.api.attention import attention_program_for
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.params import ParamDef, map_stacked


# ---------------------------------------------------------------- attention --
def attn_defs(cfg):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), P(None, "model")),
        "wk": ParamDef((d, kv * hd), P(None, "model")),
        "wv": ParamDef((d, kv * hd), P(None, "model")),
        "wo": ParamDef((h * hd, d), P("model", None)),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), P(), "ones")
        defs["k_norm"] = ParamDef((hd,), P(), "ones")
    return defs


def apply_attn(x, p, cfg, *, positions, causal=True):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, kv, hd)
    v = (x @ p["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    if cfg.rope_theta:
        cos, sin = L.rope_cos_sin(positions, hd, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    if cfg.attention_impl == "boundary_stub":
        # Dry-run stand-in for kernels/flash_attention.py: identical
        # q/k/v/o HBM boundary traffic, zero S x S intermediates.  Used to
        # measure what the Pallas kernel saves (EXPERIMENTS.md §Perf).
        g = h // kv
        km = jnp.repeat(k.mean(axis=1, keepdims=True), g, axis=2)
        vm = jnp.repeat(v.mean(axis=1, keepdims=True), g, axis=2)
        out = q * km + vm
    else:
        # Compile-once front door (repro.api.attention): the program for
        # this cfg resolves impl/mask/chunking a single time and is
        # memoized; inside this traced scan body it inlines, so the
        # lowered HLO matches the direct flash_attention call.
        prog = attention_program_for(cfg, causal=causal, dtype=q.dtype)
        out = prog.apply(q, k.astype(q.dtype), v.astype(q.dtype))
    return out.reshape(b, s, h * hd) @ p["wo"], (k, v)


def apply_attn_decode(x, p, cfg, *, cache, layer_pos):
    """x: (B,1,d). cache dict: k,v (B,Sc,KV,hd), slot_pos (Sc,), pos scalar."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, kv, hd)
    v = (x @ p["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    if cfg.rope_theta:
        cos, sin = L.rope_cos_sin(layer_pos[None, None], hd, cfg.rope_theta)
        q = L.apply_rope(q, jnp.broadcast_to(cos, (b, 1, hd // 2)),
                         jnp.broadcast_to(sin, (b, 1, hd // 2)))
        k = L.apply_rope(k, jnp.broadcast_to(cos, (b, 1, hd // 2)),
                         jnp.broadcast_to(sin, (b, 1, hd // 2)))
    kc, vc = attn.cache_update(cache["k"], cache["v"], k, v, layer_pos,
                               window=cfg.swa_window)
    slot_pos = attn.rolling_slot_pos(cache["slot_pos"], layer_pos, 1,
                                     kc.shape[1])
    out = attn.decode_attention(q, kc, vc, layer_pos + 1,
                                slot_pos=slot_pos, window=cfg.swa_window)
    y = out.reshape(b, 1, h * hd) @ p["wo"]
    return y, {"k": kc, "v": vc, "slot_pos": slot_pos}


def attn_cache_defs(cfg, batch: int, cache_len: int):
    kv, hd = cfg.kv_heads, cfg.head_dim
    sc = min(cache_len, cfg.swa_window) if cfg.swa_window else cache_len
    kv_pspec = _cache_pspec(cfg, batch, sc)
    return {
        "k": ParamDef((batch, sc, kv, hd), kv_pspec, "zeros"),
        "v": ParamDef((batch, sc, kv, hd), kv_pspec, "zeros"),
        "slot_pos": ParamDef((sc,), P(), "zeros", dtype=jnp.int32),
    }


def _cache_pspec(cfg, batch: int, seq: int) -> P:
    """KV-cache sharding over BOTH mesh axes (the cache is the dominant
    decode-state tensor; leaving 'model' unused was caught by the dry-run's
    memory analysis — 21.5 GB/device for qwen3-14b decode_32k):

      batch dim  -> DP axes when divisible, else the cache seq dim -> 'data'
                    (sequence-parallel decode; long_500k's B=1 case);
      kv-heads   -> 'model' when divisible (head-parallel attention), else
      head_dim   -> 'model' (always 16-divisible in the assigned pool;
                    scores need a psum over 'model' — see DESIGN.md §5).
    """
    mm = max(1, cfg.mesh_model)
    if cfg.kv_heads % mm == 0 and cfg.kv_heads >= mm:
        model_dims = (None, "model", None)
    elif cfg.head_dim % mm == 0:
        model_dims = (None, None, "model")
    else:
        model_dims = (None, None, None)
    if batch % max(1, cfg.mesh_dp) == 0 and batch >= cfg.mesh_dp > 1:
        return P(cfg.dp_axes, *model_dims)
    if cfg.mesh_dp > 1 and seq % cfg.mesh_dp == 0:
        return P(None, "data", *model_dims[1:])
    return P(None, *model_dims)


# -------------------------------------------------------------------- blocks --
def block_defs(cfg):
    """Per-layer parameter defs for one block of cfg.family."""
    fam = cfg.family
    if fam in ("dense", "encoder", "vlm"):
        return {
            "ln1": L.norm_defs(cfg.d_model, cfg.norm),
            "attn": attn_defs(cfg),
            "ln2": L.norm_defs(cfg.d_model, cfg.norm),
            "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act),
        }
    if fam == "moe":
        mdefs, _ = moe_mod.moe_defs(cfg.d_model, cfg.d_ff, cfg.n_experts,
                                    act=cfg.act)
        return {
            "ln1": L.norm_defs(cfg.d_model, cfg.norm),
            "attn": attn_defs(cfg),
            "ln2": L.norm_defs(cfg.d_model, cfg.norm),
            "moe": mdefs,
        }
    if fam in ("ssm", "hybrid"):
        return {
            "ln1": L.norm_defs(cfg.d_model, cfg.norm),
            "ssm": ssm_mod.ssm_defs(cfg.d_model, cfg.ssm_inner,
                                    cfg.ssm_heads, cfg.ssm_state,
                                    cfg.ssm_groups),
        }
    raise ValueError(fam)


def shared_attn_defs(cfg):
    """zamba2: one shared attention+MLP block reused every attn_every layers."""
    return {
        "ln1": L.norm_defs(cfg.d_model, cfg.norm),
        "attn": attn_defs(cfg),
        "ln2": L.norm_defs(cfg.d_model, cfg.norm),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act),
    }


def _dp(cfg):
    return cfg.dp_axes


def apply_block(x, bp, cfg, *, positions, aux):
    fam = cfg.family
    x = L.shard(x, _dp(cfg), None, None)
    if fam in ("dense", "encoder", "vlm", "moe"):
        h, _ = apply_attn(L.apply_norm(x, bp["ln1"], cfg.norm), bp["attn"],
                          cfg, positions=positions,
                          causal=fam != "encoder")
        x = x + h
        if fam == "moe":
            y, aux_l = moe_mod.apply_moe_ep(
                L.apply_norm(x, bp["ln2"], cfg.norm), bp["moe"],
                n_experts=cfg.n_experts, n_padded=cfg.n_experts_padded,
                top_k=cfg.top_k, act=cfg.act,
                capacity_factor=cfg.moe_capacity, dp_axes=_dp(cfg))
            aux = aux + aux_l
        else:
            y = L.apply_mlp(L.apply_norm(x, bp["ln2"], cfg.norm), bp["mlp"],
                            cfg.act)
        return x + y, aux
    # ssm / hybrid mamba block
    y = ssm_mod.apply_ssm(L.apply_norm(x, bp["ln1"], cfg.norm), bp["ssm"],
                          cfg, chunk=cfg.ssm_chunk)
    return x + y, aux


# ------------------------------------------------------------- full models --
def param_defs(cfg):
    defs: dict[str, Any] = {"blocks": map_stacked(block_defs(cfg),
                                                  cfg.n_layers)}
    if cfg.family == "encoder":
        defs["embed_in"] = {}  # frames arrive pre-embedded (modality stub)
        defs["mask_embed"] = ParamDef((cfg.d_model,), P(), "normal", 1.0)
        defs["head"] = ParamDef((cfg.vocab, cfg.d_model), P(None, "model"))
    else:
        defs["embed"] = L.embed_defs(cfg.vocab, cfg.d_model)
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((cfg.vocab, cfg.d_model),
                                    P(None, "model"))
    if cfg.family == "hybrid":
        defs["shared_attn"] = shared_attn_defs(cfg)
    if cfg.family == "vlm":
        defs["patch_proj"] = ParamDef((cfg.vlm_patch_dim, cfg.d_model),
                                      P(None, "model"))
    defs["ln_f"] = L.norm_defs(cfg.d_model, cfg.norm)
    if cfg.sharding == "fsdp":
        from repro.models.params import fsdp_transform
        total = max(1, cfg.mesh_dp) * max(1, cfg.mesh_model)
        defs = fsdp_transform(defs, cfg.dp_axes, total)
    return defs


def _scan_blocks(x, params, cfg, *, positions, collect_cache=False):
    """lax.scan over stacked blocks; hybrid applies the shared block inside."""
    shared = params.get("shared_attn")
    remat = cfg.remat

    def body(carry, bp_and_idx):
        x, aux = carry
        bp, idx = bp_and_idx

        def inner(x, aux, bp):
            if cfg.family == "hybrid" and shared is not None:
                def with_shared(x):
                    h, _ = apply_attn(
                        L.apply_norm(x, shared["ln1"], cfg.norm),
                        shared["attn"], cfg, positions=positions)
                    x = x + h
                    return x + L.apply_mlp(
                        L.apply_norm(x, shared["ln2"], cfg.norm),
                        shared["mlp"], cfg.act)
                x = jax.lax.cond(idx % cfg.attn_every == 0, with_shared,
                                 lambda x: x, x)
            return apply_block(x, bp, cfg, positions=positions, aux=aux)

        if remat:
            inner = jax.checkpoint(inner,
                                   policy=jax.checkpoint_policies.nothing_saveable)
        x, aux = inner(x, aux, bp)
        return (x, aux), ()

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["blocks"], jnp.arange(cfg.n_layers)))
    return x, aux


def forward_hidden(cfg, params, batch):
    """Embed + blocks + final norm -> hidden (B, S, d), aux loss."""
    if cfg.family == "encoder":
        x = batch["frames"].astype(cfg.activ_dtype)
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_embed"].astype(x.dtype), x)
    else:
        x = L.embed_lookup(batch["tokens"], params["embed"]["table"])
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
            x = jnp.concatenate([patches, x], axis=1)
    x = L.shard(x.astype(cfg.activ_dtype), _dp(cfg), None, None)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                 (x.shape[0], x.shape[1]))
    x, aux = _scan_blocks(x, params, cfg, positions=positions)
    x = L.apply_norm(x, params["ln_f"], cfg.norm)
    if cfg.family == "vlm":
        x = x[:, batch["patches"].shape[1]:]
    return x, aux


def train_loss(cfg, params, batch):
    hidden, aux = forward_hidden(cfg, params, batch)
    if cfg.family == "encoder":
        table = params["head"]
        mask = batch["mask"].astype(jnp.float32)
    else:
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["head"])
        mask = batch.get("loss_mask")
    loss = L.chunked_ce_loss(
        hidden, table, batch["labels"], mask, chunk=cfg.loss_chunk,
        logit_pspec=(_dp(cfg), None,
                     "model" if cfg.sharding == "tp" else None))
    return loss + cfg.moe_aux_weight * aux


# ----------------------------------------------------------------- serving --
def logits_fn(cfg, params, hidden):
    table = (params["head"] if (cfg.family == "encoder"
                                or not cfg.tie_embeddings)
             else params["embed"]["table"])
    return jnp.einsum("b s d, v d -> b s v", hidden.astype(jnp.float32),
                      table.astype(jnp.float32))


def cache_defs(cfg, batch: int, cache_len: int):
    """Stacked (leading L dim) decode caches per family."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return {"attn": map_stacked(attn_cache_defs(cfg, batch, cache_len),
                                    cfg.n_layers)}
    if fam == "ssm":
        return {"ssm": map_stacked(_ssm_cache_defs(cfg, batch), cfg.n_layers)}
    if fam == "hybrid":
        n_inv = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        return {
            "ssm": map_stacked(_ssm_cache_defs(cfg, batch), cfg.n_layers),
            "shared_attn": map_stacked(
                attn_cache_defs(cfg, batch, cache_len), n_inv),
        }
    raise ValueError(f"{fam} has no decode cache (encoder-only)")


def _ssm_cache_defs(cfg, batch: int):
    b_ax = (cfg.dp_axes if (cfg.mesh_dp > 1 and batch % cfg.mesh_dp == 0
                            and batch >= cfg.mesh_dp) else None)
    return {
        "conv": ParamDef((batch, 4, cfg.ssm_inner), P(b_ax, None, "model"),
                         "zeros"),
        "state": ParamDef((batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), P(b_ax, None, None, None),
                          "zeros", dtype=jnp.float32),
    }


def _shared_attn_decode(x, params, cfg, shared_cache, inv_idx, pos):
    """Apply the zamba2 shared block at dynamic invocation index inv_idx."""
    sp = params["shared_attn"]
    sl = jax.tree.map(lambda c: jax.lax.dynamic_index_in_dim(
        c, inv_idx, axis=0, keepdims=False), shared_cache)
    h, new_sl = apply_attn_decode(L.apply_norm(x, sp["ln1"], cfg.norm),
                                  sp["attn"], cfg, cache=sl, layer_pos=pos)
    x = x + h
    x = x + L.apply_mlp(L.apply_norm(x, sp["ln2"], cfg.norm), sp["mlp"],
                        cfg.act)
    shared_cache = jax.tree.map(
        lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n.astype(c.dtype),
                                                         inv_idx, axis=0),
        shared_cache, new_sl)
    return x, shared_cache


def decode_step(cfg, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 (synchronized
    batch).  Returns (logits (B, 1, V), new cache)."""
    fam = cfg.family
    x = L.embed_lookup(tokens, params["embed"]["table"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = x.astype(cfg.activ_dtype)

    if fam in ("dense", "moe", "vlm"):
        def body(carry, bp_cache):
            x, aux = carry
            bp, sl = bp_cache
            h, new_sl = apply_attn_decode(
                L.apply_norm(x, bp["ln1"], cfg.norm), bp["attn"], cfg,
                cache=sl, layer_pos=pos)
            x = x + h
            if fam == "moe":
                y, aux_l = moe_mod.apply_moe_ep(
                    L.apply_norm(x, bp["ln2"], cfg.norm), bp["moe"],
                    n_experts=cfg.n_experts, n_padded=cfg.n_experts_padded,
                    top_k=cfg.top_k, act=cfg.act,
                    capacity_factor=cfg.moe_capacity, dp_axes=_dp(cfg))
                aux += aux_l
            else:
                y = L.apply_mlp(L.apply_norm(x, bp["ln2"], cfg.norm),
                                bp["mlp"], cfg.act)
            return (x + y, aux), jax.tree.map(
                lambda a, b: b.astype(a.dtype), sl, new_sl)

        (x, _), new_attn = jax.lax.scan(
            body, (x, jnp.float32(0)), (params["blocks"], cache["attn"]))
        new_cache = {"attn": new_attn}

    elif fam in ("ssm", "hybrid"):
        shared_cache = cache.get("shared_attn")

        def body(carry, bp_cache_idx):
            x, shared_cache = carry
            bp, sl, idx = bp_cache_idx
            if fam == "hybrid":
                def with_shared(x, shared_cache):
                    return _shared_attn_decode(x, params, cfg, shared_cache,
                                               idx // cfg.attn_every, pos)
                x, shared_cache = jax.lax.cond(
                    idx % cfg.attn_every == 0, with_shared,
                    lambda x, c: (x, c), x, shared_cache)
            y, conv, state = ssm_mod.ssm_decode(
                L.apply_norm(x, bp["ln1"], cfg.norm), bp["ssm"], cfg,
                sl["conv"], sl["state"])
            new_sl = {"conv": conv.astype(sl["conv"].dtype),
                      "state": state.astype(sl["state"].dtype)}
            return (x + y, shared_cache), new_sl

        (x, shared_cache), new_ssm = jax.lax.scan(
            body, (x, shared_cache),
            (params["blocks"], cache["ssm"], jnp.arange(cfg.n_layers)))
        new_cache = {"ssm": new_ssm}
        if fam == "hybrid":
            new_cache["shared_attn"] = shared_cache
    else:
        raise ValueError(fam)

    x = L.apply_norm(x, params["ln_f"], cfg.norm)
    return logits_fn(cfg, params, x), new_cache


def prefill(cfg, params, batch, cache_len: int):
    """Process a full prompt, returning (last-token logits, decode cache)."""
    fam = cfg.family
    if fam == "encoder":
        hidden, _ = forward_hidden(cfg, params, batch)
        return logits_fn(cfg, params, hidden[:, -1:]), {}
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_lookup(tokens, params["embed"]["table"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if fam == "vlm":
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        s = x.shape[1]
    x = L.shard(x.astype(cfg.activ_dtype), _dp(cfg), None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    if fam in ("dense", "moe", "vlm"):
        def body(carry, bp):
            x, aux = carry
            h, (k, v) = apply_attn(L.apply_norm(x, bp["ln1"], cfg.norm),
                                   bp["attn"], cfg, positions=positions)
            x = x + h
            if fam == "moe":
                y, aux_l = moe_mod.apply_moe_ep(
                    L.apply_norm(x, bp["ln2"], cfg.norm), bp["moe"],
                    n_experts=cfg.n_experts, n_padded=cfg.n_experts_padded,
                    top_k=cfg.top_k, act=cfg.act,
                    capacity_factor=cfg.moe_capacity, dp_axes=_dp(cfg))
                aux += aux_l
            else:
                y = L.apply_mlp(L.apply_norm(x, bp["ln2"], cfg.norm),
                                bp["mlp"], cfg.act)
            return (x + y, aux), _to_cache(cfg, k, v, s, cache_len)

        (x, _), attn_cache = jax.lax.scan(body, (x, jnp.float32(0)),
                                          params["blocks"])
        cache = {"attn": attn_cache}

    elif fam in ("ssm", "hybrid"):
        shared_cache = None
        if fam == "hybrid":
            n_inv = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
            shared_defs = map_stacked(
                attn_cache_defs(cfg, b, cache_len), n_inv)
            shared_cache = jax.tree.map(
                lambda d: jnp.zeros(d.shape, d.dtype or cfg.activ_dtype),
                shared_defs, is_leaf=lambda q: isinstance(q, ParamDef))

        def body(carry, bp_idx):
            x, shared_cache = carry
            bp, idx = bp_idx
            if fam == "hybrid":
                def with_shared(x, shared_cache):
                    sp = params["shared_attn"]
                    h, (k, v) = apply_attn(
                        L.apply_norm(x, sp["ln1"], cfg.norm), sp["attn"],
                        cfg, positions=positions)
                    x = x + h
                    x = x + L.apply_mlp(L.apply_norm(x, sp["ln2"], cfg.norm),
                                        sp["mlp"], cfg.act)
                    new_sl = _to_cache(cfg, k, v, s, cache_len)
                    j = idx // cfg.attn_every
                    shared_cache = jax.tree.map(
                        lambda c, n: jax.lax.dynamic_update_index_in_dim(
                            c, n.astype(c.dtype), j, axis=0),
                        shared_cache, new_sl)
                    return x, shared_cache
                x, shared_cache = jax.lax.cond(
                    idx % cfg.attn_every == 0, with_shared,
                    lambda x, c: (x, c), x, shared_cache)
            y, conv, state = ssm_mod.apply_ssm_with_state(
                L.apply_norm(x, bp["ln1"], cfg.norm), bp["ssm"], cfg,
                chunk=cfg.ssm_chunk)
            return (x + y, shared_cache), {
                "conv": conv.astype(cfg.activ_dtype),
                "state": state.astype(jnp.float32)}

        (x, shared_cache), ssm_cache = jax.lax.scan(
            body, (x, shared_cache if fam == "hybrid" else None),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        cache = {"ssm": ssm_cache}
        if fam == "hybrid":
            cache["shared_attn"] = shared_cache
    else:
        raise ValueError(fam)

    x = L.apply_norm(x, params["ln_f"], cfg.norm)
    return logits_fn(cfg, params, x[:, -1:]), cache


def _to_cache(cfg, k, v, s: int, cache_len: int):
    """Pack prefill (B,S,KV,hd) k/v into a (B,Sc,KV,hd) cache + slot map."""
    w = cfg.swa_window
    sc = min(cache_len, w) if w else cache_len
    b, _, kv, hd = k.shape
    if w and s > sc:                      # rolling window: keep last sc
        keep_pos = jnp.arange(s - sc, s)
        slots = keep_pos % sc
        kc = jnp.zeros((b, sc, kv, hd), k.dtype).at[:, slots].set(
            k[:, s - sc:])
        vc = jnp.zeros((b, sc, kv, hd), v.dtype).at[:, slots].set(
            v[:, s - sc:])
        slot_pos = jnp.zeros((sc,), jnp.int32).at[slots].set(keep_pos)
    else:
        kc = jnp.zeros((b, sc, kv, hd), k.dtype).at[:, :s].set(k)
        vc = jnp.zeros((b, sc, kv, hd), v.dtype).at[:, :s].set(v)
        slot_pos = jnp.concatenate(
            [jnp.arange(s, dtype=jnp.int32),
             jnp.full((sc - s,), -1, jnp.int32)]) if sc > s else \
            jnp.arange(sc, dtype=jnp.int32)
    return {"k": kc, "v": vc, "slot_pos": slot_pos}
