"""Minimal pytree parameter system (no flax/optax in this container).

A model's parameters are a nested dict of ``ParamDef`` leaves; the same tree
yields (a) ShapeDtypeStructs for the dry-run, (b) NamedShardings for pjit
in_shardings, and (c) real initialized arrays for smoke tests / examples.

Sharding convention (mesh axes: optional 'pod', 'data', 'model'):
  * weights carry only 'model' in their PartitionSpec (tensor parallel);
    replication over 'pod'/'data' makes XLA insert the gradient all-reduce
    over those axes automatically in the backward pass;
  * optimizer moments additionally shard a divisible dim over 'data'
    (ZeRO-style) — see train/optimizer.zero_pspec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    pspec: P = P()
    init: str = "normal"        # normal | zeros | ones
    scale: float | None = None  # default: 1/sqrt(fan_in)
    dtype: Any = None           # overrides the tree-level default when set

    def fan_in(self) -> int:
        return int(self.shape[-2]) if len(self.shape) >= 2 else int(self.shape[-1])


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_abstract(tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        tree, is_leaf=is_def)


def tree_pspecs(tree):
    return jax.tree.map(lambda d: d.pspec, tree, is_leaf=is_def)


def tree_shardings(tree, mesh: Mesh):
    return jax.tree.map(lambda d: NamedSharding(mesh, d.pspec), tree,
                        is_leaf=is_def)


def tree_init(tree, key, dtype=jnp.float32):
    """Initialize real arrays. Deterministic per-leaf keys via tree paths."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_def)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = []
    for k, d in zip(keys, leaves):
        dt = d.dtype or dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            scale = d.scale if d.scale is not None else 1.0 / np.sqrt(d.fan_in())
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def tree_bytes(tree, bytes_per_el: int = 4) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) * bytes_per_el for d in leaves)


def tree_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def stacked(defn: ParamDef, n: int) -> ParamDef:
    """Stack a per-layer ParamDef for scan-over-layers (leading dim L)."""
    return ParamDef((n,) + tuple(defn.shape), P(*((None,) + tuple(defn.pspec))),
                    defn.init, defn.scale, defn.dtype)


def map_stacked(tree, n: int):
    return jax.tree.map(lambda d: stacked(d, n), tree, is_leaf=is_def)


def fsdp_transform(tree, axes: tuple, total: int):
    """Re-shard every ParamDef for FSDP: the largest dim divisible by the
    full device count is sharded over ALL mesh axes; everything else is
    replicated (gathered on use — XLA inserts the per-layer all-gathers).
    Activation-level TP constraints become no-ops (mesh_model hint = 1)."""
    def one(d: ParamDef) -> ParamDef:
        best = None
        for i, dim in enumerate(d.shape):
            if dim % total == 0 and dim >= total:
                if best is None or dim > d.shape[best]:
                    best = i
        spec = [None] * len(d.shape)
        if best is not None:
            spec[best] = axes
        return ParamDef(d.shape, P(*spec), d.init, d.scale, d.dtype)
    return jax.tree.map(one, tree, is_leaf=is_def)
