"""Mamba2 / SSD (state-space duality) mixer — chunked scan + O(1) decode.

This is the architecture family where the paper's contribution maps most
directly (DESIGN.md §4): the SSD recurrence ``h_{s+1} = exp(dt·A)·h_s +
dt·B x_s`` streamed over the sequence axis *is* a 1-D stencil in time, and
the chunked SSD algorithm below is temporal blocking — each chunk of
``chunk`` sequence steps is processed per pass with the inter-chunk state
carried like the multi-queue carries planes:

  * intra-chunk term: dense (quadratic-in-chunk) attention-like product —
    the paper's "fused steps inside the tile";
  * inter-chunk term: one sequential scan over chunk states — the paper's
    streaming queue, one "sync" (scan step) per chunk instead of per token
    (lazy streaming, §4.3.2).

Decode keeps the (h, n, p) state resident across steps — device tiling ≙
state residency (one-tile-at-a-time with the tile = the SSM state).

Simplifications vs the reference CUDA implementation (recorded in DESIGN.md):
the causal conv runs on x only (not xBC), and B/C groups are expanded to
heads before the einsums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import rms_norm
from repro.models.params import ParamDef


def ssm_defs(d_model: int, d_inner: int, n_heads: int, d_state: int,
             n_groups: int, d_conv: int = 4):
    return {
        "wz": ParamDef((d_model, d_inner), P(None, "model")),
        "wx": ParamDef((d_model, d_inner), P(None, "model")),
        "wB": ParamDef((d_model, n_groups * d_state), P()),
        "wC": ParamDef((d_model, n_groups * d_state), P()),
        "wdt": ParamDef((d_model, n_heads), P()),
        "conv_w": ParamDef((d_conv, d_inner), P(None, "model"),
                           "normal", scale=0.5),
        "A_log": ParamDef((n_heads,), P(), "zeros"),
        "D": ParamDef((n_heads,), P(), "ones"),
        "dt_bias": ParamDef((n_heads,), P(), "zeros"),
        "norm": ParamDef((d_inner,), P(), "ones"),
        "out_proj": ParamDef((d_inner, d_model), P("model", None)),
    }


def _causal_conv(x, w):
    """Depthwise causal conv over seq. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out


def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) log-decay matrix: sum_{j<i<=q} dA_i."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., q_i, q_j)
    mask = jnp.arange(q)[:, None] >= jnp.arange(q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 128):
    """Chunked SSD. x:(b,s,h,p) dt:(b,s,h) A:(h,) B,C:(b,s,h,n) D:(h,).

    Returns y:(b,s,h,p) and the final state (b,h,n,p).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk

    xr = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtr = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Br = B.reshape(b, nc, chunk, h, n).astype(jnp.float32)
    Cr = C.reshape(b, nc, chunk, h, n).astype(jnp.float32)

    dA = dtr * A[None, None, None, :]                    # (b,nc,q,h) ≤ 0
    dA_h = dA.transpose(0, 1, 3, 2)                      # (b,nc,h,q)
    cs = jnp.cumsum(dA_h, axis=-1)

    # intra-chunk (the "fused steps inside the tile"):
    L = jnp.exp(_segsum(dA_h))                           # (b,nc,h,q,k)
    xdt = xr * dtr[..., None]                            # (b,nc,k,h,p)
    y_intra = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", Cr, Br, L, xdt)

    # per-chunk end states: sum_k exp(cs_end - cs_k) dt_k B_k ⊗ x_k
    decay_to_end = jnp.exp(cs[..., -1:] - cs)            # (b,nc,h,q)
    states = jnp.einsum("bchk,bckhn,bckhp->bchnp",
                        decay_to_end, Br, xdt)

    # inter-chunk scan (the streaming queue; one step per chunk):
    chunk_decay = jnp.exp(cs[..., -1])                   # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                # emit state *before*

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,nc,h,n,p)

    in_decay = jnp.exp(cs).transpose(0, 1, 3, 2)         # (b,nc,q,h)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Cr, prev_states, in_decay)

    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    y = y + x[:, :s].astype(jnp.float32) * D[None, None, :, None]
    return y, final


def ssd_decode_step(state, x, dt, A, B, C, D):
    """One-token SSD update. state:(b,h,n,p) x:(b,h,p) dt:(b,h) B,C:(b,h,n)."""
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dt32 * A[None, :])                      # (b,h)
    inc = jnp.einsum("bhn,bhp->bhnp", B.astype(jnp.float32) * dt32[..., None],
                     x32)
    state = state * dA[..., None, None] + inc
    y = jnp.einsum("bhn,bhnp->bhp", C.astype(jnp.float32), state)
    return y + x32 * D[None, :, None], state


def apply_ssm(x, p, cfg, *, chunk: int = 128):
    """Full mamba2 mixer on (B, S, d_model) -> (B, S, d_model)."""
    h, hd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = x @ p["wz"]
    xs = _causal_conv(x @ p["wx"], p["conv_w"])
    xs = jax.nn.silu(xs)
    b, s, _ = x.shape
    if getattr(cfg, "ssm_impl", "chunked_jnp") == "boundary_stub":
        # dry-run stand-in for a fused SSD kernel: identical input/output
        # boundary traffic (x in, y out, all projections alive), none of the
        # chunked scan's intermediate state round-trips (see DESIGN.md §8.9)
        small = ((x @ p["wB"]).mean() + (x @ p["wC"]).mean()
                 + (x @ p["wdt"]).mean()) * 1e-30
        y = rms_norm(xs * jax.nn.silu(z) + small, p["norm"])
        return y @ p["out_proj"]
    B = (x @ p["wB"]).reshape(b, s, g, n)
    C = (x @ p["wC"]).reshape(b, s, g, n)
    hpg = h // g
    B = jnp.repeat(B, hpg, axis=2)
    C = jnp.repeat(C, hpg, axis=2)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xs.reshape(b, s, h, hd), dt, A, B, C,
                       p["D"].astype(jnp.float32), chunk=chunk)
    y = y.reshape(b, s, h * hd).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


def apply_ssm_with_state(x, p, cfg, *, chunk: int = 128):
    """Like apply_ssm but also returns (conv_tail, final_ssm_state) so a
    prefill can hand off to O(1) decode."""
    h, hd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z = x @ p["wz"]
    xin = x @ p["wx"]
    xs = jax.nn.silu(_causal_conv(xin, p["conv_w"]))
    b, s, _ = x.shape
    k = p["conv_w"].shape[0]
    tail = xin[:, -k:] if s >= k else jnp.pad(xin, ((0, 0), (k - s, 0), (0, 0)))
    if getattr(cfg, "ssm_impl", "chunked_jnp") == "boundary_stub":
        small = ((x @ p["wB"]).mean() + (x @ p["wC"]).mean()
                 + (x @ p["wdt"]).mean()) * 1e-30
        y = rms_norm(xs * jax.nn.silu(z) + small, p["norm"])
        state = jnp.zeros((b, h, n, hd), jnp.float32)
        return y @ p["out_proj"], tail, state
    B = (x @ p["wB"]).reshape(b, s, g, n)
    C = (x @ p["wC"]).reshape(b, s, g, n)
    hpg = h // g
    B = jnp.repeat(B, hpg, axis=2)
    C = jnp.repeat(C, hpg, axis=2)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final = ssd_chunked(xs.reshape(b, s, h, hd), dt, A, B, C,
                           p["D"].astype(jnp.float32), chunk=chunk)
    y = y.reshape(b, s, h * hd).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], tail, final


def ssm_decode(x, p, cfg, conv_state, ssm_state):
    """Single-token mixer. x: (B, 1, d). Carries (conv_state, ssm_state)."""
    h, hd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    b = x.shape[0]
    z = x @ p["wz"]
    xin = (x @ p["wx"])[:, 0]                            # (B, d_inner)
    k = p["conv_w"].shape[0]
    conv_state = jnp.concatenate([conv_state[:, 1:], xin[:, None]], axis=1)
    xs = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_state, p["conv_w"]))
    B = (x @ p["wB"])[:, 0].reshape(b, g, n)
    C = (x @ p["wC"])[:, 0].reshape(b, g, n)
    hpg = h // g
    B = jnp.repeat(B, hpg, axis=1)
    C = jnp.repeat(C, hpg, axis=1)
    dt = jax.nn.softplus((x @ p["wdt"])[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssm_state = ssd_decode_step(ssm_state, xs.reshape(b, h, hd), dt, A,
                                   B, C, p["D"].astype(jnp.float32))
    y = y.reshape(b, 1, h * hd).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], conv_state, ssm_state
