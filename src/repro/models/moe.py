"""Mixture-of-Experts: top-k router + capacity-bucketed expert compute.

Baseline implementation is pjit-level: tokens are sorted into per-expert
capacity buckets with static-shape scatter/gather, expert weights are sharded
over the 'model' axis, and XLA's SPMD partitioner inserts the dispatch
collectives.  An explicit two-hop all_to_all shard_map variant is the §Perf
hillclimb for the collective-bound MoE cells (see EXPERIMENTS.md).

Experts are padded to a multiple of the model-axis size (e.g. granite's 40
experts → 48 slots) — phantom experts get -inf router logits, so they receive
no tokens and contribute nothing; the padding cost is visible in the roofline
(documented waste, a hillclimb lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.distributed import shard_map_compat

from repro.models.layers import shard
from repro.models.params import ParamDef


def moe_defs(d_model: int, d_ff: int, n_experts: int, pad_to: int = 16,
             act: str = "swiglu"):
    e = ((n_experts + pad_to - 1) // pad_to) * pad_to
    defs = {
        "router": ParamDef((d_model, e), P()),  # small, replicated
        # 2D-sharded expert weights (ZeRO-3 style): experts over 'model',
        # the d/f dim over 'data'; gathered per layer inside the EP shard.
        # (1D sharding left 27 GB/device of expert params for the 235B MoE —
        # caught by the dry-run memory analysis, §Perf iteration 6.)
        "w_up": ParamDef((e, d_model, d_ff), P("model", "data", None)),
        "w_down": ParamDef((e, d_ff, d_model), P("model", "data", None)),
    }
    if act in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((e, d_model, d_ff),
                                  P("model", "data", None))
    return defs, e


def apply_moe(x, p, *, n_experts: int, n_padded: int, top_k: int,
              act: str = "swiglu", capacity_factor: float = 1.25,
              min_capacity: int = 4, dp_axes=("data",)):
    """x: (B, S, d) -> (B, S, d).

    Static-shape dispatch: (token, k) slots are bucketed per expert with a
    rank-within-expert cumsum; slots beyond capacity are dropped (standard
    Switch-style capacity truncation).
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if n_padded > n_experts:                       # mask phantom experts
        pad_mask = jnp.arange(n_padded) >= n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    gates, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = max(min_capacity, int(capacity_factor * t * top_k / n_experts))
    cap = (cap + 255) // 256 * 256 if cap > 256 else cap  # DP-shardable
    # rank of each (token,k) slot within its expert, computed via one-hot
    # cumulative counts — O(t·k·E) bools, all static shapes.
    flat_ids = ids.reshape(-1)                                  # (t*k,)
    onehot = jax.nn.one_hot(flat_ids, n_padded, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - onehot                  # before me
    my_rank = jnp.take_along_axis(rank, flat_ids[:, None], axis=1)[:, 0]
    keep = my_rank < cap

    # scatter tokens into (E, cap, d) buckets
    buckets = jnp.zeros((n_padded, cap, d), x.dtype)
    src = jnp.repeat(xt, top_k, axis=0)                         # (t*k, d)
    e_idx = jnp.where(keep, flat_ids, 0)
    c_idx = jnp.where(keep, my_rank, cap - 1)
    src = jnp.where(keep[:, None], src, 0)
    buckets = buckets.at[e_idx, c_idx].add(src, mode="drop")
    # experts over 'model', capacity over the DP axes: without the capacity
    # shard, every data replica computed ALL capacity slots (caught by the
    # dry-run roofline: useful_flops_ratio 0.04 for granite train_4k)
    buckets = shard(buckets, "model", dp_axes, None)

    # expert FFN: (E, cap, d) x (E, d, f) -> (E, cap, f) -> (E, cap, d)
    up = jnp.einsum("ecd,edf->ecf", buckets, p["w_up"])
    if act in ("swiglu", "geglu"):
        gate_act = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        up = gate_act(jnp.einsum("ecd,edf->ecf", buckets, p["w_gate"])) * up
    else:
        up = jax.nn.silu(up)
    out_b = jnp.einsum("ecf,efd->ecd", up, p["w_down"])
    out_b = shard(out_b, "model", dp_axes, None)

    # gather back to (t*k, d), weight by gate, sum over k
    back = out_b[e_idx, c_idx]
    back = jnp.where(keep[:, None], back, 0)
    y = (back.reshape(t, top_k, d).astype(jnp.float32)
         * gates[..., None]).sum(axis=1)
    y = shard(y.reshape(b, s, d).astype(x.dtype), dp_axes, None, None)
    return y, _aux_loss(logits[:, :n_experts], ids, n_experts, top_k)


def _aux_loss(logits, ids, n_experts, top_k):
    """Switch-style load-balance auxiliary loss."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(ids, n_experts).sum(axis=1) > 0).astype(jnp.float32),
        axis=0)
    frac_probs = probs.mean(axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)


# ----------------------------------------------------- shard_map EP path ---
def _current_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # noqa: BLE001
        return None


def apply_moe_ep(x, p, *, n_experts: int, n_padded: int, top_k: int,
                 act: str = "swiglu", capacity_factor: float = 1.25,
                 min_capacity: int = 4, dp_axes=("data",), mesh=None):
    """Expert-parallel MoE via shard_map — the §Perf hillclimb for the
    collective-bound MoE cells.

    Key observation: activations are *replicated* over the 'model' axis
    (tensor-parallel layers psum back to replicated d_model), so every
    expert owner already holds every token of its data shard.  Dispatch is
    therefore purely LOCAL — each model column buckets tokens for its own
    E/model_size experts — and the only collective is one psum of the
    (tokens, d_model) output over 'model', identical in shape to a
    row-parallel matmul's reduction.  No all_to_all, no cross-shard scatter
    (the pjit-level scatter was measured at 240 s of collective time for
    granite train_4k; see EXPERIMENTS.md §Perf iteration 2).
    """
    mesh = mesh or _current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1 or n_padded % mesh.shape["model"]:
        return apply_moe(x, p, n_experts=n_experts, n_padded=n_padded,
                         top_k=top_k, act=act,
                         capacity_factor=capacity_factor,
                         min_capacity=min_capacity, dp_axes=dp_axes)
    mm = mesh.shape["model"]
    e_loc = n_padded // mm
    dp = tuple(a for a in (dp_axes if isinstance(dp_axes, tuple)
                           else (dp_axes,)) if a and a in mesh.axis_names)
    dp = dp if dp else None

    has_gate = "w_gate" in p

    def shard_fn(x, router, w_up, w_down, *maybe_gate):
        w_gate = maybe_gate[0] if maybe_gate else None
        if dp and "data" in dp:
            # ZeRO-3 gather of this layer's local experts (bwd: XLA turns
            # the transpose into a reduce-scatter of the expert grads)
            w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, "data", axis=1, tiled=True)
            if w_gate is not None:
                w_gate = jax.lax.all_gather(w_gate, "data", axis=1,
                                            tiled=True)
        b, s, d = x.shape
        t = b * s
        xt = x.reshape(t, d)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        if n_padded > n_experts:
            pad_mask = jnp.arange(n_padded) >= n_experts
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        gates, ids = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        e0 = jax.lax.axis_index("model") * e_loc
        flat_ids = ids.reshape(-1)
        local = (flat_ids >= e0) & (flat_ids < e0 + e_loc)
        lids = jnp.where(local, flat_ids - e0, 0)

        cap = max(min_capacity, int(capacity_factor * t * top_k / n_experts))
        onehot = jax.nn.one_hot(lids, e_loc, dtype=jnp.int32) \
            * local[:, None].astype(jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - onehot
        my_rank = jnp.take_along_axis(rank, lids[:, None], axis=1)[:, 0]
        keep = local & (my_rank < cap)

        src = jnp.repeat(xt, top_k, axis=0)
        src = jnp.where(keep[:, None], src, 0)
        e_idx = jnp.where(keep, lids, 0)
        c_idx = jnp.where(keep, my_rank, cap - 1)
        buckets = jnp.zeros((e_loc, cap, d), x.dtype)
        buckets = buckets.at[e_idx, c_idx].add(src, mode="drop")

        up = jnp.einsum("ecd,edf->ecf", buckets, w_up)
        if w_gate is not None:
            gact = jax.nn.silu if act == "swiglu" else jax.nn.gelu
            up = gact(jnp.einsum("ecd,edf->ecf", buckets, w_gate)) * up
        else:
            up = jax.nn.silu(up)
        out_b = jnp.einsum("ecf,efd->ecd", up, w_down)

        back = out_b[e_idx, c_idx]
        back = jnp.where(keep[:, None], back, 0)
        y = (back.reshape(t, top_k, d).astype(jnp.float32)
             * gates[..., None]).sum(axis=1)
        y = jax.lax.psum(y, "model")           # the ONE collective
        aux = _aux_loss(logits[:, :n_experts], ids, n_experts, top_k)
        if dp:                                  # mean over data shards
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(b, s, d).astype(x.dtype), aux

    wspec = P("model", "data" if (dp and "data" in dp) else None, None)
    in_specs = [P(dp, None, None), P(), wspec, wspec]
    args = [x, p["router"], p["w_up"], p["w_down"]]
    if has_gate:
        in_specs.append(wspec)
        args.append(p["w_gate"])
    fn = shard_map_compat(shard_fn, mesh, in_specs=tuple(in_specs),
                          out_specs=(P(dp, None, None), P()))
    return fn(*args)
