"""Attention: chunked (online-softmax) prefill/train path + cached decode.

The chunked path is the EBISU execution discipline applied to attention: a
query tile stays resident while K/V stream through it, with online softmax —
one pass over memory regardless of sequence length, bounded working set
(the "one tile at a time, stream the rest" principle of §4.1/§4.3.2).

Supports GQA/MQA (kv_heads ≤ heads), causal or bidirectional masks, sliding
windows (SWA), and an optional q/k RMS-norm (qwen3-style), all under one
implementation so every assigned architecture shares this code path.

These functions are the *implementation primitives* behind the compile-once
front door in ``repro.api.attention``: ``dense_attention`` is the oracle
(the semantics every other path is tested against), ``flash_attention`` is
the chunked impl, and the Pallas kernel lives in
``kernels/flash_attention.py``.  Model/serving code dispatches through
``compile_attention(...) -> AttentionProgram`` rather than calling these
directly; ``decode_attention``/``cache_update`` remain the single-token
cached-decode path (dynamic cache lengths don't fit a static program
signature).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, *, causal: bool, window: int | None):
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > qpos[:, None] - window
    return ok


def dense_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """Reference/small-sequence path. q:(B,S,H,hd) k,v:(B,Sk,KV,hd)."""
    b, s, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    q5 = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(sk)
    ok = _mask(qpos, kpos, causal=causal, window=window)
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, q_chunk=512,
                    kv_chunk=1024, q_offset=0):
    """Online-softmax chunked attention; memory O(q_chunk · kv_chunk)."""
    b, s, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    if s % q_chunk or sk % kv_chunk or s <= q_chunk:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    nq, nk = s // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(hd)
    q5 = q.reshape(b, nq, q_chunk, kv, g, hd).astype(jnp.float32)
    k4 = k.reshape(b, nk, kv_chunk, kv, hd).astype(jnp.float32)
    v4 = v.reshape(b, nk, kv_chunk, kv, hd).astype(jnp.float32)

    def q_body(_, q_blk_idx):
        q_blk, iq = q_blk_idx
        qpos = iq * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_body(carry, kv_blk_idx):
            m, l, acc = carry
            k_blk, v_blk, ik = kv_blk_idx
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            # scores: (b, kv, g, qc, kc)
            sc = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk) * scale
            ok = _mask(qpos, kpos, causal=causal, window=window)
            sc = jnp.where(ok[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk)
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (k4.swapaxes(0, 1), v4.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b, kv, g, qc, hd) -> (b, qc, kv, g, hd)
        return (), out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_body, (), (q5.swapaxes(0, 1), jnp.arange(nq)))
    # outs: (nq, b, qc, kv, g, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, slot_pos=None,
                     window=None):
    """Single-token attention over a cache.

    q: (B, 1, H, hd); k/v_cache: (B, S_cache, KV, hd); length: scalar int —
    number of valid cache entries (synchronized batch decode).
    slot_pos: (S_cache,) absolute position of each slot for rolling (SWA)
    caches; default slot i holds position i.
    """
    b, _, h, hd = q.shape
    _, sc, kv, _ = k_cache.shape
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    q4 = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", q4.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(sc) if slot_pos is None else slot_pos
    ok = (pos < length) & (pos >= 0)
    if window is not None:
        ok &= pos > length - 1 - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, window=None):
    """Insert (B, n, KV, hd) new entries at ``pos`` (rolling when windowed).

    Returns (k_cache, v_cache, slot_pos_update_fn) — slot bookkeeping for
    windowed caches is kept by the caller via ``rolling_slot``.
    """
    sc = k_cache.shape[1]
    at = pos % sc if window is not None else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(
        k_cache.dtype), at, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(
        v_cache.dtype), at, axis=1)
    return k_cache, v_cache


def rolling_slot_pos(slot_pos, pos, n, cache_len):
    """Update the absolute-position map for a rolling cache insert."""
    at = pos % cache_len
    return jax.lax.dynamic_update_slice_in_dim(
        slot_pos, pos + jnp.arange(n, dtype=slot_pos.dtype), at, axis=0)
