"""Import shim: the fault injector moved to :mod:`repro.faults`.

PR 7 generalized the serving-only injector into one shared by the
serving front door AND the resumable campaign runner
(``repro.resilient``) — same seeded determinism contract, plus the
campaign fault kinds (NaN-at-leg, corrupt-checkpoint-on-disk,
crash-mid-save, device loss).  Import from ``repro.faults`` going
forward; this module keeps the old names resolving (shim policy in
README.md).
"""
from repro.faults import (CAMPAIGN_KINDS, HEALTHY,  # noqa: F401
                          TRAFFIC_KINDS, FaultConfig, FaultInjector,
                          MonotonicClock, SimClock, TransientFault)

__all__ = [
    "CAMPAIGN_KINDS",
    "FaultConfig",
    "FaultInjector",
    "HEALTHY",
    "MonotonicClock",
    "SimClock",
    "TRAFFIC_KINDS",
    "TransientFault",
]
