"""Seeded fault injection for the stencil serving front door.

A service that only ever sees healthy traffic is untested by
construction, so the request path is validated the other way around:
:class:`FaultInjector` drives every failure mode the service defends
against, from one seeded RNG, with **no wall-clock or unseeded
randomness in results** — the same ``FaultConfig`` always produces the
same fault sequence, so the soak test (``tests/test_serve_soak.py``) is
a deterministic regression test, not a flake generator.

Two kinds of faults:

  * **dispatch faults** the service core consults at its hook points —
    transient errors (:class:`TransientFault` with ``kind='evicted'`` /
    ``'oom'``) that the retry/backoff + degradation ladder must absorb,
    plus injected dispatch delays that push in-flight requests past
    their deadlines.  ``evicted`` really clears the runner cache before
    raising, so the retry exercises the true rebuild path, not a
    simulation of it.
  * **traffic faults** a driver weaves into synthetic load —
    NaN-poisoned inputs, oversized shapes, already-expired deadlines —
    via :meth:`FaultInjector.classify_request`.  These are *requests*,
    not errors: the service must resolve each to a typed error while its
    healthy batch-mates get correct results.

Usage (the CLI driver and the soak test are the two real call sites):

    inj = FaultInjector(FaultConfig(seed=7, evict_rate=0.1,
                                    oom_batch_limit=4))
    core = ServiceCore(config, clock=SimClock(), faults=inj)

This module is backend-free: importing it never touches JAX.
"""
from __future__ import annotations

import dataclasses
import random


class TransientFault(RuntimeError):
    """An injected failure the retry/degradation ladder should absorb.

    ``kind`` ∈ {'evicted', 'oom'}: a program/runner-cache eviction race
    (retryable at the same batch width — the rebuild succeeds) or a
    simulated device OOM on an over-wide batch (retry at the same width
    keeps failing; the ladder must *narrow* the batch instead).
    """

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"injected {kind}" + (f": {detail}" if detail else ""))
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs for :class:`FaultInjector` — all rates are per-event
    probabilities drawn from one RNG seeded with ``seed``.

    Dispatch-side:
      * ``evict_rate`` — before a dispatch, clear ``RUNNER_CACHE`` and
        raise ``TransientFault('evicted')`` once (retry rebuilds).
      * ``oom_batch_limit`` — dispatches wider than this many requests
        raise ``TransientFault('oom')`` *deterministically* (0 disables);
        the ladder must degrade to narrower batches or solo runs.
      * ``delay_ms_range`` — (lo, hi) extra milliseconds a dispatch takes
        (advanced on the service clock), so deadlines can expire while a
        request is in flight.
      * ``nan_output_rate`` — corrupt one output row of a healthy batch
        after compute (tests the guard's batch-mate isolation without a
        poisoned input).

    Traffic-side (consumed by drivers via :meth:`classify_request`):
      * ``nan_input_rate`` — request field arrives NaN-poisoned.
      * ``oversized_rate`` — request shape exceeds the admission cap.
      * ``expired_rate`` — request arrives with an already-spent deadline.
    """

    seed: int = 0
    evict_rate: float = 0.0
    oom_batch_limit: int = 0
    delay_ms_range: tuple = (0, 0)
    nan_output_rate: float = 0.0
    nan_input_rate: float = 0.0
    oversized_rate: float = 0.0
    expired_rate: float = 0.0


HEALTHY = "healthy"
TRAFFIC_KINDS = ("nan_input", "oversized", "expired")


class FaultInjector:
    """The seeded fault source; one instance per service/soak run.

        inj = FaultInjector(FaultConfig(seed=3, evict_rate=0.5))
        inj.should_evict(), inj.should_evict()   # deterministic sequence
    """

    def __init__(self, config: FaultConfig | None = None):
        self.config = config or FaultConfig()
        self._rng = random.Random(self.config.seed)
        self.injected: dict = {"evicted": 0, "oom": 0, "delay_ms": 0,
                               "nan_output": 0, "nan_input": 0,
                               "oversized": 0, "expired": 0}

    # ------------------------------------------------- dispatch hooks ----
    def should_evict(self) -> bool:
        """Roll the eviction-race die (counted when it comes up)."""
        hit = self._rng.random() < self.config.evict_rate
        if hit:
            self.injected["evicted"] += 1
        return hit

    def should_oom(self, batch_width: int) -> bool:
        """True when ``batch_width`` exceeds the configured OOM limit —
        deterministic, so retries at the same width keep failing and the
        ladder is forced to narrow."""
        limit = self.config.oom_batch_limit
        hit = bool(limit) and batch_width > limit
        if hit:
            self.injected["oom"] += 1
        return hit

    def dispatch_delay_ms(self) -> float:
        """Extra service time for this dispatch, in ms (0 when disabled)."""
        lo, hi = self.config.delay_ms_range
        if hi <= 0:
            return 0.0
        d = self._rng.uniform(lo, hi)
        self.injected["delay_ms"] += d
        return d

    def corrupt_output_row(self, batch_width: int) -> int | None:
        """Index of a batch row to NaN-poison post-compute, or None."""
        if self._rng.random() < self.config.nan_output_rate:
            self.injected["nan_output"] += 1
            return self._rng.randrange(batch_width)
        return None

    # -------------------------------------------------- traffic hooks ----
    def classify_request(self) -> str:
        """Draw the kind of the next synthetic request: ``'healthy'`` or
        one of ``TRAFFIC_KINDS`` — drivers shape the request to match."""
        r = self._rng.random()
        cfg = self.config
        edges = (("nan_input", cfg.nan_input_rate),
                 ("oversized", cfg.oversized_rate),
                 ("expired", cfg.expired_rate))
        acc = 0.0
        for kind, rate in edges:
            acc += rate
            if r < acc:
                self.injected[kind] += 1
                return kind
        return HEALTHY

    def stats(self) -> dict:
        """Counters of everything injected so far (reported by drivers so
        a soak's fault mix is visible next to its outcome mix)."""
        out = dict(self.injected)
        out["delay_ms"] = round(out["delay_ms"], 3)
        return out
