"""Stencil-as-a-service: a hardened async batching front door.

Nothing in the repo accepted a *request* before this module: the compile
side ends at :class:`~repro.api.program.StencilProgram`.  ``StencilService``
puts a defense-in-depth request path in front of it, built around the
already-measured batching win — concurrent requests for the same stencil
are coalesced into ONE vmapped ``StencilProgram.run_batched`` dispatch,
the serving analogue of the paper's amortize-everything-over-the-tile
scheme (one program dispatch amortizes launch + plan cost across
requests the way a temporal block amortizes a tile load across steps).

The request path, outside-in (guide: ``docs/serving.md``; contract:
DESIGN.md §13):

  1. **Admission control** — a bounded queue and per-tenant in-flight
     caps; over-limit submissions resolve immediately to a typed
     :class:`Rejected` (``reason='queue_full' | 'tenant_cap' |
     'oversized'``), never an unbounded backlog.  Shape/dtype/steps/
     boundary validation happens HERE, before coalescing, so a
     malformed request can never poison a batch: it resolves alone to
     :class:`InvalidRequest`.
  2. **Coalescing** — admitted requests are grouped by *shape bucket*:
     ``(spec.signature, shape, dtype, boundary, sweep depth, T)``.  A
     bucket dispatches when its oldest request has waited
     ``batch_window_ms`` or ``max_batch`` requests are ready.  The batch
     axis is padded up to the next configured width (powers of two by
     default) so the vmapped runner compiles once per width, not once
     per arrival count; pad rows are discarded.  Spatial shapes are
     grouped *exactly*, never padded: embedding a zero-Dirichlet domain
     in a larger one changes its semantics (the boundary pins cells to
     zero every step; pad cells would evolve and feed back), so the
     service refuses silent corruption and batches only true shape
     twins — the §13.2 decision.
  3. **Deadlines** — ``deadline_ms`` is checked at admission (an
     already-expired request resolves to :class:`Expired` without
     queueing), at batch formation (expired requests are dropped from
     the batch instead of dispatched), and post-dispatch (a result that
     arrives late resolves to ``Expired`` rather than pretending the
     deadline held).
  4. **Dispatch, retry, and the degradation ladder** — transient
     failures (a program-cache eviction race — classified by consuming
     the ``ProgramCache`` eviction counters — or an injected fault)
     retry with exponential backoff + seeded jitter; a failure that
     persists degrades instead of erroring: full bucket batch → split
     halves (narrower widths) → unbatched ``StencilProgram.run`` per
     request → typed :class:`ServiceFault`.  Every rung is bounded;
     there is no path that hangs.
  5. **Poison isolation** — a configurable NaN/Inf output guard
     (``guard='reject' | 'propagate' | 'retry_solo'``) checks each
     request's own output row.  vmap rows are independent, so one
     NaN input never contaminates batch-mates; ``retry_solo``
     additionally re-runs a non-finite row alone to distinguish "my
     input was poison" (:class:`PoisonedOutput`) from "my batch was"
     (solo result returned).

Determinism: the core is **sans-io** — :class:`ServiceCore` is driven by
an injectable clock (:class:`SimClock` for tests/soaks — backoff, batch
windows and injected delays advance simulated time; :class:`MonotonicClock`
for real serving) and all jitter/fault randomness is seeded.  The asyncio
wrapper :class:`StencilService` runs the same core on the real clock with
dispatches on worker threads (hence the thread-safe ``ProgramCache``).

    svc = StencilService(ServiceConfig(max_batch=8))
    await svc.start()
    y = await svc.submit(ServeRequest(spec, x, total_t=16))
    await svc.stop()
    svc.stats()["p99_latency_ms"]

Synchronous/simulated use (the soak test and CLI driver):

    core = ServiceCore(ServiceConfig(), clock=SimClock())
    tk = core.submit(ServeRequest(spec, x, total_t=8))
    core.drain()                  # advances the sim clock past windows
    y = tk.result()               # value, or raises the typed error
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import math
import random
import threading
from collections import Counter

import jax.numpy as jnp

from repro.api.boundary import ZERO, Boundary
from repro.api.program import RUNNER_CACHE, compile_stencil
from repro.core.stencil_spec import StencilSpec
from repro.faults import (FaultInjector, MonotonicClock, SimClock,
                          TransientFault)

GUARDS = ("reject", "propagate", "retry_solo")


# ============================================================ typed errors ==
class ServeError(Exception):
    """Base of every typed request outcome that is not a result.

    Each carries a machine-readable ``reason``; the service resolves
    EVERY admitted request to either a value or exactly one of these —
    an unhandled exception escaping the request path is a bug (the soak
    test's core assertion).
    """

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class Rejected(ServeError):
    """Admission control said no: ``queue_full`` (bounded queue at
    capacity), ``tenant_cap`` (per-tenant in-flight limit), or
    ``oversized`` (domain exceeds ``max_cells``).  Backpressure, not
    failure — the client should shed or retry later."""


class InvalidRequest(ServeError):
    """The request can never succeed as posed (wrong rank, non-floating
    dtype, T out of bounds, boundary incompatible with the spec, ...).
    Resolved before coalescing so it fails alone."""


class Expired(ServeError):
    """The deadline passed; ``stage`` says where it was caught:
    ``admission`` | ``batch_formation`` | ``post_dispatch``."""

    def __init__(self, stage: str):
        super().__init__(f"deadline expired at {stage}")
        self.stage = stage


class PoisonedOutput(ServeError):
    """The request's own output is non-finite under ``guard='reject'``
    or after a ``retry_solo`` re-run confirmed the poison is the
    request's, not the batch's."""


class ServiceFault(ServeError):
    """Dispatch failed after the whole retry/degradation ladder — the
    typed bottom rung, in place of a hang or a raw traceback."""


# ================================================================= request ==
@dataclasses.dataclass
class ServeRequest:
    """One unit of work: run ``spec`` on field ``x`` for ``total_t``
    steps.  ``deadline_ms`` is relative to admission; ``t`` pins the
    sweep depth (default: the program's §6 plan depth)."""

    spec: StencilSpec
    x: object                      # array-like, shape == spec.ndim rank
    total_t: int
    tenant: str = "default"
    boundary: Boundary | None = None
    deadline_ms: float | None = None
    t: int | None = None


_ids = itertools.count()


class Ticket:
    """The resolution handle for one admitted (or admission-refused)
    request: exactly one of ``value``/``error`` is set when ``done``."""

    def __init__(self, request: ServeRequest, admitted_ms: float, on_done=None):
        self.id = next(_ids)
        self.request = request
        self.admitted_ms = admitted_ms
        self.deadline_at = (None if request.deadline_ms is None
                            else admitted_ms + request.deadline_ms)
        self.value = None
        self.error: ServeError | None = None
        self.done = False
        self.latency_ms: float | None = None
        self.batched_width: int | None = None   # how it was dispatched
        self._on_done = on_done

    def result(self):
        """The request's value; raises its typed ``ServeError`` instead
        when the request did not produce one."""
        if not self.done:
            raise RuntimeError(f"ticket {self.id} not resolved yet")
        if self.error is not None:
            raise self.error
        return self.value

    @property
    def ok(self) -> bool:
        return self.done and self.error is None

    def expired(self, now_ms: float) -> bool:
        return self.deadline_at is not None and now_ms > self.deadline_at


# ================================================================== config ==
@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """The service's defense-in-depth knobs (semantics: ``docs/serving.md``).

    ``guard`` is the NaN/Inf output policy; ``batch_widths`` (derived
    when None) are the padded batch sizes the vmapped runner compiles
    for; ``seed`` feeds the backoff jitter RNG (determinism: results
    never depend on wall clock or unseeded randomness)."""

    max_queue: int = 128
    max_inflight_per_tenant: int = 16
    batch_window_ms: float = 2.0
    max_batch: int = 8
    batch_widths: tuple | None = None
    guard: str = "retry_solo"
    max_retries: int = 2
    backoff_base_ms: float = 1.0
    backoff_factor: float = 2.0
    backoff_jitter_ms: float = 0.5
    max_cells: int = 1 << 22
    max_steps: int = 4096
    default_deadline_ms: float | None = None
    interpret: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.guard not in GUARDS:
            raise ValueError(f"guard must be one of {GUARDS}, "
                             f"got {self.guard!r}")
        if self.max_batch < 1 or self.max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")

    def widths(self) -> tuple:
        """Padded batch widths, ascending: powers of two capped at (and
        always including) ``max_batch``."""
        if self.batch_widths is not None:
            return tuple(sorted(set(self.batch_widths)))
        out = {self.max_batch}
        w = 1
        while w < self.max_batch:
            out.add(w)
            w *= 2
        return tuple(sorted(out))


class _Fallthrough(Exception):
    """Internal: this rung of the ladder gave up; try the next one."""


@dataclasses.dataclass
class _Batch:
    program: object
    total_t: int
    tickets: list


# ==================================================================== core ==
class ServiceCore:
    """The sans-io engine: admission, coalescing, dispatch, resolution —
    synchronous, clock-injected, thread-safe.  :class:`StencilService`
    wraps it in asyncio; tests and the CLI drive it directly."""

    def __init__(self, config: ServiceConfig | None = None, *,
                 clock=None, faults: FaultInjector | None = None,
                 compile_fn=compile_stencil):
        self.config = config or ServiceConfig()
        self.clock = clock or MonotonicClock()
        self.faults = faults
        self._compile = compile_fn
        self._jitter = random.Random(self.config.seed)
        self._lock = threading.RLock()
        self._buckets: dict = {}            # key -> list[Ticket]
        self._programs: dict = {}           # key -> (program, total_t)
        self._tenant_inflight: Counter = Counter()
        self.counters: Counter = Counter()
        self._latencies_ms: list = []
        self._first_admit_ms: float | None = None
        self._last_resolve_ms: float | None = None

    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    # --------------------------------------------------------- admission ----
    def submit(self, request: ServeRequest, on_done=None) -> Ticket:
        """Admit (or refuse) one request.  Always returns a ticket; an
        admission refusal resolves it immediately with the typed error,
        so the caller never blocks on a request that was never queued."""
        now = self.clock.now_ms()
        if (request.deadline_ms is None
                and self.config.default_deadline_ms is not None):
            request = dataclasses.replace(
                request, deadline_ms=self.config.default_deadline_ms)
        tk = Ticket(request, now, on_done)
        err = self._admission_error(request, now)
        if err is not None:
            self._resolve(tk, error=err, count_admit=False)
            return tk
        key, prog = self._program_for(request)
        if isinstance(prog, ServeError):
            self._resolve(tk, error=prog, count_admit=False)
            return tk
        with self._lock:
            self.counters["admitted"] += 1
            self._tenant_inflight[request.tenant] += 1
            if self._first_admit_ms is None:
                self._first_admit_ms = now
            self._programs[key] = (prog, request.total_t)
            self._buckets.setdefault(key, []).append(tk)
        return tk

    def _admission_error(self, request: ServeRequest,
                         now: float) -> ServeError | None:
        cfg = self.config
        with self._lock:
            queued = sum(len(b) for b in self._buckets.values())
            inflight = self._tenant_inflight[request.tenant]
        if queued >= cfg.max_queue:
            self._count("rejected_queue_full")
            return Rejected("queue_full")
        if inflight >= cfg.max_inflight_per_tenant:
            self._count("rejected_tenant_cap")
            return Rejected("tenant_cap")
        if not isinstance(request.spec, StencilSpec):
            self._count("invalid")
            return InvalidRequest(f"spec must be a StencilSpec, got "
                                  f"{type(request.spec).__name__}")
        shape = tuple(getattr(request.x, "shape", ()))
        if len(shape) != request.spec.ndim:
            self._count("invalid")
            return InvalidRequest(
                f"{request.spec.name} is {request.spec.ndim}-D; "
                f"got a rank-{len(shape)} field {shape}")
        if math.prod(shape) > cfg.max_cells:
            self._count("rejected_oversized")
            return Rejected("oversized")
        if not (0 <= request.total_t <= cfg.max_steps):
            self._count("invalid")
            return InvalidRequest(f"total_t must be in [0, {cfg.max_steps}], "
                                  f"got {request.total_t}")
        dt = getattr(request.x, "dtype", None)
        if dt is None or not jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            self._count("invalid")
            return InvalidRequest(f"field dtype must be floating, got {dt}")
        if request.deadline_ms is not None and request.deadline_ms <= 0:
            self._count("expired_admission")
            return Expired("admission")
        return None

    def _program_for(self, request: ServeRequest):
        """Shape-bucket key + compiled program; compile errors become a
        per-request :class:`InvalidRequest` (they fail alone, pre-batch)."""
        boundary = request.boundary or ZERO
        shape = tuple(int(n) for n in request.x.shape)
        dtype = jnp.dtype(request.x.dtype).name
        key = (request.spec.signature, shape, dtype, boundary,
               request.t, request.total_t)
        try:
            prog = self._compile(request.spec, shape,
                                 dtype=request.x.dtype, t=request.t,
                                 boundary=boundary,
                                 interpret=self.config.interpret)
        except Exception as e:  # noqa: BLE001 — typed, never batch-fatal
            self._count("invalid")
            return key, InvalidRequest(f"compile failed: {e}")
        return key, prog

    # -------------------------------------------------------- coalescing ----
    @staticmethod
    def _round_robin(tickets: list) -> list:
        """Batch-formation order: tenants interleaved round-robin
        (first-appearance tenant order, oldest-first within a tenant),
        so a burst from one tenant cannot push every other tenant's
        requests out of the next ``max_batch`` slots — under contention
        each waiting tenant lands at least one request per formed batch.
        Deterministic: arrival order decides both orderings.  With a
        single tenant this is exactly the old FIFO."""
        by_tenant: dict = {}
        for tk in tickets:
            by_tenant.setdefault(tk.request.tenant, []).append(tk)
        if len(by_tenant) <= 1:
            return list(tickets)
        out, queues = [], list(by_tenant.values())
        while queues:
            still = []
            for q in queues:
                out.append(q.pop(0))
                if q:
                    still.append(q)
            queues = still
        return out

    def poll(self, force: bool = False) -> list:
        """Form due batches: a bucket dispatches when full
        (``max_batch``) or its oldest request has waited out the batch
        window (or ``force``, at drain).  Batch slots are filled in
        per-tenant round-robin order (:meth:`_round_robin`), so no
        tenant starves behind another tenant's burst.  Expired requests
        are resolved ``Expired('batch_formation')`` here — dropped from
        the batch instead of dispatched."""
        now = self.clock.now_ms()
        cfg = self.config

        def due(tickets) -> bool:
            return bool(tickets) and (
                force or len(tickets) >= cfg.max_batch
                or now - min(tk.admitted_ms for tk in tickets)
                >= cfg.batch_window_ms)

        batches, expired = [], []
        with self._lock:
            for key, tickets in self._buckets.items():
                prog, total_t = self._programs[key]
                while due(tickets):
                    ordered = self._round_robin(tickets)
                    taken, tickets[:] = (ordered[:cfg.max_batch],
                                         ordered[cfg.max_batch:])
                    if len({tk.request.tenant for tk in taken}) > 1:
                        self.counters["multi_tenant_batches"] += 1
                    live = []
                    for tk in taken:
                        (expired if tk.expired(now) else live).append(tk)
                    if live:
                        batches.append(_Batch(prog, total_t, live))
            for key in [k for k, v in self._buckets.items() if not v]:
                del self._buckets[key]
        for tk in expired:
            self._count("expired_batch_formation")
            self._resolve(tk, error=Expired("batch_formation"))
        return batches

    def pending(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._buckets.values())

    # ---------------------------------------------------------- dispatch ----
    def dispatch(self, batch: _Batch) -> None:
        """Run one formed batch down the ladder.  Defensive outer rim:
        whatever happens inside, every ticket resolves."""
        try:
            self._count("batches")
            self._ladder(batch.program, batch.total_t, batch.tickets)
        except Exception as e:  # noqa: BLE001 — the no-hang guarantee
            for tk in batch.tickets:
                if not tk.done:
                    self._resolve(tk, error=ServiceFault(
                        f"internal dispatch error: {e!r}"))

    def pump(self) -> int:
        """poll + dispatch inline (the synchronous driver loop); returns
        the number of batches dispatched."""
        batches = self.poll()
        for b in batches:
            self.dispatch(b)
        return len(batches)

    def drain(self) -> None:
        """Resolve everything still queued: advance past the batch
        window (sim clocks) and force-flush the buckets."""
        while self.pending():
            self.clock.advance(self.config.batch_window_ms)
            for b in self.poll(force=True):
                self.dispatch(b)

    # the degradation ladder: batch -> halves -> solo -> typed error
    def _ladder(self, prog, total_t: int, tickets: list) -> None:
        tickets = [tk for tk in tickets if not tk.done]
        if not tickets:
            return
        if len(tickets) == 1:
            self._solo(prog, total_t, tickets[0])
            return
        try:
            ys = self._attempt_batched(prog, total_t, tickets)
        except _Fallthrough:
            self._count("ladder_splits")
            mid = (len(tickets) + 1) // 2
            self._ladder(prog, total_t, tickets[:mid])
            self._ladder(prog, total_t, tickets[mid:])
            return
        # one fused finiteness reduction + one host sync for the whole
        # batch — a per-row ``isfinite(y).all()`` costs a device round
        # trip per request and eats the coalescing win it guards
        finite = [bool(f) for f in
                  jnp.isfinite(ys[:len(tickets)])
                     .reshape(len(tickets), -1).all(axis=1)]
        for i, tk in enumerate(tickets):
            self._guard_resolve(tk, ys[i], prog, total_t,
                                width=len(tickets), finite=finite[i])

    def _attempt_batched(self, prog, total_t: int, tickets: list):
        """One ladder rung: the padded vmapped dispatch with bounded
        retry-on-transient.  Raises :class:`_Fallthrough` when this
        width is not going to work."""
        width = next(w for w in self.config.widths()
                     if w >= len(tickets))
        pad = width - len(tickets)
        self._count("pad_rows", pad)
        xs = jnp.stack([jnp.asarray(tk.request.x) for tk in tickets]
                       + [jnp.asarray(tickets[0].request.x)] * pad)
        evict_mark = RUNNER_CACHE.stats()["evictions"]
        for attempt in range(self.config.max_retries + 1):
            try:
                self._inject_dispatch_faults(width)
                ys = prog.run_batched(xs, total_t)
                return self._maybe_corrupt(ys, len(tickets))
            except TransientFault as e:
                self._count(f"transient_{e.kind}")
                if e.kind == "oom":
                    # deterministic at this width: narrowing is the fix,
                    # not retrying
                    raise _Fallthrough from e
                self._backoff(attempt)
            except Exception as e:  # noqa: BLE001
                # consume the cache eviction counters: a concurrent
                # eviction between runner lookup and call is transient
                now_evict = RUNNER_CACHE.stats()["evictions"]
                if now_evict > evict_mark and attempt < self.config.max_retries:
                    evict_mark = now_evict
                    self._count("transient_evicted")
                    self._backoff(attempt)
                    continue
                raise _Fallthrough from e
        raise _Fallthrough                    # retries exhausted

    def _solo(self, prog, total_t: int, tk: Ticket) -> None:
        """Bottom compute rung: unbatched ``.run`` with bounded retries;
        a persistent failure resolves the typed :class:`ServiceFault`."""
        self._count("solo_dispatches")
        for attempt in range(self.config.max_retries + 1):
            try:
                self._inject_dispatch_faults(1)
                y = prog.run(jnp.asarray(tk.request.x), total_t)
                self._guard_resolve(tk, y, prog, total_t, width=1)
                return
            except TransientFault as e:
                self._count(f"transient_{e.kind}")
                self._backoff(attempt)
            except Exception as e:  # noqa: BLE001
                self._resolve(tk, error=ServiceFault(
                    f"solo dispatch failed: {e}"))
                return
        self._resolve(tk, error=ServiceFault(
            f"retries exhausted after {self.config.max_retries + 1} "
            "transient failures"))

    def _inject_dispatch_faults(self, width: int) -> None:
        if self.faults is None:
            return
        delay = self.faults.dispatch_delay_ms()
        if delay:
            self.clock.advance(delay)
        if self.faults.should_evict():
            RUNNER_CACHE.clear()              # the real eviction race
            raise TransientFault("evicted", "runner cache cleared mid-flight")
        if self.faults.should_oom(width):
            raise TransientFault("oom", f"batch width {width}")

    def _maybe_corrupt(self, ys, n: int):
        if self.faults is None:
            return ys
        row = self.faults.corrupt_output_row(n)
        if row is not None and row < n:
            ys = ys.at[row].set(jnp.nan)
        return ys

    def _backoff(self, attempt: int) -> None:
        cfg = self.config
        ms = (cfg.backoff_base_ms * cfg.backoff_factor ** attempt
              + self._jitter.uniform(0, cfg.backoff_jitter_ms))
        self._count("retries")
        self.clock.advance(ms)

    # -------------------------------------------------- guard / resolve ----
    def _guard_resolve(self, tk: Ticket, y, prog, total_t: int, *,
                       width: int, solo_retry_done: bool = False,
                       finite: bool | None = None) -> None:
        """Post-dispatch rim: late results expire; non-finite outputs go
        through the configured guard; everything else resolves clean.
        ``finite`` carries a precomputed per-row verdict from the batched
        path's fused reduction; solo paths leave it ``None`` and check
        their single row here."""
        if tk.done:
            return
        if tk.expired(self.clock.now_ms()):
            self._count("expired_post_dispatch")
            self._resolve(tk, error=Expired("post_dispatch"))
            return
        if finite is None:
            finite = bool(jnp.isfinite(y).all())
        if finite:
            tk.batched_width = width
            self._resolve(tk, value=y)
            return
        guard = self.config.guard
        self._count("nonfinite_outputs")
        if guard == "propagate":
            tk.batched_width = width
            self._resolve(tk, value=y)
        elif guard == "reject" or solo_retry_done:
            self._count("poisoned")
            self._resolve(tk, error=PoisonedOutput(
                "non-finite output" + (" (confirmed solo)"
                                       if solo_retry_done else "")))
        else:                                  # retry_solo: isolate blame
            self._count("guard_solo_retries")
            try:
                y2 = prog.run(jnp.asarray(tk.request.x), total_t)
            except Exception as e:  # noqa: BLE001
                self._resolve(tk, error=ServiceFault(
                    f"guard solo retry failed: {e}"))
                return
            self._guard_resolve(tk, y2, prog, total_t, width=1,
                                solo_retry_done=True)

    def _resolve(self, tk: Ticket, value=None, error: ServeError | None = None,
                 count_admit: bool = True) -> None:
        now = self.clock.now_ms()
        with self._lock:
            if tk.done:
                return
            tk.value = value
            tk.error = error
            tk.done = True
            tk.latency_ms = now - tk.admitted_ms
            self._last_resolve_ms = now
            if count_admit:
                self._tenant_inflight[tk.request.tenant] -= 1
                self._latencies_ms.append(tk.latency_ms)
                self.counters["completed" if error is None
                              else "errored"] += 1
        if tk._on_done is not None:
            tk._on_done(tk)

    # --------------------------------------------------------------- stats --
    def stats(self) -> dict:
        """The service's health report: outcome counters, latency
        percentiles (service clock), throughput, cache and fault-injector
        counters — the CLI driver prints this verbatim."""
        with self._lock:
            lat = sorted(self._latencies_ms)
            out = dict(self.counters)
            out["pending"] = sum(len(b) for b in self._buckets.values())
            out["resolved"] = len(lat)
            if lat:
                out["p50_latency_ms"] = round(lat[len(lat) // 2], 3)
                out["p99_latency_ms"] = round(
                    lat[min(len(lat) - 1, int(len(lat) * 0.99))], 3)
                elapsed_ms = ((self._last_resolve_ms or 0)
                              - (self._first_admit_ms or 0))
                if elapsed_ms > 0:
                    out["requests_per_sec"] = round(
                        len(lat) / (elapsed_ms / 1e3), 2)
            out["runner_cache"] = RUNNER_CACHE.stats()
            if self.faults is not None:
                out["faults_injected"] = self.faults.stats()
            return out


# ============================================================ async front ==
class StencilService:
    """The asyncio front door over :class:`ServiceCore`: admission on the
    event loop, batch dispatch on worker threads (the default executor),
    one pump task forming batches on the real clock.

        svc = StencilService()
        await svc.start()
        try:
            y = await svc.submit(ServeRequest(spec, x, total_t=8))
        except Rejected as e:       # typed backpressure
            ...
        finally:
            await svc.stop()        # drains: every ticket resolves
    """

    def __init__(self, config: ServiceConfig | None = None, *,
                 faults: FaultInjector | None = None):
        self.core = ServiceCore(config, clock=MonotonicClock(),
                                faults=faults)
        self._pump_task = None
        self._dispatches: set = set()
        self._stopping = False

    async def start(self) -> None:
        self._stopping = False
        self._pump_task = asyncio.create_task(self._pump_loop())

    async def submit(self, request: ServeRequest):
        """Admit, await resolution, return the value — or raise the
        request's typed :class:`ServeError`."""
        loop = asyncio.get_running_loop()
        done = asyncio.Event()
        tk = self.core.submit(
            request,
            on_done=lambda _tk: loop.call_soon_threadsafe(done.set))
        if not tk.done:                      # admission refusals are sync
            await done.wait()
        return tk.result()

    async def _pump_loop(self) -> None:
        loop = asyncio.get_running_loop()
        tick_s = max(self.core.config.batch_window_ms / 2e3, 5e-4)
        while not self._stopping:
            self._launch(loop, self.core.poll())
            await asyncio.sleep(tick_s)

    def _launch(self, loop, batches) -> None:
        for b in batches:
            fut = loop.run_in_executor(None, self.core.dispatch, b)
            self._dispatches.add(fut)
            fut.add_done_callback(self._dispatches.discard)

    async def stop(self) -> None:
        """Stop pumping and drain: force-flush the buckets, await every
        in-flight dispatch — no admitted request is left unresolved."""
        self._stopping = True
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None
        loop = asyncio.get_running_loop()
        self._launch(loop, self.core.poll(force=True))
        while self._dispatches:
            await asyncio.gather(*tuple(self._dispatches),
                                 return_exceptions=True)

    def stats(self) -> dict:
        return self.core.stats()
