"""Serving entry points: prefill + decode step builders.

``make_prefill``/``make_decode_step`` close over (cfg, cache_len); the
launcher jits them with explicit in/out shardings from the config's
ParamDef/cache trees.  decode carries a scalar ``pos`` (synchronized batched
decode — continuous batching would thread per-row positions; noted in
DESIGN.md as a serving extension)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer


def make_prefill(cfg, cache_len: int):
    def prefill_step(params, batch):
        logits, cache = transformer.prefill(cfg, params, batch, cache_len)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache
    return prefill_step


def make_decode_step(cfg):
    def decode_one(params, cache, tokens, pos):
        logits, cache = transformer.decode_step(cfg, params, cache, tokens,
                                                pos)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, cache
    return decode_one


def greedy_generate(cfg, params, prompt, max_new: int, cache_len: int):
    """Reference loop for examples/tests: prefill + n greedy decode steps."""
    prefill_step = make_prefill(cfg, cache_len)
    decode_one = make_decode_step(cfg)
    batch = prompt if isinstance(prompt, dict) else {"tokens": prompt}
    tok, cache = prefill_step(params, batch)
    s0 = batch["tokens"].shape[1] if "tokens" in batch else 0
    if cfg.family == "vlm":
        s0 += cfg.vlm_patches
    toks = [tok]
    pos = s0
    for _ in range(max_new - 1):
        tok, cache = decode_one(params, cache, tok[:, None], jnp.int32(pos))
        toks.append(tok)
        pos += 1
    return jnp.stack(toks, axis=1)
