"""Sharded deep-halo execution: ``StencilProgram.run_sharded`` over a mesh.

EBISU's thesis — low occupancy, large tiles, executed tile-by-tile — scales
out by treating **each device as one large tile**: the domain is
decomposed over a 1-D/2-D device mesh, and neighbor shards exchange ghost
zones **once per temporal block** of ``t`` fused steps, at halo depth
``t·radius``, instead of once per time step at depth ``radius`` (the
wavefront/ghost-layer temporal blocking of Wittmann, Hager & Wellein —
PAPERS.md).  Total halo *bytes* are unchanged (depth × 1/frequency), but
the number of collective rounds — the latency/synchronization term, the
distributed analogue of Eq 11's grid-sync count — drops by ``t``.

Execution of one temporal block of depth ``d`` (DESIGN.md §12):

  1. **deep-halo gather** — for every sharded tensor dim, exchange
     ``h = d·radius``-deep slabs with both mesh neighbors via
     ``lax.ppermute`` (one round per dim per block).  Axes are extended
     sequentially on the progressively extended array, so box-stencil
     corner values arrive via the standard two-hop trick — the mesh-level
     analogue of the up-to-27 rim sub-block views the 3-D kernel fetches
     per tile (``stencil3d.py`` §9.2).  Boundary handling at the domain
     edge: *periodic* closes the ppermute ring (torus seam), *dirichlet*
     leaves the open chain's zero fill (exact for the shifted field),
     *reflect* self-mirrors the edge shard's own rim.
  2. **per-shard trapezoid** — ``d`` valid-mode steps of the shared tap
     engine (``taps.chain_trapezoid``) narrow the haloed block by one
     radius per step along every extended dim: step ``s`` computes only
     cells that can still reach the block's output, and after ``d`` steps
     the extent is exactly the shard again — gather and crop are the same
     geometry, no separate crop pass.
  3. **carry** — the result is the next block's input; all blocks of a
     ``T``-step run live under ONE cached jit (donated on backends that
     support it), exactly like ``StencilProgram.run``.

The per-shard compute is the jnp tap-engine chain (the same numerical
core the Pallas kernels and the oracle share, DESIGN.md §8.3); driving
the Pallas kernels *inside* shard_map needs a per-shard scalar-prefetch
origin operand and stays a recorded stretch item (DESIGN.md §17).

Everything here is importable without initializing a JAX backend; device
questions are answered when ``compile_stencil(..., mesh=)`` resolves the
mesh.  See ``docs/sharding.md`` for the user-facing guide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import _exchange_one_axis, shard_map_compat
from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import engine_for, tap_sum

__all__ = [
    "count_ppermutes",
    "mesh_key",
    "planned_exchange_rounds",
    "resolve_mesh",
    "shard_extents",
    "sharded_partition_spec",
    "validate_mesh_for",
]


# ============================================================ mesh plumbing ==
def resolve_mesh(mesh, ndim: int) -> Mesh | None:
    """Normalize the ``compile_stencil(..., mesh=)`` argument to a Mesh.

    Accepted forms (mesh axis ``k`` shards tensor dim ``k``):

      * ``None``            — single-device program (no sharding),
      * ``jax.sharding.Mesh`` — used as-is (at most ``ndim`` axes),
      * ``int n``           — 1-D mesh ``(n,)`` sharding dim 0,
      * ``tuple`` of ints   — e.g. ``(2, 4)`` shards dims 0 and 1.

        mesh = resolve_mesh((2, 4), ndim=3)    # Mesh('shard0': 2, 'shard1': 4)

    Int/tuple forms construct the mesh over ``jax.devices()`` (see
    ``repro.launch.mesh.make_stencil_mesh``) — this is the one place the
    sharded layer touches the backend.
    """
    if mesh is None:
        return None
    if isinstance(mesh, int):
        mesh = (mesh,)
    if isinstance(mesh, (tuple, list)):
        from repro.launch.mesh import make_stencil_mesh
        mesh = make_stencil_mesh(tuple(mesh))
    if not isinstance(mesh, Mesh):
        raise TypeError(
            f"mesh must be a jax.sharding.Mesh, an int, a tuple of ints, "
            f"or None; got {type(mesh).__name__}")
    if not (1 <= len(mesh.axis_names) <= ndim):
        raise ValueError(
            f"mesh has {len(mesh.axis_names)} axes but the stencil domain "
            f"is {ndim}-D; use a 1-D or up-to-{ndim}-D mesh (axis k shards "
            f"tensor dim k)")
    return mesh


def mesh_key(mesh: Mesh | None):
    """Hashable identity of a mesh for program/runner cache keys."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def _mesh_dims(mesh: Mesh) -> tuple[int, ...]:
    """Shard count per mesh-covered tensor dim (dim k <- mesh axis k)."""
    return tuple(mesh.shape[ax] for ax in mesh.axis_names)


def shard_extents(shape: tuple[int, ...], mesh: Mesh) -> tuple[int, ...]:
    """Per-shard domain extents: ``shape[k] / mesh_axis_k`` on covered
    dims, the full extent on uncovered trailing dims.  Requires
    divisibility (checked by :func:`validate_mesh_for`)."""
    dims = _mesh_dims(mesh)
    return tuple(s // n for s, n in zip(shape, dims)) + shape[len(dims):]


def sharded_partition_spec(shape_len: int, mesh: Mesh) -> P:
    """The PartitionSpec ``run_sharded`` places its operand with: mesh
    axis ``k`` over tensor dim ``k``, trailing dims replicated."""
    axes = list(mesh.axis_names) + [None] * (shape_len - len(mesh.axis_names))
    return P(*axes)


def validate_mesh_for(spec: StencilSpec, shape: tuple[int, ...],
                      mesh: Mesh, t: int, boundary) -> None:
    """Refuse mesh/domain/depth combinations the one-hop deep-halo
    exchange cannot execute, with the fix spelled out:

      * every sharded dim must be divisible by its mesh axis (shards are
        uniform — XLA's sharded layout requires it);
      * the block halo ``t·radius`` must fit inside one neighbor shard
        (halo slabs travel exactly one ppermute hop per block);
      * reflect additionally mirrors ``t·radius`` interior cells about
        the edge *excluding* the edge cell, needing one extra row;
      * neumann is not wired into the shard-local edge fills yet —
        refused up front rather than KeyError-ing mid-compile.
    """
    if getattr(boundary, "kind", None) == "neumann":
        raise ValueError(
            f"{spec.name}: run_sharded does not support neumann boundaries "
            "yet (the shard-local edge ghost fill only implements "
            "dirichlet/periodic/reflect); use one of those, or run the "
            "program single-device where neumann is fully supported")
    dims = _mesh_dims(mesh)
    h = spec.halo(t)
    for d, n in enumerate(dims):
        if n == 1:
            continue
        if shape[d] % n:
            raise ValueError(
                f"{spec.name}: domain dim {d} ({shape[d]}) is not divisible "
                f"by mesh axis {mesh.axis_names[d]!r} ({n} shards); pad the "
                f"domain to a multiple of {n} or pick a mesh shape that "
                f"divides {shape[d]} (shards must be uniform)")
        shard = shape[d] // n
        need = h + 1 if getattr(boundary, "kind", None) == "reflect" else h
        if need > shard:
            raise ValueError(
                f"{spec.name}: block halo t*radius = {t}*{spec.radius} = {h} "
                f"{'(+1 for the reflect mirror) ' if need > h else ''}"
                f"exceeds the shard extent {shard} on dim {d} "
                f"({shape[d]} cells / {n} shards) — the deep-halo gather is "
                f"one neighbor hop per block.  Reduce t, use fewer shards "
                f"on mesh axis {mesh.axis_names[d]!r}, or grow the domain")


def planned_exchange_rounds(total_t: int, t: int) -> int:
    """Halo-exchange rounds a ``T``-step sharded run performs: one per
    temporal block (``ceil(T/t)`` via the remainder-sweep schedule) —
    versus ``T`` rounds for the classic exchange-every-step scheme.

        planned_exchange_rounds(64, 4)   # -> 16, an 4x round reduction
    """
    from repro.api.program import sweep_schedule
    return len(sweep_schedule(total_t, t))


# ====================================================== deep-halo execution ==
def _extend_local(x: jnp.ndarray, dim: int, h: int, boundary) -> jnp.ndarray:
    """Ghost-extend one *unsharded* dim by ``h`` with the boundary rule —
    the global edge lives entirely on this shard, so no exchange needed."""
    pad = [(0, 0)] * x.ndim
    pad[dim] = (h, h)
    mode = {"periodic": dict(mode="wrap"),
            "reflect": dict(mode="reflect")}[boundary.kind]
    return jnp.pad(x, pad, **mode)


def _mirror_rim(ext: jnp.ndarray, dim: int, h: int, lo: bool) -> jnp.ndarray:
    """The reflect ghost slab an edge shard fills from its own rim:
    ``ghost(-k) = u(k)`` about the edge cell (edge cell excluded)."""
    n = ext.shape[dim]
    idx = [slice(None)] * ext.ndim
    idx[dim] = slice(1, 1 + h) if lo else slice(n - 1 - h, n - 1)
    return jnp.flip(ext[tuple(idx)], axis=dim)


def _exchange_sharded_axis(ext: jnp.ndarray, dim: int, h: int, axis_name: str,
                           n: int, boundary) -> jnp.ndarray:
    """One deep-halo exchange round on a sharded dim (both directions).

    periodic: closed ppermute ring — the torus seam is just another
    neighbor hop.  dirichlet: open chain; edge shards keep ppermute's
    zero fill, which is exactly the ghost value of the *shifted* field.
    reflect: open chain, then edge shards overwrite their sourceless
    halo with the mirror of their own rim (a local flip, no traffic).
    """
    periodic = boundary.kind == "periodic"
    out = _exchange_one_axis(ext, dim, h, axis_name, n, periodic=periodic)
    if boundary.kind != "reflect" or n == 1:
        return out
    idx = jax.lax.axis_index(axis_name)
    lo_idx = [slice(None)] * out.ndim
    lo_idx[dim] = slice(0, h)
    hi_idx = [slice(None)] * out.ndim
    hi_idx[dim] = slice(out.shape[dim] - h, out.shape[dim])
    lo = jnp.where(idx == 0, _mirror_rim(ext, dim, h, lo=True),
                   out[tuple(lo_idx)])
    hi = jnp.where(idx == n - 1, _mirror_rim(ext, dim, h, lo=False),
                   out[tuple(hi_idx)])
    mid = [slice(None)] * out.ndim
    mid[dim] = slice(h, out.shape[dim] - h)
    return jnp.concatenate([lo, out[tuple(mid)], hi], axis=dim)


def _dirichlet_post(sharded_dims, axis_names, ns, shard_shape, rad, h):
    """The trapezoid ``post`` hook re-pinning the *global* Dirichlet
    boundary: after step ``s``, the surviving ghost band (``h − s·rad``
    deep, only on shards at the true domain edge) is re-zeroed so the
    next step reads boundary-true zeros, not evolved ghost garbage.
    Interior seams need nothing — their halo is true neighbor data
    evolving exactly."""

    def post(v: jnp.ndarray, s: int) -> jnp.ndarray:
        cur = h - s * rad
        if cur <= 0:
            return v
        mask = None
        for dim in sharded_dims:
            idx = jax.lax.axis_index(axis_names[dim])
            ids = jnp.arange(v.shape[dim])
            ok = (((ids >= cur) | (idx > 0))
                  & ((ids < shard_shape[dim] + cur) | (idx < ns[dim] - 1)))
            bshape = [1] * v.ndim
            bshape[dim] = v.shape[dim]
            ok = ok.reshape(bshape)
            mask = ok if mask is None else mask & ok
        return jnp.where(mask, v, jnp.zeros((), v.dtype))

    return post


def build_sharded_runner(prog, total_t: int):
    """The un-jitted global ``f(x) -> y`` for ``prog.run_sharded(x, T)``.

    One shard_map over the program's mesh; inside it, the full multi-
    block schedule (``sweep_schedule`` — full-depth blocks plus one
    shallower remainder block) with one deep-halo gather per block and
    the per-shard trapezoid chain per block.  Compute happens at the
    program's ``compute_dtype``; only the final result is cast back to
    storage.  Dirichlet(v≠0) runs through the same affine closure as the
    single-device chain (DESIGN.md §11.3): the carry is shifted by ``v``
    into zero-Dirichlet space around every block and re-shifted by
    ``v·s^d`` after it — exact when ``s = 1`` (any depth) or ``d = 1``
    (validated at compile).
    """
    from repro.api.program import _grouped, sweep_schedule

    spec, mesh, boundary = prog.spec, prog.mesh, prog.boundary
    depth = max(1, min(prog.t, total_t))
    groups = _grouped(sweep_schedule(total_t, depth))
    rad = spec.radius
    ndim = spec.ndim
    axis_names = list(mesh.axis_names) + [None] * (ndim - len(mesh.axis_names))
    ns = list(_mesh_dims(mesh)) + [1] * (ndim - len(mesh.axis_names))
    sharded_dims = [d for d in range(ndim) if ns[d] > 1]
    shard_shape = shard_extents(prog.shape, mesh)
    cdtype = prog.compute_dtype
    s = tap_sum(spec.taps)
    engine = engine_for(spec.taps, ndim)
    pspec = sharded_partition_spec(ndim, mesh)
    dirichlet = boundary.kind == "dirichlet"
    shift = boundary.value if dirichlet else 0.0

    def block(v: jnp.ndarray, d: int) -> jnp.ndarray:
        """One temporal block: gather a d*rad halo once, run d narrowed
        steps; output extent == shard extent again."""
        h = rad * d
        if dirichlet and shift != 0.0:
            v = v - jnp.asarray(shift, cdtype)
        ext = v
        for dim in sharded_dims:
            ext = _exchange_sharded_axis(ext, dim, h, axis_names[dim],
                                        ns[dim], boundary)
        if dirichlet:
            # unsharded dims stay unextended: the tap engine's zero-fill
            # IS the (shifted) Dirichlet condition at the true array edge
            out = engine.chain_trapezoid(
                ext, d, axes=sharded_dims,
                post=_dirichlet_post(sharded_dims, axis_names, ns,
                                     shard_shape, rad, h))
        else:
            for dim in range(ndim):
                if dim not in sharded_dims:
                    ext = _extend_local(ext, dim, h, boundary)
            out = engine.chain_trapezoid(ext, d, axes=tuple(range(ndim)))
        if dirichlet and shift != 0.0:
            out = out + jnp.asarray(shift * s ** d, cdtype)
        return out

    def shard_fn(local: jnp.ndarray) -> jnp.ndarray:
        v = local
        for d, count in groups:
            for _ in range(count):
                v = block(v, d)
        return v

    mapped = shard_map_compat(shard_fn, mesh, in_specs=(pspec,),
                              out_specs=pspec)

    def run(x: jnp.ndarray) -> jnp.ndarray:
        return mapped(x.astype(cdtype)).astype(prog.dtype)

    return run


def operand_sharding(prog) -> NamedSharding:
    """The NamedSharding ``run_sharded`` places its operand with."""
    return NamedSharding(prog.mesh,
                         sharded_partition_spec(prog.spec.ndim, prog.mesh))


# ========================================================== introspection ==
def _walk_jaxprs(obj):
    """Yield every (Closed)Jaxpr reachable from an eqn param value.

    Duck-typed (``eqns`` / ``.jaxpr.eqns``) so it survives the move of
    Jaxpr/ClosedJaxpr between ``jax.core`` homes across versions.
    """
    if hasattr(obj, "eqns"):                        # a Jaxpr
        yield obj
    elif hasattr(obj, "jaxpr") and hasattr(getattr(obj, "jaxpr"), "eqns"):
        yield obj.jaxpr                             # a ClosedJaxpr
    elif isinstance(obj, (tuple, list)):
        for o in obj:
            yield from _walk_jaxprs(o)


def _count_primitive(jaxpr, name: str) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in _walk_jaxprs(v):
                n += _count_primitive(sub, name)
    return n


def count_ppermutes(fn, *args) -> int:
    """Number of ``ppermute`` collectives in the trace of ``fn(*args)`` —
    what the exchange-count tests assert against
    ``planned_exchange_rounds(T, t) × 2 × (#sharded axes)``.

        fn = build_sharded_runner(prog, total_t=16)
        count_ppermutes(fn, x)    # e.g. 4 blocks × 2 dirs × 1 axis = 8
    """
    closed = jax.make_jaxpr(fn)(*args)
    return _count_primitive(closed.jaxpr, "ppermute")
