"""``AttentionProgram``: the compile-once front door for attention.

The stencil half resolves its plan/geometry/boundary exactly once
(``compile_stencil``) and hands back an immutable program with memoized
jitted runners.  This module gives the LM half the same treatment: an
attention configuration (heads, GQA groups, mask, chunking, dtype
policy) is resolved exactly once into an :class:`AttentionProgram`, and
every execution surface — the Pallas flash kernel, the chunked
online-softmax jnp path, the dense oracle-shaped path — dispatches
through one memoized runner table instead of ad-hoc call sites.

    prog = compile_attention(heads=8, kv_heads=2, head_dim=64)
    out  = prog.apply(q, k, v)           # forward, memoized jitted runner
    dq, dk, dv = prog.grad(q, k, v, do)  # VJP runner (flash bwd kernels)

Implementation selection (``impl=``):

  * ``"pallas"``  — the Pallas TPU flash kernel
    (``kernels/flash_attention.py``): q tile + running softmax stats
    resident in VMEM, K/V streamed — the paper's §4.1/§4.3 "one tile in
    scratchpad, stream the rest" execution model.  Chunk-divisibility is
    validated at dispatch with the fix spelled out.
  * ``"chunked"`` — the pure-jnp online-softmax path
    (``models/attention.flash_attention``): same math, no Pallas; this
    is what the LM dry-run cells lower (it shards/remats freely).
  * ``"dense"``   — ``models/attention.dense_attention``, the
    independent oracle (materializes S×S scores; reference semantics).
  * ``"auto"``    — ``"pallas"`` on a real TPU backend, ``"chunked"``
    elsewhere, mirroring ``compile_stencil``'s interpret choice.

Semantics are defined by the dense oracle: causal masks compare absolute
key position ≤ absolute query position, sliding windows keep
``kpos > qpos - window``, GQA maps query head ``h`` to kv head
``h // (heads // kv_heads)``.  ``tests/test_attention_program.py`` holds
every impl to that oracle across a shapes × GQA × mask × dtype matrix,
and the backward runners to ``jax.grad`` of the oracle.

Dtype policy (mirrors ``resolve_compute_dtype``): ``dtype`` is the
storage dtype of q/k/v; every impl computes in float32 and casts the
output back to storage — bf16 fields pay one rounding at the end, not
one per kv chunk.  Importing this module never initializes a JAX
backend (checked by ``scripts/tier1.sh``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.api.program import ProgramCache

IMPLS = ("auto", "pallas", "chunked", "dense")

ATTN_PROGRAM_CACHE = ProgramCache(64, "attention_programs")
ATTN_RUNNER_CACHE = ProgramCache(256, "attention_runners")


def attention_cache_stats() -> dict:
    """Hit/miss/size counters for the attention caches.

        from repro.api import attention_cache_stats
        attention_cache_stats()["attention_runners"]["misses"]
    """
    return {c.name: c.stats()
            for c in (ATTN_PROGRAM_CACHE, ATTN_RUNNER_CACHE)}


def clear_attention_caches() -> None:
    for c in (ATTN_PROGRAM_CACHE, ATTN_RUNNER_CACHE):
        c.clear()


# ============================================================ AttentionSpec ==
@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """The structural identity of an attention configuration — what two
    programs must share to share runners.  Validated by
    :func:`compile_attention`; hashable (it is the program cache key)."""
    heads: int
    kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None
    q_chunk: int = 256
    kv_chunk: int = 512

    @property
    def groups(self) -> int:
        """GQA group size: query heads per kv head."""
        return self.heads // self.kv_heads

    @property
    def signature(self) -> tuple:
        return (self.heads, self.kv_heads, self.head_dim, self.causal,
                self.window, self.q_chunk, self.kv_chunk)


def _validate_spec(spec: AttentionSpec) -> None:
    if spec.heads < 1 or spec.kv_heads < 1 or spec.head_dim < 1:
        raise ValueError(
            f"heads/kv_heads/head_dim must be >= 1, got "
            f"({spec.heads}, {spec.kv_heads}, {spec.head_dim})")
    if spec.heads % spec.kv_heads:
        raise ValueError(
            f"GQA needs kv_heads | heads: got heads={spec.heads}, "
            f"kv_heads={spec.kv_heads} — pick kv_heads from the divisors "
            f"of {spec.heads}")
    if spec.window is not None and spec.window < 1:
        raise ValueError(f"sliding window must be >= 1 token, got "
                         f"{spec.window} (None disables windowing)")
    if spec.q_chunk < 1 or spec.kv_chunk < 1:
        raise ValueError(
            f"q_chunk/kv_chunk must be >= 1, got "
            f"({spec.q_chunk}, {spec.kv_chunk})")


def spec_from_arch(cfg, *, causal: bool = True) -> AttentionSpec:
    """An :class:`AttentionSpec` from an ``ArchConfig``-shaped object
    (``n_heads``/``kv_heads``/``head_dim``/``swa_window``/``q_chunk``/
    ``kv_chunk`` attributes)."""
    return AttentionSpec(heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                         head_dim=cfg.head_dim, causal=causal,
                         window=cfg.swa_window, q_chunk=cfg.q_chunk,
                         kv_chunk=cfg.kv_chunk)


# ========================================================= AttentionProgram ==
class AttentionProgram:
    """An immutable compiled attention configuration with memoized jitted
    forward/VJP runners.  Construct via :func:`compile_attention`:

        prog = compile_attention(heads=8, kv_heads=2, head_dim=64)
        out = prog.apply(q, k, v)            # (B, S, H, hd)
        dq, dk, dv = prog.grad(q, k, v, do)  # VJP at (q, k, v)

    Runners are keyed per (impl, input shapes) in the bounded
    ``ATTN_RUNNER_CACHE`` — a serving loop over one bucket jits once.
    Inside an outer trace (jit / scan / grad), ``apply`` inlines the
    implementation instead of nesting a jit, so lowered programs (the
    dry-run cells, train_step) see exactly the ops they saw before the
    front door existed."""

    def __init__(self, key, spec: AttentionSpec, dtype, compute_dtype,
                 impl: str, interpret: bool):
        self._key = key
        self.spec = spec
        self.dtype = dtype
        self.compute_dtype = compute_dtype
        self.impl = impl
        self.interpret = interpret

    # ------------------------------------------------------------ checks ----
    def _check(self, q, k, v):
        sp = self.spec
        if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
            raise ValueError(
                f"attention inputs are rank-4 (B, S, heads, head_dim); got "
                f"q{tuple(q.shape)} k{tuple(k.shape)} v{tuple(v.shape)}")
        b, s, h, hd = q.shape
        bk, sk, kv, hdk = k.shape
        if k.shape != v.shape:
            raise ValueError(f"k and v must share a shape; got "
                             f"k{tuple(k.shape)} v{tuple(v.shape)}")
        if h != sp.heads or kv != sp.kv_heads or hd != sp.head_dim \
                or hdk != sp.head_dim or b != bk:
            raise ValueError(
                f"program compiled for heads={sp.heads}, "
                f"kv_heads={sp.kv_heads}, head_dim={sp.head_dim}; got "
                f"q{tuple(q.shape)} k{tuple(k.shape)} — compile_attention "
                "a new program for a new head layout")
        for name, x in (("q", q), ("k", k), ("v", v)):
            if x.dtype != self.dtype:
                raise ValueError(
                    f"program compiled for dtype {self.dtype.name}; {name} "
                    f"is {x.dtype.name} — cast the operand or "
                    f"compile_attention(dtype={x.dtype.name})")

    def _resolve_impl(self, s: int, sk: int) -> str:
        """The impl a (s, sk) call dispatches: 'auto' picks the Pallas
        kernel only where it can actually launch (chunk-divisible shapes
        on the compiled backend mode); explicit 'pallas' refuses
        undivisible shapes with the fix spelled out."""
        sp = self.spec
        qc, kc = min(sp.q_chunk, s), min(sp.kv_chunk, sk)
        divisible = (s % qc == 0) and (sk % kc == 0)
        if self.impl == "pallas":
            if not divisible:
                raise ValueError(
                    f"impl='pallas' needs chunk-divisible sequences: "
                    f"S={s} %% q_chunk({qc}) or Sk={sk} %% kv_chunk({kc}) "
                    "!= 0 — pad the sequence, change q_chunk/kv_chunk, or "
                    "compile impl='chunked'")
            return "pallas"
        if self.impl == "auto":
            return "pallas" if (divisible and not self.interpret) \
                else "chunked"
        return self.impl

    # ----------------------------------------------------------- runners ----
    def _fn(self, impl: str):
        """The raw differentiable callable for ``impl`` — closed over the
        program's static configuration, taking only (q, k, v)."""
        sp = self.spec
        if impl == "pallas":
            from repro.kernels.flash_attention import (
                flash_attention_trainable)

            def fn(q, k, v):
                return flash_attention_trainable(
                    q, k, v, sp.causal, sp.window, sp.q_chunk, sp.kv_chunk,
                    self.interpret)
        elif impl == "chunked":
            from repro.models.attention import flash_attention

            def fn(q, k, v):
                return flash_attention(q, k, v, causal=sp.causal,
                                       window=sp.window,
                                       q_chunk=sp.q_chunk,
                                       kv_chunk=sp.kv_chunk)
        elif impl == "dense":
            from repro.models.attention import dense_attention

            def fn(q, k, v):
                return dense_attention(q, k, v, causal=sp.causal,
                                       window=sp.window)
        else:  # pragma: no cover — impl validated at compile
            raise ValueError(impl)
        return fn

    def apply(self, q, k, v):
        """Forward attention: q ``(B, S, H, hd)``, k/v ``(B, Sk, KV,
        hd)`` → ``(B, S, H, hd)`` in the program's storage dtype.

        Top-level calls go through a memoized jitted runner; calls made
        while tracing (inside an outer jit/scan/grad) inline the
        implementation so the outer program lowers exactly as before.
        """
        self._check(q, k, v)
        impl = self._resolve_impl(q.shape[1], k.shape[1])
        if isinstance(q, jax.core.Tracer):
            return self._fn(impl)(q, k, v)
        key = (self._key, "fwd", impl, q.shape, k.shape)
        fn = ATTN_RUNNER_CACHE.get_or_build(
            key, lambda: jax.jit(self._fn(impl)))
        return fn(q, k, v)

    def grad(self, q, k, v, do):
        """The VJP of :meth:`apply` at (q, k, v) against cotangent ``do``
        → ``(dq, dk, dv)``.  For ``impl='pallas'`` this runs the Pallas
        backward kernels (dq over the kv axis, dk/dv over the q axis)
        via the kernel's ``custom_vjp``; other impls differentiate the
        jnp path.  Matches ``jax.grad`` of the dense oracle (tested)."""
        self._check(q, k, v)
        if do.shape != q.shape:
            raise ValueError(f"cotangent must match q: got do"
                             f"{tuple(do.shape)} vs q{tuple(q.shape)}")
        impl = self._resolve_impl(q.shape[1], k.shape[1])
        fn_raw = self._fn(impl)

        def vjp_fn(q, k, v, do):
            _, vjp = jax.vjp(fn_raw, q, k, v)
            return vjp(do)

        if isinstance(q, jax.core.Tracer):
            return vjp_fn(q, k, v, do)
        key = (self._key, "vjp", impl, q.shape, k.shape)
        fn = ATTN_RUNNER_CACHE.get_or_build(key, lambda: jax.jit(vjp_fn))
        return fn(q, k, v, do)

    # ----------------------------------------------------- introspection ----
    def hbm_bytes(self, b: int, s: int, sk: int) -> int:
        """Kernel-model HBM traffic for one forward call: q, k, v read
        once + o written once — no S×S score materialization (the
        chunked-jnp path's score blocks round-trip ~``S·Sk`` extra)."""
        from repro.kernels.flash_attention import attention_hbm_bytes
        return attention_hbm_bytes(b, s, sk, self.spec.heads,
                                   self.spec.kv_heads, self.spec.head_dim,
                                   bytes_per_el=self.dtype.itemsize)

    def cache_stats(self) -> dict:
        """Counters of the module's bounded caches — see
        :func:`attention_cache_stats`."""
        return attention_cache_stats()

    def __repr__(self) -> str:
        sp = self.spec
        return (f"AttentionProgram(heads={sp.heads}, kv_heads={sp.kv_heads},"
                f" head_dim={sp.head_dim}, causal={sp.causal}, "
                f"window={sp.window}, chunks=({sp.q_chunk}, {sp.kv_chunk}), "
                f"impl={self.impl!r}, dtype={self.dtype.name}/"
                f"{self.compute_dtype.name}, interpret={self.interpret})")


# ========================================================= compile_attention ==
def compile_attention(cfg=None, *, heads: int | None = None,
                      kv_heads: int | None = None,
                      head_dim: int | None = None, causal: bool = True,
                      window: int | None = None, q_chunk: int | None = None,
                      kv_chunk: int | None = None, dtype=jnp.float32,
                      compute_dtype=None, impl: str = "auto",
                      interpret: bool | None = None) -> AttentionProgram:
    """Compile an attention configuration to an immutable
    :class:`AttentionProgram` — the LM twin of ``compile_stencil``.

        from repro.api import compile_attention
        prog = compile_attention(heads=8, kv_heads=2, head_dim=64,
                                 window=4096, dtype=jnp.bfloat16)
        out = prog.apply(q, k, v)

    ``cfg`` may be an :class:`AttentionSpec` or an ``ArchConfig``-shaped
    object (``n_heads``/``kv_heads``/``head_dim``/``swa_window``/
    ``q_chunk``/``kv_chunk``); explicit keywords override its fields.
    ``impl`` ∈ ``{"auto", "pallas", "chunked", "dense"}`` (module
    docstring); ``interpret`` defaults to non-TPU backends, resolved at
    compile time — importing stays backend-free.

    The dtype policy: ``dtype`` is q/k/v storage; compute is float32
    (``compute_dtype`` may restate it; other compute dtypes are refused
    — every attention path runs its softmax/dots in f32 and casts the
    output back to storage once).  Programs are memoized in the bounded
    ``ATTN_PROGRAM_CACHE``; recompiling with identical arguments returns
    the same handle.
    """
    if isinstance(cfg, AttentionSpec):
        base = cfg
    elif cfg is not None:
        base = spec_from_arch(cfg, causal=causal)
        if window is None:
            window = base.window
        if q_chunk is None:
            q_chunk = base.q_chunk
        if kv_chunk is None:
            kv_chunk = base.kv_chunk
    else:
        base = None
    if base is not None:
        heads = base.heads if heads is None else heads
        kv_heads = base.kv_heads if kv_heads is None else kv_heads
        head_dim = base.head_dim if head_dim is None else head_dim
        if isinstance(cfg, AttentionSpec):
            causal = base.causal
            window = base.window if window is None else window
            q_chunk = base.q_chunk if q_chunk is None else q_chunk
            kv_chunk = base.kv_chunk if kv_chunk is None else kv_chunk
    if heads is None or head_dim is None:
        raise ValueError(
            "compile_attention needs heads and head_dim — pass them as "
            "keywords or hand in an AttentionSpec / ArchConfig")
    spec = AttentionSpec(heads=heads,
                         kv_heads=heads if kv_heads is None else kv_heads,
                         head_dim=head_dim, causal=causal, window=window,
                         q_chunk=256 if q_chunk is None else q_chunk,
                         kv_chunk=512 if kv_chunk is None else kv_chunk)
    _validate_spec(spec)
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    d = jnp.dtype(dtype)
    if not jnp.issubdtype(d, jnp.floating):
        raise ValueError(f"attention dtype must be floating, got {d.name}")
    cd = jnp.dtype(jnp.float32 if compute_dtype is None else compute_dtype)
    if cd != jnp.float32:
        raise ValueError(
            f"attention computes in float32 (softmax + dots are f32 in "
            f"every impl); got compute_dtype={cd.name} — drop it or pass "
            "float32")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    key = (spec, d.name, cd.name, impl, bool(interpret))
    return ATTN_PROGRAM_CACHE.get_or_build(
        key, lambda: AttentionProgram(key, spec, d, cd, impl,
                                      bool(interpret)))


def attention_program_for(cfg, *, causal: bool = True,
                          dtype=None) -> AttentionProgram:
    """The program an ``ArchConfig`` resolves to — the ONE mapping from
    config-level ``attention_impl`` names to program impls, shared by
    the model forward pass, the train step, and the serving driver.

        prog = attention_program_for(cfg)            # decoder blocks
        prog = attention_program_for(cfg, causal=False)   # encoder

    ``dtype`` defaults to ``cfg.activ_dtype``; the model passes the
    actual post-projection q dtype (norm params may promote bf16
    activations to f32) — programs are memoized, so per-dtype handles
    are free."""
    impl = {"flash_jnp": "chunked", "flash_pallas": "pallas"}.get(
        cfg.attention_impl)
    if impl is None:
        raise ValueError(
            f"attention_impl {cfg.attention_impl!r} has no program "
            "mapping (boundary_stub is inlined by the model, not "
            "compiled) — use 'flash_jnp' or 'flash_pallas'")
    return compile_attention(
        cfg, causal=causal,
        dtype=cfg.activ_dtype if dtype is None else dtype, impl=impl)
