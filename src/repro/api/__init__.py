"""Public compile-once API for the EBISU temporal-blocking kernels.

    from repro.api import Boundary, compile_stencil
    prog = compile_stencil(spec, shape, t=4, boundary=Boundary.periodic())
    y = prog.run(x, T=64)

See README.md for the full quick-start and the deprecation policy for
the legacy entry points (``ops.ebisu_stencil``, ``sweep.run_sweeps``).
Importing this package never initializes a JAX backend (checked by
``scripts/tier1.sh``).
"""
from repro.api.boundary import Boundary
from repro.api.program import (ProgramCache, StencilProgram, cache_stats,
                               clear_caches, compile_stencil, plan_bucketed,
                               resolve_geometry, run_sweeps_padded,
                               sweep_once, sweep_schedule)

__all__ = [
    "Boundary",
    "ProgramCache",
    "StencilProgram",
    "cache_stats",
    "clear_caches",
    "compile_stencil",
    "plan_bucketed",
    "resolve_geometry",
    "run_sweeps_padded",
    "sweep_once",
    "sweep_schedule",
]
