"""Public compile-once API for the EBISU temporal-blocking kernels.

    from repro.api import Boundary, compile_stencil, define_stencil
    spec = define_stencil([((0, 0), 0.6), ((0, 1), 0.1), ...])  # any taps
    prog = compile_stencil(spec, shape, t=4, boundary=Boundary.periodic())
    y = prog.run(x, T=64)

Multi-device: pass ``mesh=`` and call ``run_sharded`` — ghost zones are
exchanged once per temporal block instead of once per step
(``docs/sharding.md``):

    prog = compile_stencil(spec, shape, t=4, mesh=(2, 4))   # 8 devices
    y = prog.run_sharded(x, 64)          # 16 exchange rounds, not 64

The LM workload gets the same compile-once treatment
(``docs/attention.md``):

    prog = compile_attention(heads=8, kv_heads=2, head_dim=64)
    out = prog.apply(q, k, v)            # flash attention, memoized runner

The definition layer is open: ``define_stencil`` / ``from_operator``
build arbitrary user stencils with derived cost models; the Table-2
registry (``repro.core.stencil_spec.get``) is just nine pre-built specs
with the paper's published numbers pinned as overrides.  See README.md
for the quick-start and the deprecation policy for the legacy entry
points (``ops.ebisu_stencil``, ``sweep.run_sweeps``).  Importing this
package never initializes a JAX backend (checked by ``scripts/tier1.sh``).
"""
from repro.api.attention import (AttentionProgram, AttentionSpec,
                                 attention_cache_stats,
                                 attention_program_for, clear_attention_caches,
                                 compile_attention)
from repro.api.boundary import Boundary
from repro.api.define import from_operator, parse_taps, spec_from_json
from repro.api.program import (ProgramCache, StencilProgram, cache_stats,
                               clear_caches, compile_stencil, plan_bucketed,
                               resolve_compute_dtype, resolve_geometry,
                               run_sweeps_padded, sweep_once, sweep_schedule)
from repro.api.sharded import (count_ppermutes, planned_exchange_rounds,
                               resolve_mesh, shard_extents)
from repro.core.stencil_spec import StencilSpec, define_stencil

__all__ = [
    "AttentionProgram",
    "AttentionSpec",
    "Boundary",
    "ProgramCache",
    "attention_cache_stats",
    "attention_program_for",
    "StencilProgram",
    "StencilSpec",
    "cache_stats",
    "clear_attention_caches",
    "clear_caches",
    "compile_attention",
    "compile_stencil",
    "count_ppermutes",
    "define_stencil",
    "from_operator",
    "parse_taps",
    "plan_bucketed",
    "planned_exchange_rounds",
    "resolve_compute_dtype",
    "resolve_geometry",
    "resolve_mesh",
    "run_sweeps_padded",
    "shard_extents",
    "spec_from_json",
    "sweep_once",
    "sweep_schedule",
]
