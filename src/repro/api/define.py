"""User-facing stencil builders: ``define_stencil`` and named operators.

The definition layer is open: any tap set becomes a plannable, costable,
compilable :class:`~repro.core.stencil_spec.StencilSpec` — AN5D-style,
the stencil is *input* to the temporal-blocking machinery, not a registry
entry.  ``define_stencil`` (re-exported from ``repro.core.stencil_spec``)
derives geometry and the §5 cost model from the tap structure;
``from_operator`` builds the common discretizations by name:

    from repro.api import Boundary, compile_stencil, define_stencil
    spec = define_stencil([((0, 0), 0.6), ((0, 1), 0.15), ((0, -1), 0.05),
                           ((1, 0), 0.1), ((-1, 0), 0.1)])   # anisotropic
    prog = compile_stencil(spec, (512, 512), t=4)
    y = prog.run(x, 64)

    from repro.api.define import from_operator
    heat = from_operator("diffusion", ndim=3, alpha=0.1)     # u + a*lap(u)

``parse_taps`` / ``spec_from_json`` are the CLI adapters
(``repro.launch.stencil_run --taps / --spec-json``).  This module is pure
Python over the core spec layer — importing it never initializes a JAX
backend (gated by ``scripts/tier1.sh``).
"""
from __future__ import annotations

import json

from repro.core.stencil_spec import (StencilSpec, box_taps, define_stencil,
                                     gaussian_taps, star_taps)

# 1-D second-derivative coefficients by order of accuracy (2nd/4th):
# the radius-r Laplacian is their sum over axes.
_D2 = {1: ((0, -2.0), (1, 1.0), (-1, 1.0)),
       2: ((0, -2.5), (1, 4 / 3), (-1, 4 / 3), (2, -1 / 12), (-2, -1 / 12))}


def _lap_taps(ndim: int, radius: int, scale: float = 1.0):
    if radius not in _D2:
        raise ValueError(f"laplacian supports radius 1 or 2, got {radius}")
    acc: dict[tuple, float] = {}
    for ax in range(ndim):
        for off1, c in _D2[radius]:
            off = tuple(off1 if a == ax else 0 for a in range(ndim))
            acc[off] = acc.get(off, 0.0) + c * scale
    return tuple(acc.items())


def laplacian(ndim: int = 2, radius: int = 1, *,
              scale: float = 1.0) -> StencilSpec:
    """The raw discrete Laplacian ``∇²`` (2nd- or 4th-order star).

    Its coefficients sum to 0 — zero-Dirichlet and periodic run exactly;
    non-zero Dirichlet needs ``t=1`` sweeps (the affine closure with
    ``s = 0``).  For a Jacobi-style smoother use :func:`diffusion`.
    """
    return define_stencil(_lap_taps(ndim, radius, scale),
                          name=f"lap{ndim}d-r{radius}")


def diffusion(ndim: int = 2, radius: int = 1, *,
              alpha: float = 0.1) -> StencilSpec:
    """Explicit heat step ``u + α·∇²u`` — taps sum to 1, so every
    boundary reduction (including the Dirichlet constant shift) is exact
    at any depth.  FTCS stability wants ``α ≤ 1/(2·ndim)``."""
    taps = dict(_lap_taps(ndim, radius, alpha))
    center = (0,) * ndim
    taps[center] = taps.get(center, 0.0) + 1.0
    # at the stability limit alpha = 1/(2*ndim) the center weight is
    # exactly 0 — a valid pure-neighbor smoother, not a user error
    taps = {off: c for off, c in taps.items() if c != 0.0}
    return define_stencil(tuple(taps.items()),
                          name=f"heat{ndim}d-r{radius}")


def blur(ndim: int = 2, radius: int = 2, *,
         sigma: float = 1.2) -> StencilSpec:
    """Normalized Gaussian blur box (the j2d25pt family, any ndim/radius)."""
    return define_stencil(gaussian_taps(radius, ndim=ndim, sigma=sigma),
                          name=f"blur{ndim}d-r{radius}")


def star(ndim: int = 2, radius: int = 1, *, center_w: float = 2.0,
         arm_w: float = 1.0, normalize: bool = True) -> StencilSpec:
    """Custom star (axis-aligned arms, ``arm_w/r`` falloff)."""
    return define_stencil(
        star_taps(ndim, radius, center_w, arm_w, normalize=normalize),
        name=f"star{ndim}d-r{radius}")


def box(ndim: int = 2, radius: int = 1, *, center_w: float = 4.0,
        normalize: bool = True) -> StencilSpec:
    """Custom dense box (``1/(1+manhattan)`` falloff)."""
    return define_stencil(
        box_taps(ndim, radius, center_w, normalize=normalize),
        name=f"box{ndim}d-r{radius}")


OPERATORS = {"laplacian": laplacian, "diffusion": diffusion, "blur": blur,
             "star": star, "box": box}


def from_operator(kind: str, **params) -> StencilSpec:
    """Build a spec from a named operator: laplacian | diffusion | blur |
    star | box (each takes ``ndim``/``radius`` plus its own knobs).

        from repro.api import compile_stencil, from_operator
        heat = from_operator("diffusion", ndim=3, alpha=0.1)
        prog = compile_stencil(heat, (64, 64, 64), t=2)
    """
    try:
        build = OPERATORS[kind]
    except KeyError:
        raise ValueError(f"unknown operator {kind!r}; choose from "
                         f"{sorted(OPERATORS)}") from None
    return build(**params)


# ------------------------------------------------------------ CLI adapters --
def parse_taps(text: str):
    """Parse a JSON tap list ``[[[dz, dy, dx], coeff], ...]`` (offsets of
    any supported arity) into the tuple form ``define_stencil`` takes.

        from repro.api import define_stencil, parse_taps
        spec = define_stencil(parse_taps('[[[0,0],0.6],[[0,1],0.4]]'))
    """
    try:
        raw = json.loads(text)
    except ValueError as e:
        raise ValueError(
            f"--taps is JSON like '[[[0,0],0.6],[[0,1],0.1],...]': {e}"
        ) from None
    if not isinstance(raw, list):
        raise ValueError(f"--taps must be a JSON list of [offset, coeff] "
                         f"pairs, got {type(raw).__name__}")
    taps = []
    for item in raw:
        if (not isinstance(item, list) or len(item) != 2
                or not isinstance(item[0], list)):
            raise ValueError(
                f"each tap is [offset, coeff] (e.g. [[0,1], 0.25]); "
                f"got {item!r}")
        off, c = item
        if any(o != int(o) for o in off):
            raise ValueError(
                f"tap offset {off} has non-integer components; offsets "
                "are integer grid displacements")
        taps.append((tuple(int(o) for o in off), float(c)))
    return tuple(taps)


def spec_from_json(source) -> StencilSpec:
    """Build a spec from a JSON object (or a path to one):

        {"taps": [[[0,0],0.6],...], "name": "mine", "normalize": true,
         "domain": [4096, 4096], "flops_per_cell": 10, "a_sm": 6,
         "a_sm_rst": 4, "a_gm": 2.0}

    ``taps`` is required (or ``"operator": {"kind": "diffusion", ...}``);
    everything else is optional — omitted cost-model fields are derived
    from the tap structure.

    A JSON object with a ``"fields"`` key is a coupled *system* spec and
    dispatches to :func:`repro.systems.system_from_json`, returning a
    :class:`~repro.systems.spec.SystemSpec` (compile it with
    ``repro.systems.compile_system`` — guide: ``docs/systems.md``).
    """
    if isinstance(source, str):
        with open(source) as f:
            obj = json.load(f)
    else:
        obj = dict(source)
    if "fields" in obj:
        from repro.systems import system_from_json
        return system_from_json(obj)
    if "operator" in obj:
        op = dict(obj["operator"])
        if "kind" not in op:
            raise ValueError(
                "spec JSON 'operator' object needs a 'kind' key, e.g. "
                '{"operator": {"kind": "diffusion", "ndim": 2}}; choose '
                f"from {sorted(OPERATORS)}")
        return from_operator(op.pop("kind"), **op)
    if "taps" not in obj:
        raise ValueError("spec JSON needs a 'taps' list (or an 'operator' "
                         "object); see repro.api.define.spec_from_json")
    taps = parse_taps(json.dumps(obj["taps"]))
    kw = {k: obj[k] for k in ("name", "normalize", "flops_per_cell",
                              "a_sm", "a_sm_rst", "a_gm") if k in obj}
    if "domain" in obj:
        kw["domain"] = tuple(int(d) for d in obj["domain"])
    return define_stencil(taps, **kw)
