"""First-class boundary conditions for stencil programs.

The seed kernels hard-coded one boundary: zero Dirichlet ("cells outside
the domain read as 0 at every step"), realized for free by the tap
engine's zero-fill slicing.  ``Boundary`` makes the condition an explicit
compile-time property of a :class:`~repro.api.program.StencilProgram`,
with three kinds:

  * ``Boundary.dirichlet(v)`` — cells outside the domain read as the
    constant ``v`` at every step.  ``v = 0`` is the seed semantics and
    the fast path (the padded layout is closed under it, DESIGN.md §9.3).
    ``v ≠ 0`` is run *exactly* through the zero-Dirichlet kernels via the
    affine closure ``u_t = Z_t(u_0 − v) + v·s^t`` (``Z_t`` = t
    zero-Dirichlet steps, ``s`` = tap sum — DESIGN.md §11.3), which is
    exact when ``s = 1`` (normalized sets: a constant field is a fixed
    point, so the classic constant shift holds at any depth) or when the
    chain is one step deep (``t = 1`` sweeps, re-shifted per sweep — how
    unnormalized user stencils run).  Checked at compile time; other
    (s ≠ 1, t ≥ 2) combinations fail with the fixes spelled out.
  * ``Boundary.periodic()`` — the domain wraps (torus).  Executed by
    deep-halo ghost pinning: extend the field by ``halo = t·rad`` wrapped
    cells, run the zero-Dirichlet kernel on the extended domain, crop.
    The zero-fill corruption at the extended edge travels one radius per
    step and reaches exactly the domain boundary after ``t`` steps — the
    interior is exact (the §9.3 error-zone argument, pointed outward).
  * ``Boundary.reflect()`` — mirror boundary (``ghost(−k) = u(k)``,
    ``jnp.pad mode='reflect'``).  Same ghost-pinning execution; exact
    when the tap set is mirror-symmetric per axis (the mirrored exterior
    then evolves as the mirror of the interior), which all nine Table-2
    sets are.  Checked at compile time.
  * ``Boundary.neumann(flux=0.0)`` — flux boundary: the outward normal
    derivative at every domain face is ``flux``, discretized as the
    face-mirror ghost fill ``ghost(−k) = u(k−1) + k·flux`` (``jnp.pad
    mode='symmetric'`` plus a linear ramp; zero-flux insulation by
    default).  Ghost-pinning execution like periodic/reflect; the
    one-fill-per-sweep chain is exact for mirror-symmetric taps at zero
    flux (any depth), and for any taps/flux at ``t = 1`` (ghosts
    re-pinned every step).  Other depth/tap combinations are refused at
    compile time with the fixes spelled out (``taps.check_boundary``).

Because the padded layout is only closed under *zero Dirichlet*, the
multi-sweep executor re-pins the ghost halo once per sweep for
periodic/reflect programs (the boundary-aware §9.3 contract — see
DESIGN.md §10); Dirichlet programs of either value keep the zero-copy
pad-once/crop-once path.

The low-level mechanics (ghost extension, the shift/extend/crop wrapper,
and the compatibility checks) live in ``repro.kernels.taps`` so the
kernels and the oracle share them without depending on this package.
"""
from __future__ import annotations

import dataclasses

from repro.kernels.taps import check_boundary

KINDS = ("dirichlet", "periodic", "reflect", "neumann")


@dataclasses.dataclass(frozen=True)
class Boundary:
    """A boundary condition: ``kind`` ∈ {dirichlet, periodic, reflect,
    neumann}.

    Immutable and hashable — it is part of every program/runner cache key
    and is passed to the jitted kernels as a static argument.

        from repro.api import Boundary, compile_stencil
        prog = compile_stencil(spec, (512, 512), t=4,
                               boundary=Boundary.periodic())
        y = prog.run(x, 64)         # torus domain, validated at compile
    """

    kind: str
    value: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown boundary kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.kind in ("periodic", "reflect") and self.value != 0.0:
            raise ValueError(f"{self.kind} boundary takes no value")

    # ----------------------------------------------------- constructors ----
    @staticmethod
    def dirichlet(value: float = 0.0) -> "Boundary":
        """Constant ``value`` outside the domain at every time step."""
        return Boundary("dirichlet", float(value))

    @staticmethod
    def periodic() -> "Boundary":
        """Wrap-around (torus) domain."""
        return Boundary("periodic")

    @staticmethod
    def reflect() -> "Boundary":
        """Mirror boundary: ``ghost(-k) = u(k)`` about the edge cell."""
        return Boundary("reflect")

    @staticmethod
    def neumann(flux: float = 0.0) -> "Boundary":
        """Flux boundary: outward normal derivative = ``flux`` at every
        face (``ghost(-k) = u(k-1) + k·flux``; zero-flux insulation by
        default).  ``value`` stores the flux."""
        return Boundary("neumann", float(flux))

    # ------------------------------------------------------- predicates ----
    @property
    def is_zero_dirichlet(self) -> bool:
        return self.kind == "dirichlet" and self.value == 0.0

    def validate_for(self, spec, t: int | None = None) -> None:
        """Raise ``ValueError`` if a ``t``-step chain of ``spec`` cannot
        run under this boundary exactly (the affine Dirichlet closure
        needs unit tap sum OR depth-1 sweeps for a non-zero value;
        reflect needs mirror-symmetric taps — DESIGN.md §11.3)."""
        check_boundary(spec.taps, self, t)

    def __repr__(self) -> str:  # compact, key-friendly
        if self.kind == "dirichlet":
            return f"Boundary.dirichlet({self.value:g})"
        if self.kind == "neumann" and self.value != 0.0:
            return f"Boundary.neumann({self.value:g})"
        return f"Boundary.{self.kind}()"


ZERO = Boundary.dirichlet(0.0)
