"""``StencilProgram``: the compile-once front door for temporal blocking.

EBISU's pitch (paper §6) is *plan once, then drive aggressive deep
blocking tile-by-tile*.  This module is where that contract lives:
``compile_stencil`` resolves the §6 plan, the launch geometry, and the
boundary-condition execution strategy exactly once, and hands back an
immutable :class:`StencilProgram` whose runners are built and memoized
per launch signature — every other entry point in the repo
(``ops.ebisu_stencil``, ``sweep.run_sweeps``, ``ops.launch_geometry``)
is a thin shim over a program, so there is exactly ONE geometry/dispatch
resolution path.

    prog = compile_stencil(get("j3d7pt"), (256, 288, 384), t=4)
    y   = prog.run(x, T=64)          # T steps as chained zero-copy sweeps
    ys  = prog.run_batched(xs, T=64) # leading batch axis, one vmapped runner

Execution surface:

  * ``apply(x, t=None)``   — one temporally-blocked sweep.
  * ``run(x, T)``          — a ``T``-step simulation as chained sweeps;
    subsumes the zero-copy multi-sweep executor (DESIGN.md §9.3: pad
    once / crop once / dispatch once for Dirichlet boundaries, per-sweep
    ghost re-pin for periodic/reflect — DESIGN.md §10).
  * ``run_padded(xp, T)``  — the 2-D padded-layout chain with a donated
    carry (XLA ping-pongs two buffers where the backend supports it).
  * ``run_batched(xs, T=None)`` — leading batch axis via one vmapped
    padded runner (a single jitted dispatch for the whole batch).
  * ``geometry(t=None)`` / ``cost(t=None)`` / ``cache_stats()`` —
    introspection: the launch the kernels will resolve, the §5 roofline
    estimate, and the hit/miss counters of the bounded caches.

All module-global state is held in explicit bounded :class:`ProgramCache`
instances (LRU + counters + ``clear()``) — no unbounded module dicts.
Importing this module never initializes a JAX backend (checked by
``scripts/tier1.sh``): backend questions are answered at compile time,
not import time.
"""
from __future__ import annotations

import functools
import math
import threading
import warnings
from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.api.boundary import ZERO, Boundary
from repro.core import roofline as rl
from repro.core.planner import (EbisuPlan, fit_streaming_batch,
                                plan as make_plan, vmem_required_2d)
from repro.core.stencil_spec import (StencilSpec, lift_2d_to_3d,
                                     validate_spec)
from repro.kernels.stencil2d import (ebisu2d, ebisu2d_padded,
                                     padded_shape_2d, strip_geometry)
from repro.kernels.stencil3d import (_pad_to, ebisu3d, ebisu3d_padded,
                                     launch_geometry_3d, padded_shape_3d,
                                     xy_tile)
from repro.kernels.taps import ghost_extend, tap_sum

# plan-less fallback tiles (the request defaults the legacy entry points
# used; programs compiled without an explicit plan resolve one instead)
DEFAULT_BH_2D = 128
DEFAULT_ZC_3D = 16
DEFAULT_ZC_STREAM_2D = 64

_BUCKET = 64


# =========================================================== ProgramCache ==
class ProgramCache:
    """Bounded LRU cache with hit/miss/eviction counters — the explicit
    replacement for the module-global plan/launch dicts the executor used
    to hide state in.  Eviction only drops memoization: handles already
    returned stay valid.

    Thread-safe: the serving front door (``repro.serve``) dispatches from
    an event loop plus worker threads, so get/put/LRU bookkeeping run
    under a per-cache ``RLock``.  ``get_or_build`` holds the lock across
    the build — two threads racing on the same missing key build ONCE and
    observe the same value, instead of double-building and corrupting the
    LRU order.  (Builds here are plan derivations and ``jax.jit`` wrapper
    construction — cheap and non-reentrant on the same cache, so holding
    the lock is safe; tracing happens at first *call*, outside the lock.)

        c = ProgramCache(maxsize=2, name="demo")
        c.get_or_build("k", lambda: 42)    # -> 42 (miss, built)
        c.get("k"), c.stats()["hits"]      # -> 42, 1
    """

    def __init__(self, maxsize: int = 128, name: str = ""):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        self.name = name
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._lock = threading.RLock()
        self._d: OrderedDict = OrderedDict()

    def get(self, key, default=None):
        with self._lock:
            try:
                val = self._d[key]
            except KeyError:
                self.misses += 1
                return default
            self._d.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)
                self.evictions += 1

    def get_or_build(self, key, build):
        """Return the cached value, building (and caching) it on miss —
        atomically: concurrent callers of the same missing key get the
        one built value."""
        sentinel = object()
        with self._lock:
            val = self.get(key, sentinel)
            if val is sentinel:
                val = build()
                self.put(key, val)
            return val

    def clear(self) -> None:
        """Drop all memoization (counted as evictions — the retry path in
        ``repro.serve`` reads the delta to classify eviction races)."""
        with self._lock:
            self.evictions += len(self._d)
            self._d.clear()

    def stats(self) -> dict:
        with self._lock:
            return {"name": self.name, "size": len(self._d),
                    "maxsize": self.maxsize, "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._d


PROGRAM_CACHE = ProgramCache(64, "programs")   # compile_stencil results
PLAN_CACHE = ProgramCache(256, "plans")        # §6 plans, shape-bucketed
RUNNER_CACHE = ProgramCache(128, "runners")    # jitted runners per launch


def cache_stats() -> dict:
    """Hit/miss/size counters for all three bounded caches.

        from repro.api import cache_stats, clear_caches
        cache_stats()["plans"]   # {'name': 'plans', 'size': ..., ...}
        clear_caches()           # drop memoization (handles stay valid)
    """
    return {c.name: c.stats()
            for c in (PROGRAM_CACHE, PLAN_CACHE, RUNNER_CACHE)}


def clear_caches() -> None:
    for c in (PROGRAM_CACHE, PLAN_CACHE, RUNNER_CACHE):
        c.clear()


def plan_bucketed(spec: StencilSpec, shape: tuple[int, ...],
                  hw: rl.HardwareModel = rl.TPU_V5E) -> EbisuPlan:
    """§6 plan memoized per (tap structure, 64-rounded domain, hardware)
    in the bounded ``PLAN_CACHE`` — a simulation loop over near-identical
    domains plans once per bucket.  Keyed on ``spec.signature`` (the tap
    set plus the cost-model numbers), NOT the registry name: user-defined
    specs plan without any registry lookup, and two differently-named
    specs with identical structure share one plan.

        p = plan_bucketed(get("j2d5pt"), (512, 512))
        p.t, p.block          # §6.2 depth, §6.4 tile
    """
    bucket = tuple(_pad_to(d, _BUCKET) for d in shape)
    key = (spec.signature, bucket, hw.name)
    return PLAN_CACHE.get_or_build(
        key, lambda: make_plan(spec, hw, domain=bucket))


# ======================================================= geometry / sweep ==
# The ONE place tile/grid/pad geometry is resolved (kernel rounding
# included) and the ONE place a sweep dispatches to a kernel.  ops.py and
# sweep.py delegate here.

def _tile_request(spec: StencilSpec, t: int, plan: EbisuPlan | None,
                  mode: str) -> dict:
    """The tile request a launch resolves from the plan (or the legacy
    request defaults), pre-kernel-rounding — the ONE derivation shared by
    geometry introspection and dispatch, so `prog.geometry()` can never
    drift from the tile `apply` actually launches."""
    halo = spec.halo(t)
    if spec.ndim == 2 and mode != "stream":
        bh = plan.block[0] if plan is not None else max(DEFAULT_BH_2D, halo)
        return dict(bh=max(bh, halo))
    if spec.ndim == 2:                   # stream mode: lifted 3-D launch
        zc = plan.block[0] if plan is not None else \
            max(DEFAULT_ZC_STREAM_2D, halo)
        return dict(zc=max(zc, halo),
                    tx=plan.block[1] if plan is not None else None)
    zc = plan.block[0] if plan is not None else max(DEFAULT_ZC_3D, halo)
    return dict(zc=max(zc, halo),
                ty=plan.block[1] if plan is not None else None,
                tx=plan.block[2] if plan is not None else None)


def resolve_geometry(spec: StencilSpec, t: int, shape: tuple[int, ...], *,
                     plan: EbisuPlan | None = None,
                     mode: str = "fused") -> dict:
    """The geometry a one-sweep launch with these args will execute.

    Resolves the same tile/grid the kernels resolve (rounding included),
    so modeled traffic is derived from the launch that actually runs —
    not from the plan-less default tile (``fetched_cells``/``body_cells``
    are the halo-exact input cells and output cells per grid step).

        g = resolve_geometry(get("j2d5pt"), 4, (512, 512))
        g["grid"], g["block"], g["halo"]    # what apply() will launch
    """
    req = _tile_request(spec, t, plan, mode)
    if spec.ndim == 2 and mode != "stream":
        bh, halo = strip_geometry(spec, t, req["bh"])
        hp, wp = padded_shape_2d(spec, t, bh, *shape)
        return dict(grid=(hp // bh,), block=(bh, shape[1]), halo=halo,
                    padded=(hp, wp),
                    fetched_cells=(bh + 2 * halo) * wp,
                    body_cells=bh * wp)
    if spec.ndim == 2:                   # stream mode: lifted 3-D geometry
        return launch_geometry_3d(lift_2d_to_3d(spec), t,
                                  (shape[0], 1, shape[1]), **req)
    return launch_geometry_3d(spec, t, shape, **req)


def sweep_once(x: jnp.ndarray, spec: StencilSpec, t: int, *,
               plan: EbisuPlan | None = None, mode: str = "fused",
               interpret: bool = True,
               boundary: Boundary | None = None,
               compute_dtype=None) -> jnp.ndarray:
    """One temporally-blocked sweep — the sole plan→kernel dispatch path.

    When a §6 plan is supplied, its decisions are wired all the way into
    the kernels: tile height/chunk depth (``plan.block``), streaming
    batch (``plan.lazy_batch``) and DMA pipeline depth
    (``plan.parallelism.num_buffers``) — none of the planner's outputs
    are decorative.  ``compute_dtype`` (default float32) is the dtype of
    the padded compute buffers the kernels run on.
    """
    lazy = plan.lazy_batch if plan is not None else None
    nbuf = plan.parallelism.num_buffers if plan is not None else None
    b = None if boundary is None or boundary.is_zero_dirichlet else boundary
    req = _tile_request(spec, t, plan, mode)
    if spec.ndim == 2:
        if mode == "stream":
            # the paper's 2-D scheme: stream y through the multi-queue
            # (no overlapped halo along the streamed dim); the planner's
            # §6.4 tile width (plan.block[1]) tiles x with overlapped halo.
            # The boundary is resolved before lifting (the size-1 lifted
            # axis must not be ghost-extended).
            if b is not None:
                from repro.kernels.taps import check_boundary, with_boundary
                check_boundary(spec.taps, b, t)
                return with_boundary(
                    x, 2, spec.halo(t), b,
                    lambda v: sweep_once(v, spec, t, plan=plan, mode=mode,
                                         interpret=interpret,
                                         compute_dtype=compute_dtype),
                    taps=spec.taps, t=t)
            y = ebisu3d(x[:, None, :], lift_2d_to_3d(spec), t,
                        lazy_batch=lazy, num_buffers=nbuf,
                        interpret=interpret, compute_dtype=compute_dtype,
                        **req)
            return y[:, 0, :]
        return ebisu2d(x, spec, t, mode=mode, num_buffers=nbuf,
                       interpret=interpret, boundary=b,
                       compute_dtype=compute_dtype, **req)
    return ebisu3d(x, spec, t, lazy_batch=lazy, num_buffers=nbuf,
                   interpret=interpret, boundary=b,
                   compute_dtype=compute_dtype, **req)


# ===================================================== multi-sweep runner ==
def sweep_schedule(total_t: int, t: int) -> tuple[int, ...]:
    """Per-sweep depths covering ``total_t`` steps: full-depth sweeps plus
    one shallower remainder sweep when ``t`` does not divide ``total_t``.

        sweep_schedule(10, 4)    # -> (4, 4, 2)
        sweep_schedule(8, 4)     # -> (4, 4)
    """
    assert total_t >= 0 and t >= 1
    q, r = divmod(total_t, t)
    return (t,) * q + ((r,) if r else ())


def _grouped(schedule: tuple[int, ...]) -> list[tuple[int, int]]:
    """Runs of equal depth: [(depth, count), ...] — one layout per run."""
    out: list[list[int]] = []
    for d in schedule:
        if out and out[-1][0] == d:
            out[-1][1] += 1
        else:
            out.append([d, 1])
    return [(d, c) for d, c in out]


def _budget(hw: rl.HardwareModel) -> float:
    return hw.onchip_device_bytes or hw.onchip_bytes


def _sweep_tile_2d(spec: StencilSpec, t: int, shape: tuple[int, int],
                   hw: rl.HardwareModel, plan: EbisuPlan,
                   interpret: bool = False) -> int:
    """Widest strip the §6 VMEM model affords (§6.4: wider before deeper),
    halving toward the plan's tile when the whole domain does not fit.

    ``interpret``: skip the widening entirely and keep the plan's own
    tile.  The §6.4 growth exists to fill real VMEM; the interpreter has
    none, and growing the strip past the plan's block is a measured
    superlinear pessimization on single-threaded CPU hosts (the
    pre-existing ``sweep/j2d5pt-T24`` bench regression — DESIGN.md §17).
    """
    height, width = shape
    halo = spec.halo(t)
    nbuf = plan.parallelism.num_buffers
    floor = max(min(plan.block[0], height), halo)
    if interpret:
        bh, _ = strip_geometry(spec, t, floor)
        return bh
    bh, _ = strip_geometry(spec, t, max(height, halo))
    while (vmem_required_2d(spec, t, bh, width, hw.s_cell, nbuf)
           > _budget(hw) and bh // 2 >= floor):
        bh, _ = strip_geometry(spec, t, bh // 2)
    return bh


def _sweep_tile_3d(spec: StencilSpec, t: int, shape: tuple[int, int, int],
                   hw: rl.HardwareModel, plan: EbisuPlan,
                   interpret: bool = False
                   ) -> tuple[int, int | None, int | None, int]:
    """Deepest z chunk — and the streaming batch — the §6 VMEM model
    affords at the plan's xy tile.  The batch is fitted with the
    planner's own ``fit_streaming_batch``, so the executor never
    launches a configuration the shared model says does not fit: at the
    plan's own (zc, depth) the planner already proved one exists, and an
    off-plan depth too deep for the budget raises instead of silently
    over-committing on-chip memory.  ``interpret`` starts from the
    plan's own chunk instead of the whole domain (see
    :func:`_sweep_tile_2d` — the VMEM-filling growth is a pessimization
    where there is no VMEM)."""
    zdim, ydim, xdim = shape
    halo = spec.halo(t)
    nbuf = plan.parallelism.num_buffers
    ty, tx = plan.block[1], plan.block[2]
    ty_r, tiled_y = xy_tile(spec, t, ydim, ty)
    tx_r, tiled_x = xy_tile(spec, t, xdim, tx)
    ny = ty_r + 2 * halo if tiled_y else ydim
    nx = tx_r + 2 * halo if tiled_x else xdim

    def fit_batch(zc_c: int) -> int | None:
        return fit_streaming_batch(spec, t, zc_c, ny, nx, hw.s_cell,
                                   nbuf, _budget(hw))

    zc = _pad_to(max(zdim, halo), halo)
    floor = min(zc, _pad_to(max(min(plan.block[0], zdim), halo), halo))
    if interpret:
        zc = floor
    batch = fit_batch(zc)
    while batch is None and zc > floor:
        zc = max(floor, _pad_to(zc // 2, halo))
        batch = fit_batch(zc)
    if batch is None:
        raise ValueError(
            f"{spec.name}: depth t={t} at xy tile ({ny}, {nx}) does not fit "
            f"the {hw.name} on-chip budget even at zc={zc} with a one-halo "
            f"batch — lower t toward the plan's depth ({plan.t})")
    return zc, (ty if tiled_y else None), (tx if tiled_x else None), batch


def _supports_donation() -> bool:
    return jax.default_backend() in ("tpu", "gpu")


def _build_chain(spec: StencilSpec, shape: tuple[int, ...], dtype,
                 total_t: int, depth: int, plan: EbisuPlan,
                 hw: rl.HardwareModel, mode: str, interpret: bool,
                 boundary: Boundary, compute_dtype=None,
                 batched: bool = False):
    """The multi-sweep schedule as an un-jitted f(x) -> x (DESIGN.md §9.3).

    Zero Dirichlet: the zero-copy padded chain — pad once per depth
    group, chain the padded kernel, crop once.  dirichlet(v), normalized
    taps: the same chain under the exact constant shift (still
    zero-copy).  dirichlet(v), tap sum s ≠ 1: the affine closure
    ``u' = Z_1(u − v) + v·s`` re-applied around every (depth-1) sweep —
    ``check_boundary`` guarantees no deeper sweep reaches this branch.
    periodic/reflect: the padded layout is NOT closed under the boundary,
    so each sweep re-pins the ghost halo from the evolved field and runs
    the zero-Dirichlet core on the extended domain (DESIGN.md §10).

    All compute buffers are ``compute_dtype`` (the program's policy —
    default float32); only the final result is cast to the program's
    storage ``dtype``.
    """
    groups = _grouped(sweep_schedule(total_t, depth))
    nbuf = plan.parallelism.num_buffers
    # interpret-mode strip floor (§17): the plan's own tile beats grown
    # strips on a single-threaded host — EXCEPT under vmap, where the
    # per-strip mask machinery is multiplied by the batch width and the
    # grown strip measures faster; batched chains keep the §6.4 growth
    tile_interp = interpret and not batched
    repin = boundary.kind in ("periodic", "reflect", "neumann")
    cdtype = jnp.dtype(compute_dtype) if compute_dtype else jnp.float32
    s = tap_sum(spec.taps)
    # per-sweep affine re-shift (s != 1): shift inside the sweep loop;
    # constant shift (s == 1): once around the whole chain (zero-copy)
    affine = (boundary.kind == "dirichlet" and boundary.value != 0.0
              and abs(s - 1.0) > 1e-6)
    shift = boundary.value if boundary.kind == "dirichlet" else 0.0

    def halo_of(d: int) -> int:
        return spec.halo(d) if repin else 0

    def pre(v, d):
        """Domain field -> sweep input, per sweep."""
        if affine:
            return v - jnp.asarray(shift, cdtype)
        return v

    def post(v, d):
        """Sweep output -> domain field, per sweep."""
        if affine:
            return v + jnp.asarray(shift * s ** d, cdtype)
        return v

    if spec.ndim == 2:
        height, width = shape

        def ext(d: int) -> tuple[int, int]:
            return height + 2 * halo_of(d), width + 2 * halo_of(d)

        cfg = {d: (_sweep_tile_2d(spec, d, ext(d), hw, plan, tile_interp),)
               for d, _ in groups}

        def chain(v: jnp.ndarray) -> jnp.ndarray:
            for d, count in groups:
                (bh,) = cfg[d]
                he, we = ext(d)
                halo = halo_of(d)
                hp, wp = padded_shape_2d(spec, d, bh, he, we)

                def sweep(xp, d=d, bh=bh, he=he, we=we):
                    return ebisu2d_padded(xp, spec, d, height=he, width=we,
                                          bh=bh, mode=mode,
                                          num_buffers=nbuf,
                                          interpret=interpret)

                if repin or affine:
                    # layout not closed under the boundary: re-pin the
                    # ghost halo (periodic/reflect) or re-apply the
                    # affine shift (unnormalized Dirichlet) every sweep
                    for _ in range(count):
                        xp = jnp.zeros((hp, wp), cdtype).at[:he, :we].set(
                            ghost_extend(pre(v, d), 2, halo, boundary)
                            if repin else pre(v, d))
                        xp = sweep(xp)
                        v = post(xp[halo:halo + height,
                                    halo:halo + width], d)
                else:
                    # zero-copy: pad once, chain, crop once (§9.3)
                    xp = jnp.zeros((hp, wp), cdtype).at[
                        :height, :width].set(v)
                    for _ in range(count):
                        xp = sweep(xp)
                    v = xp[:height, :width]
            return v
    else:
        zdim, ydim, xdim = shape

        def ext3(d: int) -> tuple[int, int, int]:
            h = halo_of(d)
            return zdim + 2 * h, ydim + 2 * h, xdim + 2 * h

        cfg = {d: _sweep_tile_3d(spec, d, ext3(d), hw, plan, tile_interp)
               for d, _ in groups}

        def chain(v: jnp.ndarray) -> jnp.ndarray:
            for d, count in groups:
                zc, ty, tx, batch = cfg[d]
                ze, ye, xe = ext3(d)
                halo = halo_of(d)
                zp, yp, xp_ = padded_shape_3d(spec, d, (ze, ye, xe), zc=zc,
                                              ty=ty, tx=tx)

                def sweep(xp, d=d, zc=zc, ty=ty, tx=tx, batch=batch,
                          ze=ze, ye=ye, xe=xe):
                    return ebisu3d_padded(xp, spec, d, zdim=ze, ydim=ye,
                                          xdim=xe, zc=zc, ty=ty, tx=tx,
                                          lazy_batch=batch,
                                          num_buffers=nbuf,
                                          interpret=interpret)

                if repin or affine:
                    for _ in range(count):
                        xp = jnp.zeros((zp, yp, xp_), cdtype).at[
                            :ze, :ye, :xe].set(
                                ghost_extend(pre(v, d), 3, halo, boundary)
                                if repin else pre(v, d))
                        xp = sweep(xp)
                        v = post(xp[halo:halo + zdim, halo:halo + ydim,
                                    halo:halo + xdim], d)
                else:
                    xp = jnp.zeros((zp, yp, xp_), cdtype).at[
                        :zdim, :ydim, :xdim].set(v)
                    for _ in range(count):
                        xp = sweep(xp)
                    v = xp[:zdim, :ydim, :xdim]
            return v

    if boundary.kind == "dirichlet" and boundary.value != 0.0 and not affine:
        def run(x):
            w = x.astype(cdtype) - shift
            return (chain(w) + shift).astype(dtype)
    else:
        def run(x):
            return chain(x.astype(cdtype)).astype(dtype)

    return run


# ------------------------------------------- 2-D donated padded carry ------
def _padded_chain_2d(xp, spec, total_t, *, t, height, width, bh, mode,
                     num_buffers, interpret):
    assert total_t % t == 0, "padded chaining needs a uniform sweep depth"
    for _ in range(total_t // t):
        xp = ebisu2d_padded(xp, spec, t, height=height, width=width, bh=bh,
                            mode=mode, num_buffers=num_buffers,
                            interpret=interpret)
    return xp


@functools.lru_cache(maxsize=None)
def _padded_runner_2d(donate: bool):
    return jax.jit(_padded_chain_2d,
                   static_argnames=("spec", "total_t", "t", "height",
                                    "width", "bh", "mode", "num_buffers",
                                    "interpret"),
                   donate_argnums=(0,) if donate else ())


def run_sweeps_padded(xp: jnp.ndarray, spec: StencilSpec, total_t: int, *,
                      t: int, height: int, width: int, bh: int,
                      mode: str = "fused", num_buffers: int | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """Padded-layout sweep chain (2-D, zero Dirichlet), ``t | total_t``.

    The caller owns the padded buffer and the layout never changes, so
    the carry is donated where the backend supports it — XLA ping-pongs
    two buffers across sweeps instead of allocating per sweep
    (DESIGN.md §9.3).  The donation choice is made at call time so
    importing this module never initializes a JAX backend."""
    return _padded_runner_2d(_supports_donation())(
        xp, spec, total_t, t=t, height=height, width=width, bh=bh,
        mode=mode, num_buffers=num_buffers, interpret=interpret)


# ============================================================== programs ==
def _plan_key(plan: EbisuPlan | None):
    if plan is None:
        return None
    return (plan.hw_name, plan.t, plan.block, plan.lazy_batch,
            plan.parallelism.num_buffers)


class StencilProgram:
    """An immutable compiled stencil: spec + domain shape + §6 plan +
    boundary + launch mode (+ optional device mesh), with memoized
    runners.  Construct via :func:`compile_stencil`:

        prog = compile_stencil(get("j2d5pt"), (512, 512), t=4)
        y  = prog.apply(x)            # one temporally-blocked sweep
        y  = prog.run(x, 64)          # 64 steps under one jit
        ys = prog.run_batched(xs, 64) # leading batch axis, one dispatch
    """

    def __init__(self, key, spec: StencilSpec, shape: tuple[int, ...],
                 dtype, t: int, plan: EbisuPlan | None,
                 hw: rl.HardwareModel, boundary: Boundary, mode: str,
                 interpret: bool, compute_dtype=None, mesh=None,
                 tuned: dict | None = None):
        self._key = key
        self.spec = spec
        self.shape = shape
        self.dtype = dtype
        self.t = t
        self.plan = plan
        self.hw = hw
        self.boundary = boundary
        self.mode = mode
        self.interpret = interpret
        self.mesh = mesh
        self.compute_dtype = (jnp.dtype(compute_dtype) if compute_dtype
                              else jnp.float32)
        # provenance of a mode="tuned" resolution: {"source": "plandb",
        # "record": ...} on a DB hit, {"source": "analytic_fallback"} on
        # a miss, None for programs compiled with an explicit mode
        self.tuned = tuned

    # ------------------------------------------------------- execution ----
    def _check(self, x, batched: bool = False):
        want = ((-1,) + self.shape) if batched else self.shape
        if x.ndim != len(want) or any(
                w != -1 and n != w for n, w in zip(x.shape, want)):
            raise ValueError(
                f"program compiled for shape {self.shape} "
                f"({'batched ' if batched else ''}got {x.shape}); "
                "compile_stencil a new program for a new domain shape")

    def apply(self, x: jnp.ndarray, t: int | None = None) -> jnp.ndarray:
        """One temporally-blocked sweep of depth ``t`` (default: the
        program's compiled depth).

            y = prog.apply(x)        # == t plain steps, one memory pass
            y = prog.apply(x, t=2)   # off-plan depth, separately cached
        """
        self._check(x)
        depth = self.t if t is None else t
        if depth < 1:
            raise ValueError(f"temporal depth must be >= 1, got {depth} "
                             "(run(x, 0) is the identity)")
        fn = RUNNER_CACHE.get_or_build(
            (self._key, "apply", depth),
            lambda: jax.jit(functools.partial(
                sweep_once, spec=self.spec, t=depth, plan=self.plan,
                mode=self.mode, interpret=self.interpret,
                boundary=self.boundary,
                compute_dtype=self.compute_dtype)))
        return fn(x)

    def _run_fn(self, total_t: int, batched: bool = False):
        plan = self.plan or plan_bucketed(self.spec, self.shape, self.hw)
        depth = max(1, min(self.t, total_t))
        if self.spec.ndim == 2 and self.mode not in ("fused", "scratch"):
            raise ValueError(
                f"run supports 2-D modes 'fused'/'scratch', got "
                f"{self.mode!r} (use apply for the lifted 'stream' path)")
        return _build_chain(self.spec, self.shape, self.dtype, total_t,
                            depth, plan, self.hw, self.mode,
                            self.interpret, self.boundary,
                            compute_dtype=self.compute_dtype,
                            batched=batched)

    def run(self, x: jnp.ndarray, total_t: int) -> jnp.ndarray:
        """``total_t`` steps as chained temporally-blocked sweeps under a
        single cached jit — the zero-copy executor (remainder sweep
        included when the program depth does not divide ``total_t``).

            prog = compile_stencil(spec, x.shape, t=4)
            y = prog.run(x, 64)     # 16 sweeps: pad once, chain, crop
            y = prog.run(x, 10)     # sweeps of depth 4, 4, then 2
        """
        self._check(x)
        if total_t == 0:
            return x
        fn = RUNNER_CACHE.get_or_build(
            (self._key, "run", total_t),
            lambda: jax.jit(self._run_fn(total_t)))
        return fn(x)

    def run_batched(self, xs: jnp.ndarray,
                    total_t: int | None = None) -> jnp.ndarray:
        """A leading batch axis of independent fields through ONE vmapped
        padded runner — a single jitted dispatch for the whole batch,
        instead of a Python loop of per-field launches.

            xs = jnp.stack([x0, x1, x2])        # (3, *prog.shape)
            ys = prog.run_batched(xs, 64)       # one dispatch, 3 fields
        """
        self._check(xs, batched=True)
        total_t = self.t if total_t is None else total_t
        if total_t == 0:
            return xs
        fn = RUNNER_CACHE.get_or_build(
            (self._key, "batched", total_t),
            lambda: jax.jit(jax.vmap(self._run_fn(total_t, batched=True))))
        return fn(xs)

    def run_sharded(self, x: jnp.ndarray, total_t: int) -> jnp.ndarray:
        """``total_t`` steps over the program's device mesh, exchanging
        deep ghost zones **once per temporal block** instead of once per
        step (DESIGN.md §12; guide: ``docs/sharding.md``).

        Each device holds one uniform shard (mesh axis ``k`` over tensor
        dim ``k``); per block of depth ``d``, neighbor shards swap
        ``d·radius``-deep halo slabs (one ``ppermute`` round per sharded
        dim, corners via two hops) and run the trapezoid-narrowed chain
        locally.  The whole schedule — remainder block included — is one
        cached jit; the operand buffer is donated to it on backends that
        support donation (pass ``x.copy()`` to keep ``x`` alive there).
        A mesh of total size 1 falls back transparently to :meth:`run`.

            prog = compile_stencil(spec, (256, 512), t=4, mesh=(2, 4))
            y = prog.run_sharded(x, 64)       # 16 exchange rounds, not 64

        Requires a program compiled with ``mesh=``; the output is a
        global ``jax.Array`` sharded like the input placement.
        """
        self._check(x)
        if self.mesh is None:
            raise ValueError(
                "run_sharded needs a mesh-compiled program: "
                "compile_stencil(spec, shape, mesh=(2, 4)) or mesh=8 — "
                "see docs/sharding.md")
        if total_t == 0:
            return x
        if self.mesh.size == 1:                 # 1-device mesh: no seams
            return self.run(x, total_t)
        from repro.api import sharded
        fn = RUNNER_CACHE.get_or_build(
            (self._key, "sharded", total_t),
            lambda: jax.jit(
                sharded.build_sharded_runner(self, total_t),
                donate_argnums=(0,) if _supports_donation() else ()))
        xs = jax.device_put(x, sharded.operand_sharding(self))
        return fn(xs)

    def run_padded(self, xp: jnp.ndarray, total_t: int) -> jnp.ndarray:
        """Uniform-depth padded-layout chain with a donated carry (2-D,
        zero Dirichlet, ``t | total_t``); see :func:`run_sweeps_padded`.
        The caller owns the ``padded_shape`` buffer across calls."""
        if (self.spec.ndim != 2 or not self.boundary.is_zero_dirichlet
                or self.mode not in ("fused", "scratch")):
            raise ValueError("run_padded is the 2-D zero-Dirichlet "
                             "padded-carry path (fused/scratch); use run()")
        if xp.dtype != self.compute_dtype:
            raise ValueError(
                f"run_padded carry is the compute buffer: expected dtype "
                f"{self.compute_dtype.name}, got {xp.dtype.name} "
                "(the caller owns the padded buffer at the program's "
                "compute_dtype)")
        bh = self.geometry()["block"][0]
        return run_sweeps_padded(
            xp, self.spec, total_t, t=self.t, height=self.shape[0],
            width=self.shape[1], bh=bh, mode=self.mode,
            num_buffers=(self.plan.parallelism.num_buffers
                         if self.plan else None),
            interpret=self.interpret)

    # ----------------------------------------------- resumable campaigns ----
    def run_resumable(self, x, total_t: int, *, store, every: int = 1,
                      **kwargs):
        """``total_t`` steps as checkpointed legs of ``every`` temporal
        blocks, resumable after a crash and **bit-exact** equal to
        :meth:`run` (guide: ``docs/resilience.md``).

            store = CampaignStore("/ckpt/heat2d")
            y = prog.run_resumable(x, 512, store=store, every=2)
            # ... SIGKILL mid-campaign ...
            y = prog.run_resumable(x, 512, store=store)   # picks up

        Keyword knobs (``policy=``, ``health=``, ``faults=``, ``clock=``,
        ``resume=``, ``on_leg=``) pass through to
        :func:`repro.resilient.runner.run_campaign`; returns its
        :class:`~repro.resilient.runner.CampaignReport` (the final field
        is ``report.result``).
        """
        from repro.resilient import runner
        return runner.run_campaign(self, x, total_t, store=store,
                                   every=every, sharded=False, **kwargs)

    def run_sharded_resumable(self, x, total_t: int, *, store,
                              every: int = 1, **kwargs):
        """The sharded twin of :meth:`run_resumable`: checkpointed legs
        of :meth:`run_sharded` over the program's mesh, plus elastic
        restore onto a smaller mesh when a device drops (the default
        ``RetryPolicy(elastic=True)``)."""
        if self.mesh is None:
            raise ValueError(
                "run_sharded_resumable needs a mesh-compiled program: "
                "compile_stencil(spec, shape, mesh=(2, 4)) — "
                "see docs/sharding.md")
        from repro.resilient import runner
        return runner.run_campaign(self, x, total_t, store=store,
                                   every=every, sharded=True, **kwargs)

    # ---------------------------------------------------- introspection ----
    def fingerprint(self) -> dict:
        """A JSON-safe identity card for checkpoint manifests: what a
        resumed campaign must match bit-for-bit (spec signature, shape,
        dtypes, boundary, depth, mode, hw) plus what may drift only
        elastically (mesh, plan) — see ``repro.resilient.store``."""
        return {
            "spec_name": self.spec.name,
            "spec_signature": repr(self.spec.signature),
            "shape": list(self.shape),
            "dtype": self.dtype.name,
            "compute_dtype": self.compute_dtype.name,
            "boundary": repr(self.boundary),
            "t": int(self.t),
            "mode": self.mode,
            "hw": self.hw.name,
            "plan": repr(_plan_key(self.plan)),
            "mesh": (None if self.mesh is None
                     else {k: int(v) for k, v in self.mesh.shape.items()}),
        }

    def compute_shape(self, t: int | None = None) -> tuple[int, ...]:
        """The domain the kernels actually compute: the program shape,
        ghost-extended by ``t·rad`` per side for re-pinning boundaries."""
        depth = self.t if t is None else t
        if self.boundary.kind in ("periodic", "reflect", "neumann"):
            h = self.spec.halo(depth)
            return tuple(n + 2 * h for n in self.shape)
        return self.shape

    def geometry(self, t: int | None = None) -> dict:
        """The launch geometry a depth-``t`` sweep resolves (tile, grid,
        halo, padded layout, halo-exact fetched/body cells)."""
        depth = self.t if t is None else t
        return resolve_geometry(self.spec, depth, self.compute_shape(depth),
                                plan=self.plan, mode=self.mode)

    def cost(self, t: int | None = None) -> rl.RooflineResult:
        """§5 practical-attainable estimate at depth ``t``.  At the plan's
        own depth this is the plan's prediction (redundancy/sync valid
        fractions included); off-plan depths get the ideal-V roofline."""
        depth = self.t if t is None else t
        if self.plan is not None and depth == self.plan.t:
            return self.plan.pp
        return rl.attainable(self.spec, depth, self.hw, rst=True,
                             d_all=math.prod(self.shape))

    def cache_stats(self) -> dict:
        """Counters of the module's bounded caches (programs, plans,
        runners) — see :func:`cache_stats`."""
        return cache_stats()

    def __repr__(self) -> str:
        mesh = (f", mesh={dict(self.mesh.shape)}" if self.mesh is not None
                else "")
        return (f"StencilProgram({self.spec.name}, shape={self.shape}, "
                f"t={self.t}, boundary={self.boundary!r}, "
                f"mode={self.mode!r}, hw={self.hw.name}, "
                f"dtype={self.dtype.name}/{self.compute_dtype.name}, "
                f"interpret={self.interpret}{mesh})")


def resolve_compute_dtype(dtype, compute_dtype=None):
    """The program dtype policy: compute in ``compute_dtype`` when given,
    else in the storage dtype promoted to at least float32 (bf16/f16
    fields are stored narrow but stepped in f32 — one rounding at the
    end instead of one per sweep; f64 storage computes in f64).

        resolve_compute_dtype(jnp.bfloat16)              # -> float32
        resolve_compute_dtype(jnp.float32, jnp.float64)  # -> float64
    """
    if compute_dtype is not None:
        cd = jnp.dtype(compute_dtype)
        if not jnp.issubdtype(cd, jnp.floating):
            raise ValueError(
                f"compute_dtype must be a floating dtype, got {cd.name}")
        return cd
    d = jnp.dtype(dtype)
    if not jnp.issubdtype(d, jnp.floating):
        raise ValueError(
            f"stencil cell dtype must be floating, got {d.name} "
            "(pass dtype=jnp.float32/bfloat16/... to compile_stencil)")
    return jnp.promote_types(d, jnp.float32)


def compile_stencil(spec: StencilSpec, shape: tuple[int, ...], *,
                    dtype=jnp.float32, t: int | None = None,
                    hw: rl.HardwareModel = rl.TPU_V5E,
                    boundary: Boundary | None = None, mode: str = "fused",
                    interpret: bool | None = None,
                    plan: EbisuPlan | None | str = "auto",
                    compute_dtype=None, mesh=None,
                    plan_db=None) -> StencilProgram:
    """Compile a stencil to an immutable :class:`StencilProgram`.

        from repro.api import Boundary, compile_stencil
        from repro.core.stencil_spec import get
        prog = compile_stencil(get("j3d7pt"), (256, 288, 384), t=4,
                               boundary=Boundary.periodic())
        y = prog.run(x, 64)

    Accepts ANY validated :class:`StencilSpec` — the Table-2 registry and
    ``repro.api.define_stencil`` products are equals here: the plan is
    derived from the tap structure (``plan_bucketed`` keys on
    ``spec.signature``), never from a registry lookup.

    Resolves — exactly once — the §6 plan (shape-bucketed, memoized),
    the boundary execution strategy (validated against the tap set *and*
    the chain depth: the affine Dirichlet closure, DESIGN.md §11.3), the
    dtype policy (``dtype`` is cell storage; ``compute_dtype`` — default
    storage promoted to ≥ f32 — is what the kernels and the multi-sweep
    chain run in), and the interpret/lowering choice (Pallas-TPU on TPU
    backends, interpreter elsewhere).  Programs are memoized in the
    bounded ``PROGRAM_CACHE``; recompiling with identical arguments
    returns the same handle.

    ``t`` is the per-sweep temporal depth (default: the plan's §6.2
    choice).  ``plan`` is normally derived ("auto"); pass an explicit
    ``EbisuPlan`` to pin tiles (autotuning), or ``None`` for the legacy
    request-default tiles the deprecated entry points used.

    ``mode="tuned"`` resolves (t, block, lazy_batch, kernel family) from
    the persistent plan DB (``repro.tuning``, guide in
    ``docs/tuning.md``): a DB hit replays the *measured* winner with
    zero search or timing; a miss falls back to the analytic plan
    (``mode="fused"``) — run ``repro.tuning.tune(...)`` or ``python -m
    repro.tuning sweep`` to warm the DB.  Either way ``prog.tuned``
    records the provenance.  ``plan_db`` is a ``PlanDB``, a directory
    path, or ``None`` for the default location; it is only consulted
    for ``mode="tuned"``.

    ``mesh`` (a ``jax.sharding.Mesh``, an int, or a tuple — mesh axis
    ``k`` shards tensor dim ``k``) makes the program multi-device: the §6
    plan is resolved **per shard** (domain/mesh, since each device sees
    one shard plus its ``t·radius`` block halo), shard uniformity and
    halo-fit are validated here with the fix spelled out, and
    :meth:`StencilProgram.run_sharded` becomes available
    (DESIGN.md §12, guide in ``docs/sharding.md``)::

        prog = compile_stencil(spec, (256, 512), t=4, mesh=(2, 4))
        y = prog.run_sharded(x, 64)     # one halo exchange per 4 steps
    """
    validate_spec(spec)
    shape = tuple(int(n) for n in shape)
    if len(shape) != spec.ndim:
        raise ValueError(f"{spec.name} is {spec.ndim}-D; got shape {shape}")
    tuned_info = None
    if mode == "tuned":
        # plan resolution only: the DB record supplies depth, block,
        # batch AND the kernel family — explicit overrides would make
        # the record a lie, so they are refused with the fix spelled out
        if t is not None:
            raise ValueError(
                "mode='tuned' resolves t from the plan DB; drop t= "
                "(or compile mode='fused' with an explicit t to pin "
                "depth yourself)")
        if not (isinstance(plan, str) and plan == "auto"):
            raise ValueError(
                "mode='tuned' resolves the plan from the plan DB; drop "
                "plan= (pass an explicit EbisuPlan with mode='fused'/"
                "'scratch' to pin tiles yourself)")
        if mesh is not None:
            raise ValueError(
                "mode='tuned' records are single-device measurements; "
                "compile mesh= programs with an explicit mode (the "
                "per-shard plan is derived analytically)")
        from repro.tuning import plandb as _plandb
        itp = (interpret if interpret is not None
               else jax.default_backend() != "tpu")
        rec = _plandb.resolve_db(plan_db).lookup(
            spec, shape, "interpret" if itp else "native")
        if rec is not None:
            plan = _plandb.plan_from_record(spec, shape, hw, rec)
            t = plan.t
            mode = rec["plan"]["exec_mode"]
            tuned_info = {"source": "plandb", "record": rec}
        else:
            mode = "fused"
            tuned_info = {"source": "analytic_fallback"}
    valid_modes = ("fused", "scratch", "stream") if spec.ndim == 2 \
        else ("fused", "scratch")        # 3-D ignores scratch (seed compat)
    if mode not in valid_modes:
        raise ValueError(f"unknown mode {mode!r} for a {spec.ndim}-D spec; "
                         f"expected one of {valid_modes}")
    boundary = ZERO if boundary is None else boundary
    cdtype = resolve_compute_dtype(dtype, compute_dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.api import sharded as _sharded
    mesh = _sharded.resolve_mesh(mesh, spec.ndim)
    plan_shape = shape
    if mesh is not None:
        # shard uniformity first (depth-1 halo fit is a subset of the
        # full-depth check below), then the per-shard planning pass:
        # each device is one big tile — plan for the shard it owns, not
        # the global domain (DESIGN.md §12)
        _sharded.validate_mesh_for(spec, shape, mesh, 1, boundary)
        plan_shape = _sharded.shard_extents(shape, mesh)
    if isinstance(plan, str):
        if plan != "auto":
            raise ValueError(f"plan must be an EbisuPlan, None, or 'auto'; "
                             f"got {plan!r}")
        plan = plan_bucketed(spec, plan_shape, hw)
    depth = t if t is not None else (plan.t if plan is not None else 1)
    if depth < 1:
        raise ValueError(f"temporal depth must be >= 1, got {depth}")
    boundary.validate_for(spec, t=depth)
    if mesh is not None:
        _sharded.validate_mesh_for(spec, shape, mesh, depth, boundary)
    key = (spec, shape, jnp.dtype(dtype).name, depth, hw.name,
           boundary, mode, bool(interpret), _plan_key(plan), cdtype.name,
           _sharded.mesh_key(mesh),
           None if tuned_info is None else ("tuned", tuned_info["source"]))
    cached = PROGRAM_CACHE.get(key)
    if cached is not None:
        return cached
    prog = StencilProgram(key, spec, shape, jnp.dtype(dtype), depth, plan,
                          hw, boundary, mode, bool(interpret),
                          compute_dtype=cdtype, mesh=mesh,
                          tuned=tuned_info)
    PROGRAM_CACHE.put(key, prog)
    return prog


def deprecated_entry(name: str, replacement: str) -> None:
    """One-per-call-site deprecation notice for the legacy entry points
    (policy in README.md: shims stay for two PR cycles, geometry/dispatch
    already lives here).

    Emitted strictly at *call* time, never at import time — importing
    ``repro.kernels.ops`` / ``repro.kernels.sweep`` stays silent, so
    modules that merely transit the legacy names (test collection,
    introspection) produce no warnings; ``benchmarks/`` drives
    ``repro.api`` directly and emits none at all.
    """
    warnings.warn(f"{name} is deprecated; use {replacement} "
                  "(repro.api) instead", DeprecationWarning, stacklevel=3)
