"""Serving driver: batched prefill + greedy decode for any decoder arch."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.api.attention import attention_cache_stats, attention_program_for
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.models.params import tree_init, tree_shardings
from repro.serve import serve_step as serve


def run(arch: str, *, batch: int = 4, prompt_len: int = 32,
        max_new: int = 16, reduced: bool = True, n_data: int = 1,
        n_model: int = 1, seed: int = 0, repeats: int = 3):
    cfg = C.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    assert cfg.family != "encoder", "encoder-only archs do not decode"
    mesh = make_host_mesh(n_data, n_model)
    cfg = cfg.with_mesh(mesh)
    key = jax.random.PRNGKey(seed)
    params = tree_init(transformer.param_defs(cfg), key, cfg.param_dtype)
    cache_len = prompt_len + max_new + (
        cfg.vlm_patches if cfg.family == "vlm" else 0) + 8

    prompt = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                           cfg.vocab)}
    if cfg.family == "vlm":
        prompt["patches"] = jax.random.normal(
            key, (batch, cfg.vlm_patches, cfg.vlm_patch_dim),
            cfg.activ_dtype)

    # Warm the attention program handle before tracing: prefill dispatches
    # through the compile-once AttentionProgram (repro.api.attention).
    if cfg.family != "ssm" and cfg.attention_impl != "boundary_stub":
        attention_program_for(cfg)
    prefill = jax.jit(serve.make_prefill(cfg, cache_len))
    decode = jax.jit(serve.make_decode_step(cfg), donate_argnums=(1,))
    pos = prompt_len + (cfg.vlm_patches if cfg.family == "vlm" else 0)
    with mesh:
        # warmup dispatch: compile prefill AND a decode step outside the
        # timed region — the first call pays jit, not the model
        tok, cache = prefill(params, prompt)
        tok, cache = decode(params, cache, tok[:, None], jnp.int32(pos))
        tok.block_until_ready()

        # min-of-N: shared-machine contamination is one-sided, so the
        # fastest pass is the least-contaminated one (bench protocol,
        # see benchmarks/common.time_fn).  Decode donates the cache, so
        # every pass re-prefills to get a fresh one.
        t_prefill = t_decode = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            tok, cache = prefill(params, prompt)
            tok.block_until_ready()
            t_prefill = min(t_prefill, time.perf_counter() - t0)
            toks = [tok]
            t0 = time.perf_counter()
            for i in range(max_new - 1):
                tok, cache = decode(params, cache, tok[:, None],
                                    jnp.int32(pos + i))
                toks.append(tok)
            tok.block_until_ready()
            t_decode = min(t_decode, time.perf_counter() - t0)
    out = jnp.stack(toks, axis=1)
    print(f"[serve] {arch}: prefill {batch}x{prompt_len} in "
          f"{t_prefill*1e3:.1f}ms; {max_new-1} decode steps in "
          f"{t_decode*1e3:.1f}ms "
          f"({(max_new-1)*batch/max(t_decode,1e-9):.1f} tok/s, "
          f"best of {max(1, repeats)})", flush=True)
    stats = attention_cache_stats()["attention_programs"]
    print(f"[serve] attention programs: {stats['size']} compiled, "
          f"{stats['hits']} cache hits", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--n-model", type=int, default=1)
    args = ap.parse_args()
    run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
        max_new=args.max_new, reduced=not args.full, n_data=args.n_data,
        n_model=args.n_model)


if __name__ == "__main__":
    main()
