"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's 512-placeholder-
device bootstrap to stay isolated from tests and benchmarks.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType, Mesh


def _mk(shape, axes) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devs[:n])


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips) mesh.

    Axes: 'pod' (cross-pod data parallel, DCN-friendly — it only ever carries
    the once-per-step gradient all-reduce), 'data' (in-pod DP + ZeRO shards),
    'model' (tensor/expert parallel + stencil domain decomposition).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests/examples (axis_types pinned to Auto)."""
    return _mk(tuple(shape), tuple(axes))


def make_host_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Smoke-test mesh over whatever devices the host actually has."""
    n = len(jax.devices())
    assert n_data * n_model <= n, (n_data, n_model, n)
    return _mk((n_data, n_model), ("data", "model"))
