"""Mesh construction for distributed/sharded execution.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's 512-placeholder-
device bootstrap to stay isolated from tests and benchmarks, and for the
``import repro.api`` backend-free gate (``scripts/tier1.sh``).

Two families of meshes live here:

  * the production LM meshes (``make_production_mesh``) — pod/data/model
    axes for the training/serving drivers;
  * stencil domain meshes (``make_stencil_mesh``) — one mesh axis per
    sharded *tensor* dimension, consumed by
    ``repro.api.compile_stencil(..., mesh=)`` / ``run_sharded``
    (DESIGN.md §12).  Axis ``shard<k>`` shards tensor dim ``k``.

Faked multi-device CPU (how every multi-device path in this repo is
tested and CI-smoked) — set **before** the first device query::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

or from Python, before touching any device::

    from repro.launch.mesh import ensure_fake_devices
    ensure_fake_devices(8)

Version compatibility: ``jax.sharding.AxisType`` (explicit-sharding axis
annotations) only exists in newer jax; on the pinned 0.4.37 toolchain the
meshes are built without axis types, which is the classic (fully ``Auto``)
behavior the shard_map paths assume anyway.
"""
from __future__ import annotations

import math
import os

import jax
import numpy as np
from jax.sharding import Mesh


def ensure_fake_devices(n: int) -> None:
    """Request >= ``n`` faked CPU devices (idempotent; must run before
    the JAX backend initializes — i.e. before any ``jax.devices()``
    call).

    Appends ``--xla_force_host_platform_device_count=n`` to
    ``XLA_FLAGS``; an existing device-count flag is kept when it already
    grants >= ``n`` devices and raised to ``n`` otherwise (other flags
    are preserved either way).

        from repro.launch.mesh import ensure_fake_devices
        ensure_fake_devices(4)            # then: import-time-lazy jax use
        assert len(jax.devices()) >= 4
    """
    import re

    n = int(n)
    flags = os.environ.get("XLA_FLAGS", "")
    pat = r"--xla_force_host_platform_device_count=(\d+)"
    m = re.search(pat, flags)
    if m:
        if int(m.group(1)) >= n:
            return
        flags = re.sub(pat,
                       f"--xla_force_host_platform_device_count={n}", flags)
    else:
        flags = f"{flags} --xla_force_host_platform_device_count={n}".strip()
    os.environ["XLA_FLAGS"] = flags


def _mk(shape, axes) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
    try:  # newer jax: pin axis types explicitly
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devs[:n])
    except ImportError:  # jax 0.4.x: no AxisType — plain (Auto) mesh
        return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips) mesh.

    Axes: 'pod' (cross-pod data parallel, DCN-friendly — it only ever carries
    the once-per-step gradient all-reduce), 'data' (in-pod DP + ZeRO shards),
    'model' (tensor/expert parallel + stencil domain decomposition).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests/examples."""
    return _mk(tuple(shape), tuple(axes))


def make_stencil_mesh(shape) -> Mesh:
    """A domain-decomposition mesh for ``compile_stencil(..., mesh=)``.

    Mesh axis ``k`` (named ``shard<k>``) shards tensor dimension ``k`` of
    the stencil domain; axes of size 1 leave their dimension unsharded.
    Devices are taken in ``jax.devices()`` order, so on a faked-CPU host
    this is deterministic.

        mesh = make_stencil_mesh((2, 4))       # 8 devices: dims 0 and 1
        prog = compile_stencil(spec, (256, 512), t=4, mesh=mesh)
        y = prog.run_sharded(x, 64)
    """
    shape = tuple(int(n) for n in shape)
    if not shape or any(n < 1 for n in shape):
        raise ValueError(f"mesh shape must be positive ints, got {shape}")
    return _mk(shape, tuple(f"shard{k}" for k in range(len(shape))))


def make_host_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Smoke-test mesh over whatever devices the host actually has."""
    n = len(jax.devices())
    assert n_data * n_model <= n, (n_data, n_model, n)
    return _mk((n_data, n_model), ("data", "model"))
