"""End-to-end trainer: --arch <id> --steps N, with fault-tolerant restart.

Runs on whatever devices exist (CPU smoke: 1 device; TPU pod: the production
mesh).  Features exercised here and tested in tests/test_train_driver.py:

  * deterministic data pipeline with host prefetch (train/data.py);
  * periodic async checkpointing, atomic rename, --resume auto picks up the
    latest step after a crash — and reshards onto a *different* mesh if the
    world changed (elastic restart);
  * straggler mitigation: data is a pure function of (seed, step), so a
    replaced host needs no coordination to rejoin at the right step.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.models.params import tree_abstract, tree_init, tree_shardings
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.data import Prefetcher, batch_for_step
from repro.train.train_step import make_train_step


def reduced_shapes(cfg, batch: int, seq: int):
    i32 = jnp.int32
    if cfg.family == "encoder":
        return {"frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.float32),
                "mask": jax.ShapeDtypeStruct((batch, seq), jnp.bool_),
                "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    out = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32),
           "labels": jax.ShapeDtypeStruct((batch, seq), i32)}
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vlm_patches, cfg.vlm_patch_dim), jnp.float32)
    return out


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 50, resume: str = "auto", seed: int = 0,
          n_data: int = 1, n_model: int = 1, lr: float = 3e-4,
          log_every: int = 10, schedule_steps: int | None = None):
    cfg = C.get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(n_data, n_model)
    cfg = cfg.with_mesh(mesh)
    horizon = schedule_steps or steps   # keep LR schedule invariant across
    ocfg = opt.OptConfig(lr=lr,          # crash-restart runs of one job
                         warmup=min(20, horizon // 10 + 1),
                         total_steps=horizon, schedule=cfg.schedule)

    pdefs = transformer.param_defs(cfg)
    odefs = opt.opt_state_defs(pdefs, data_size=cfg.mesh_dp)
    p_sh = tree_shardings(pdefs, mesh)
    o_sh = tree_shardings(odefs, mesh)

    start = 0
    if ckpt_dir and resume == "auto" and (s := ckpt.latest_step(ckpt_dir)):
        like = {"params": tree_abstract(pdefs, cfg.param_dtype),
                "opt": tree_abstract(odefs)}
        tree = ckpt.restore(ckpt_dir, s, like,
                            shardings={"params": p_sh, "opt": o_sh})
        params, state = tree["params"], tree["opt"]
        start = s
        print(f"[train] resumed step {s} from {ckpt_dir}", flush=True)
    else:
        key = jax.random.PRNGKey(seed)
        params = tree_init(pdefs, key, cfg.param_dtype)
        state = tree_init(odefs, key)

    # out_shardings pin the updated params back to their logical specs —
    # without them the ZeRO update leaks the moments' 'data' sharding into
    # params and step 2 violates in_shardings (multi-device only)
    step_fn = jax.jit(make_train_step(cfg, ocfg),
                      in_shardings=(p_sh, o_sh, None),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))
    shapes = reduced_shapes(cfg, batch, seq)
    pf = Prefetcher(cfg, "train_4k", start_step=start, seed=seed,
                    reduced_shapes=shapes)
    losses = []
    t0 = time.time()
    try:
        with mesh:
            for i in range(start, steps):
                step_idx, b = pf.next()
                assert step_idx == i
                params, state, metrics = step_fn(params, state, b)
                losses.append(float(metrics["loss"]))
                if i % log_every == 0 or i == steps - 1:
                    print(f"[train] step {i} loss {losses[-1]:.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({(time.time()-t0):.1f}s)", flush=True)
                if ckpt_dir and (i + 1) % ckpt_every == 0:
                    ckpt.save(ckpt_dir, i + 1,
                              {"params": params, "opt": state})
    finally:
        pf.close()
    if ckpt_dir:
        ckpt.save(ckpt_dir, steps, {"params": params, "opt": state},
                  block=True)
    return params, state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--n-model", type=int, default=1)
    args = ap.parse_args()
    _, _, losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=not args.full, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume, lr=args.lr,
        n_data=args.n_data, n_model=args.n_model)
    print(f"[train] done: first-loss {losses[0]:.4f} last-loss "
          f"{losses[-1]:.4f}")


if __name__ == "__main__":
    main()
