"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST keep the next two statements first — jax locks the device count at
first initialization, and only the dry-run may see 512 placeholder devices.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (CI smoke override — still before any jax import:)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse     # noqa: E402
import json         # noqa: E402
import math         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.configs as C                      # noqa: E402
from repro.analysis import hlo_cost            # noqa: E402
from repro.core import roofline as rl          # noqa: E402
from repro.launch.mesh import make_production_mesh, make_mesh  # noqa: E402
from repro.models import transformer           # noqa: E402
from repro.models.params import (tree_abstract, tree_shardings)  # noqa: E402
from repro.serve import serve_step as serve    # noqa: E402
from repro.train import optimizer as opt       # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

HW = rl.TPU_V5E


# --------------------------------------------------------------- programs --
def lower_cell(cfg, shape_name: str, mesh, attn_impl: str | None = None,
               sharding: str | None = None, ssm_impl: str | None = None):
    """Lower + compile one (arch × shape) cell on ``mesh``; returns
    (lowered, compiled, meta)."""
    import dataclasses
    if sharding:
        cfg = dataclasses.replace(cfg, sharding=sharding)
    cfgm = cfg.with_mesh(mesh)
    if attn_impl:
        cfgm = dataclasses.replace(cfgm, attention_impl=attn_impl)
    if ssm_impl:
        cfgm = dataclasses.replace(cfgm, ssm_impl=ssm_impl)
    info = C.SHAPES[shape_name]
    kind, b, s = info["kind"], info["batch"], info["seq"]
    pdefs = transformer.param_defs(cfgm)
    p_abs = tree_abstract(pdefs, cfgm.param_dtype)
    p_sh = tree_shardings(pdefs, mesh)
    batch_abs = cfgm.input_specs(shape_name)
    batch_sh = {k: NamedSharding(mesh, v)
                for k, v in cfgm.input_pspecs(shape_name).items()}

    if kind == "train":
        ocfg = opt.OptConfig(schedule=cfgm.schedule)
        odefs = opt.opt_state_defs(pdefs, data_size=cfgm.mesh_dp)
        o_abs = tree_abstract(odefs)
        o_sh = tree_shardings(odefs, mesh)
        fn = make_train_step(cfgm, ocfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, batch_sh),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))
        with mesh:
            lowered = jfn.lower(p_abs, o_abs, batch_abs)
    elif kind == "prefill":
        fn = serve.make_prefill(cfgm, cache_len=s)
        jfn = jax.jit(fn, in_shardings=(p_sh, batch_sh))
        with mesh:
            lowered = jfn.lower(p_abs, batch_abs)
    else:  # decode
        cdefs = transformer.cache_defs(cfgm, b, s)
        c_abs = tree_abstract(cdefs, cfgm.activ_dtype)
        c_sh = tree_shardings(cdefs, mesh)
        fn = serve.make_decode_step(cfgm)
        tok_sh = NamedSharding(
            mesh, P(cfgm.dp_axes if b % max(1, cfgm.mesh_dp) == 0
                    and b >= cfgm.mesh_dp > 1 else None, None))
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, None),
                      donate_argnums=(1,))
        with mesh:
            lowered = jfn.lower(p_abs, c_abs,
                                jax.ShapeDtypeStruct((b, 1), jnp.int32),
                                jax.ShapeDtypeStruct((), jnp.int32))
    t0 = time.time()
    compiled = lowered.compile()
    return lowered, compiled, {"compile_s": time.time() - t0, "kind": kind,
                               "tokens": b * s if kind != "decode" else b,
                               "cfg": cfgm}


def model_flops(cfg, shape_name: str) -> float:
    """Analytic 6·N·D (train) / 2·N·D (inference) FLOPs, N = active params."""
    info = C.SHAPES[shape_name]
    n = cfg.n_active_params()
    tokens = (info["batch"] * info["seq"]
              if info["kind"] != "decode" else info["batch"])
    return (6.0 if info["kind"] == "train" else 2.0) * n * tokens


def roofline_terms(cost: hlo_cost.HloCost, n_chips: int, mesh_axes):
    """Per-chip three-term roofline (numerators are per-device = global/chips
    for SPMD programs)."""
    t_comp = cost.dot_flops / HW.mxu_flops
    t_mem = cost.bytes_accessed / HW.b_gm
    links = HW.b_ici * max(1, HW.ici_links // 2)
    t_coll = cost.total_wire_bytes / links
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return terms, dom


# ---------------------------------------------------------------- stencil --
def run_stencil_cell(spec_name: str, mesh, t_block: int | None = None,
                     inner: str = "jnp"):
    from repro.core.distributed import make_distributed_stencil
    from repro.core.planner import plan
    from repro.core.stencil_spec import get
    spec = get(spec_name)
    pl = plan(spec, HW)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp = dp if len(dp) > 1 else dp[0]
    dp_size = math.prod(v for k, v in axes.items() if k in ("pod", "data"))
    mdl = axes.get("model", 1)
    dim_to_axis = {0: dp, 1: "model"} if spec.ndim == 2 else \
        {0: dp, 1: "model"}
    # round the domain up so every sharded dim divides its axis
    dom = list(spec.domain)
    dom[0] = math.ceil(dom[0] / dp_size) * dp_size
    dom[1] = math.ceil(dom[1] / mdl) * mdl
    tb = t_block or max(1, min(pl.t, dom[0] // dp_size // spec.radius,
                               dom[1] // mdl // spec.radius))
    t_total = int(os.environ.get("REPRO_STENCIL_TTOTAL", 0)) or tb * 2
    assert t_total % tb == 0
    fn, pspec = make_distributed_stencil(spec, mesh, dim_to_axis,
                                         tuple(dom), t_total, tb,
                                         inner=inner)
    x_abs = jax.ShapeDtypeStruct(tuple(dom), jnp.float32)
    with mesh:
        lowered = fn.lower(x_abs)
    t0 = time.time()
    compiled = lowered.compile()
    meta = {"compile_s": time.time() - t0, "kind": "stencil",
            "tokens": math.prod(dom) * t_total, "t_block": tb,
            "t_total": t_total, "domain": dom}
    return lowered, compiled, meta


# ------------------------------------------------------------------- main --
def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, outdir: str,
             attn_impl: str | None = None, sharding: str | None = None,
             ssm_impl: str | None = None):
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_chips": int(n_chips)}
    if attn_impl:
        rec["mesh"] = mesh_name = f"{mesh_name}-{attn_impl}"
    if sharding:
        rec["mesh"] = mesh_name = f"{mesh_name}-{sharding}"
    if ssm_impl:
        rec["mesh"] = mesh_name = f"{mesh_name}-ssmstub"
    try:
        if arch == "stencil-suite":
            lowered, compiled, meta = run_stencil_cell(
                shape_name, mesh,
                t_block=int(os.environ.get("REPRO_STENCIL_TBLOCK", 0)) or None,
                inner=os.environ.get("REPRO_STENCIL_INNER", "jnp"))
            rec["t_block"] = meta["t_block"]
            from repro.core.stencil_spec import get
            spec = get(shape_name)
            rec["model_flops"] = (spec.flops_per_cell * meta["tokens"])
        else:
            cfg = C.get_config(arch)
            ok, why = cfg.supports(shape_name)
            if not ok:
                rec.update(status="skipped", reason=why)
                _write(outdir, rec)
                return rec
            lowered, compiled, meta = lower_cell(cfg, shape_name, mesh,
                                                 attn_impl, sharding,
                                                 ssm_impl)
            rec["model_flops"] = model_flops(cfg, shape_name)
        ma = compiled.memory_analysis()
        # jax returns one dict per program executable here on some
        # versions (a list); normalize to the entry-point dict
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        cost = hlo_cost.analyze(compiled.as_text())
        terms, dom = roofline_terms(cost, n_chips, mesh.axis_names)
        mf_chip = rec["model_flops"] / n_chips
        peak = HW.mxu_flops
        if arch == "stencil-suite":
            # stencils run on the VPU (elementwise FMA, no dots): both the
            # compute term and the roofline use the VPU peak
            peak = HW.thr_cmp
            terms["compute_s"] = mf_chip / peak
        dom = max(terms, key=terms.get)
        step_time = max(terms.values())
        rec.update(
            status="ok",
            compile_s=round(meta["compile_s"], 2),
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                code_bytes=int(ma.generated_code_size_in_bytes),
                peak_per_device=int(ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
            ),
            cost_analysis_raw=dict(
                flops=float(ca.get("flops", -1)),
                bytes_accessed=float(ca.get("bytes accessed", -1)),
            ),
            hlo=cost.as_dict(),
            terms=terms,
            dominant=dom,
            roofline_fraction=(mf_chip / peak) / step_time
            if step_time > 0 else None,
            useful_flops_ratio=(mf_chip / cost.dot_flops
                                if cost.dot_flops else None),
            hbm_ok=bool(ma.argument_size_in_bytes + ma.temp_size_in_bytes
                        - ma.alias_size_in_bytes < HW.hbm_bytes),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _write(outdir, rec)
    return rec


def _write(outdir, rec):
    os.makedirs(outdir, exist_ok=True)
    path = os.path.join(outdir,
                        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    t = rec.get("terms", {})
    print(f"[{rec['status']:7s}] {rec['arch']:24s} {rec['shape']:12s} "
          f"{rec['mesh']:6s} compile={rec.get('compile_s', '-')}s "
          f"dom={rec.get('dominant', '-')} "
          f"roofline={rec.get('roofline_fraction') and round(rec['roofline_fraction'], 3)} "
          f"{rec.get('reason', '') or rec.get('error', '')[:120] if rec['status']=='error' else rec.get('reason','')}",
          flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both", "smoke"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--attn", default=None,
                    choices=[None, "flash_jnp", "boundary_stub"])
    ap.add_argument("--sharding", default=None, choices=[None, "tp", "fsdp"])
    ap.add_argument("--ssm", default=None,
                    choices=[None, "chunked_jnp", "boundary_stub"])
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))
    if args.mesh == "smoke":
        n = jax.device_count()
        meshes.append(("smoke", make_mesh((max(1, n // 4), 4),
                                          ("data", "model"))))

    archs = (C.list_archs() if args.arch == "all" else args.arch.split(","))
    for mesh_name, mesh in meshes:
        for arch in archs:
            if arch == "stencil-suite":
                from repro.core.stencil_spec import names
                shapes = names() if args.shape == "all" \
                    else args.shape.split(",")
            else:
                shapes = (list(C.SHAPES) if args.shape == "all"
                          else args.shape.split(","))
            for shape in shapes:
                run_cell(arch, shape, mesh, mesh_name, args.out, args.attn,
                         args.sharding, args.ssm)


if __name__ == "__main__":
    main()
