"""Serving driver: synthetic Poisson traffic through the stencil service.

Generates a seeded arrival process over a mix of stencil specs, shapes,
step counts and tenants, optionally weaving in every fault kind the
service defends against (NaN inputs, oversized shapes, already-expired
deadlines, forced cache evictions, simulated OOM, delayed dispatch), and
drives :class:`~repro.serve.stencil_service.ServiceCore` on a simulated
clock — the run is **deterministic**: same flags, same outcome mix.

The exit code is the robustness assertion CI leans on (tier1.yml serve
smoke): 0 iff zero unhandled exceptions escaped the request path AND
every request resolved to a result or a typed error.  The stats report
is printed either way.

    PYTHONPATH=src python -m repro.launch.serve_stencil --requests 200 \\
        --faults --seed 7
    PYTHONPATH=src python -m repro.launch.serve_stencil --requests 50 \\
        --rate 500 --guard reject

``--asyncio`` runs the same traffic through the real-clock asyncio front
door (:class:`StencilService`) instead — non-deterministic timings, same
resolution guarantees."""
from __future__ import annotations

import argparse
import random
import sys

import jax.numpy as jnp

from repro.core.stencil_spec import get
from repro.serve.faults import (FaultConfig, FaultInjector, HEALTHY)
from repro.serve.stencil_service import (ServeError, ServeRequest,
                                         ServiceConfig, ServiceCore,
                                         SimClock, StencilService)
from repro.stencils.data import init_domain

# the served mix: 2-D and 3-D, radius 1 and 2, two shapes per spec —
# enough bucket diversity to exercise coalescing without dwarfing the
# CPU-interpret budget of a CI smoke
MIX = (
    ("j2d5pt", ((16, 20), (24, 16))),
    ("j2d9pt", ((20, 20),)),
    ("j3d7pt", ((8, 8, 6),)),
)
TENANTS = ("alice", "bob", "carol", "mallory")


def synth_requests(n: int, rng: random.Random, inj: FaultInjector | None,
                   rate_hz: float, max_cells: int, total_t: int = 4):
    """The seeded arrival tape: ``[(arrival_ms, ServeRequest, kind)]``.

    Poisson arrivals (exponential gaps at ``rate_hz``); each request's
    fault kind is drawn from the injector's traffic rates (``'healthy'``
    when faults are off) and shapes the request accordingly."""
    out, t_ms = [], 0.0
    for i in range(n):
        t_ms += rng.expovariate(rate_hz) * 1e3
        name, shapes = MIX[rng.randrange(len(MIX))]
        spec = get(name)
        shape = shapes[rng.randrange(len(shapes))]
        kind = inj.classify_request() if inj is not None else HEALTHY
        x = init_domain(spec, shape, seed=rng.randrange(1 << 20))
        deadline = None
        if kind == "nan_input":
            x = x.at[tuple(0 for _ in shape)].set(jnp.nan)
        elif kind == "oversized":
            # rank-correct but over the admission cell cap
            side = int(max_cells ** (1 / spec.ndim)) + 2
            shape = tuple(side for _ in range(spec.ndim))
            x = jnp.zeros(shape, jnp.float32)
        elif kind == "expired":
            deadline = 0.0
        out.append((t_ms, ServeRequest(spec, x, total_t=total_t,
                                       tenant=rng.choice(TENANTS),
                                       deadline_ms=deadline), kind))
    return out


def drive_sim(core: ServiceCore, tape) -> list:
    """Replay the arrival tape on the core's sim clock: advance to each
    arrival, submit, pump due batches; then drain.  Returns
    ``[(ticket, kind)]`` in arrival order."""
    clock = core.clock
    tickets = []
    for t_ms, req, kind in tape:
        clock.advance(t_ms - clock.now_ms())
        tickets.append((core.submit(req), kind))
        core.pump()
    core.drain()
    return tickets


def report(core: ServiceCore, tickets, *, show: bool = True) -> int:
    """Print the stats report; return the number of robustness violations
    (unresolved tickets — unhandled exceptions already propagated)."""
    unresolved = [tk for tk, _ in tickets if not tk.done]
    by_kind: dict = {}
    for tk, kind in tickets:
        outcome = ("ok" if tk.ok else type(tk.error).__name__)
        by_kind.setdefault(kind, {}).setdefault(outcome, 0)
        by_kind[kind][outcome] += 1
    if show:
        print("[serve] outcome by injected kind:")
        for kind in sorted(by_kind):
            print(f"  {kind:12s} {by_kind[kind]}")
        stats = core.stats()
        print("[serve] stats:")
        for k in sorted(stats):
            print(f"  {k:26s} {stats[k]}")
        print(f"[serve] unresolved: {len(unresolved)}")
    return len(unresolved)


def run(n_requests: int = 200, *, seed: int = 0, rate_hz: float = 200.0,
        faults: bool = False, guard: str = "retry_solo",
        window_ms: float = 8.0, max_batch: int = 8,
        show: bool = True) -> int:
    """The deterministic sim-clock run; returns the violation count."""
    cfg = ServiceConfig(guard=guard, batch_window_ms=window_ms,
                        max_batch=max_batch, max_cells=1 << 14,
                        max_queue=max(64, n_requests), seed=seed)
    inj = FaultInjector(FaultConfig(
        seed=seed, nan_input_rate=0.06, oversized_rate=0.03,
        expired_rate=0.03, evict_rate=0.05, oom_batch_limit=max_batch // 2,
        delay_ms_range=(0, 4))) if faults else None
    rng = random.Random(seed)
    core = ServiceCore(cfg, clock=SimClock(), faults=inj)
    tape = synth_requests(n_requests, rng, inj, rate_hz, cfg.max_cells)
    tickets = drive_sim(core, tape)
    bad = report(core, tickets, show=show)
    # stats report must be non-empty and every request typed-resolved
    if not core.stats().get("resolved"):
        print("[serve] FAIL: empty stats report")
        return bad + 1
    return bad


async def run_asyncio(n_requests: int, *, seed: int, rate_hz: float,
                      guard: str) -> int:
    """The real-clock asyncio path: same mix, actual awaited submits."""
    import asyncio

    rng = random.Random(seed)
    svc = StencilService(ServiceConfig(guard=guard, batch_window_ms=4.0,
                                       max_queue=max(64, n_requests),
                                       seed=seed))
    tape = synth_requests(n_requests, rng, None, rate_hz, 1 << 14)
    await svc.start()

    async def one(req):
        try:
            return await svc.submit(req)
        except ServeError as e:
            return e

    results = await asyncio.gather(*[one(req) for _, req, _ in tape])
    await svc.stop()
    stats = svc.stats()
    ok = sum(1 for r in results if not isinstance(r, ServeError))
    print(f"[serve] asyncio: {ok}/{len(results)} ok, "
          f"batches={stats.get('batches', 0)}, "
          f"p99={stats.get('p99_latency_ms', 0)}ms, "
          f"rps={stats.get('requests_per_sec', 0)}")
    return 0 if len(results) == n_requests else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="synthetic Poisson traffic through the stencil service")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate, requests/sec (sim clock)")
    ap.add_argument("--faults", action="store_true",
                    help="enable seeded fault injection (NaN inputs, "
                         "oversized shapes, expired deadlines, evictions, "
                         "OOM, delays)")
    ap.add_argument("--guard", choices=("reject", "propagate", "retry_solo"),
                    default="retry_solo")
    ap.add_argument("--window-ms", type=float, default=8.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--asyncio", action="store_true",
                    help="drive the real-clock asyncio front door instead")
    args = ap.parse_args(argv)
    if args.asyncio:
        import asyncio
        return asyncio.run(run_asyncio(args.requests, seed=args.seed,
                                       rate_hz=args.rate, guard=args.guard))
    bad = run(args.requests, seed=args.seed, rate_hz=args.rate,
              faults=args.faults, guard=args.guard,
              window_ms=args.window_ms, max_batch=args.max_batch)
    print(f"[serve] {'FAIL' if bad else 'OK'} — "
          f"{args.requests} requests, {bad} robustness violations")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
