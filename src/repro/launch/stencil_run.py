"""Stencil driver: run the paper's suite end-to-end (single- or multi-device).

``--distributed`` shards the domain over the host mesh and uses the deep-halo
communication-avoiding schedule; otherwise the Pallas kernels run directly
(interpret mode on CPU)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import roofline as rl
from repro.core.planner import plan as make_plan
from repro.core.stencil_spec import TABLE2, get
from repro.kernels import ops, ref, sweep
from repro.stencils.data import init_domain, reduced_domain


def run_single(name: str, *, t: int | None = None, scale: int = 64,
               check: bool = True):
    spec = get(name)
    eplan = make_plan(spec, rl.TPU_V5E)
    depth = t or min(eplan.t, 6)
    shape = reduced_domain(spec, scale)
    x = init_domain(spec, shape)
    t0 = time.time()
    if depth > eplan.t:
        # deeper than the plan's sweet spot: run T = depth total steps as
        # plan-depth sweeps through the zero-copy executor instead of one
        # over-deep sweep (whose halo would eat the tile)
        y = sweep.run_sweeps(x, spec, depth, plan=eplan, interpret=True)
        how = f"sweeps={sweep.sweep_schedule(depth, eplan.t)}"
    else:
        y = ops.ebisu_stencil(x, spec, depth, plan=eplan, interpret=True)
        how = "single-sweep"
    y.block_until_ready()
    dt = time.time() - t0
    line = (f"[stencil] {name:11s} domain={shape} t={depth} {how} "
            f"plan(t={eplan.t}, tile={eplan.block}, "
            f"lazy_batch={eplan.lazy_batch}, "
            f"buffers={eplan.parallelism.num_buffers}) "
            f"{dt*1e3:.0f}ms")
    if check:
        want = ref.reference(x, spec, depth)
        err = float(jnp.abs(y - want).max())
        line += f" maxerr={err:.2e}"
        assert err < 1e-4
    print(line, flush=True)
    return y


def run_distributed(name: str, *, t_total: int = 4, t_block: int = 2,
                    scale: int = 64):
    # lazy: the mesh helpers need jax.sharding.AxisType (newer jax); the
    # single-device path must keep working without it
    from repro.core.distributed import make_distributed_stencil
    from repro.launch.mesh import make_mesh

    spec = get(name)
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    shape = list(reduced_domain(spec, scale))
    shape[0] = (shape[0] + n - 1) // n * n
    fn, pspec = make_distributed_stencil(spec, mesh, {0: "data"},
                                         tuple(shape), t_total, t_block)
    x = init_domain(spec, tuple(shape))
    from jax.sharding import NamedSharding
    xs = jax.device_put(x, NamedSharding(mesh, pspec))
    t0 = time.time()
    y = fn(xs)
    y.block_until_ready()
    dt = time.time() - t0
    want = ref.reference(x, spec, t_total)
    err = float(jnp.abs(y - want).max())
    print(f"[stencil-dist] {name:11s} domain={tuple(shape)} shards={n} "
          f"t={t_total}(x{t_block}) {dt*1e3:.0f}ms maxerr={err:.2e}",
          flush=True)
    assert err < 1e-4
    return y


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stencil", default="all")
    ap.add_argument("--t", type=int, default=None)
    ap.add_argument("--scale", type=int, default=64)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()
    names = list(TABLE2) if args.stencil == "all" else args.stencil.split(",")
    for n in names:
        if args.distributed:
            run_distributed(n, scale=args.scale)
        else:
            run_single(n, t=args.t, scale=args.scale)


if __name__ == "__main__":
    main()
