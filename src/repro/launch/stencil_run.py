"""Stencil driver: the paper's suite AND user-defined stencils, end-to-end.

Quick start (the three-line compile→run flow):

    from repro.api import Boundary, compile_stencil, define_stencil
    spec = define_stencil([((0, 0), 0.6), ((0, 1), 0.1), ...])  # any taps
    prog = compile_stencil(spec, x.shape, t=4, boundary=Boundary.periodic())
    y = prog.run(x, T=64)         # 64 steps as chained zero-copy sweeps

Custom stencils are drivable straight from the CLI — the derived §5 cost
model is printed so the analytic machinery is inspectable:

    python -m repro.launch.stencil_run \
        --taps '[[[0,0],0.6],[[0,1],0.1],[[0,-1],0.1],[[1,0],0.1],[[-1,0],0.1]]' --t 2
    python -m repro.launch.stencil_run --spec-json my_stencil.json

``--mesh ZxY`` compiles the program onto a device mesh and runs it through
``run_sharded`` — deep ghost zones exchanged once per temporal block
(``docs/sharding.md``); on a CPU-only host the device count is faked
automatically.  ``--distributed`` is the older jnp reference scheme over
the host mesh; otherwise the compiled program drives the Pallas kernels
(interpret mode on CPU)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import (Boundary, compile_stencil, define_stencil,
                       parse_taps, spec_from_json)
from repro.core import roofline as rl
from repro.core.stencil_spec import StencilSpec, TABLE2, get
from repro.kernels import ref
from repro.stencils.data import init_domain, reduced_domain


def parse_mesh(text: str) -> tuple[int, ...]:
    """'8' | '2x4' | '2,4' → mesh shape tuple (axis k shards tensor dim k)."""
    try:
        shape = tuple(int(p) for p in text.replace(",", "x").split("x"))
        if not shape or any(n < 1 for n in shape):
            raise ValueError
        return shape
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"bad mesh {text!r}; use an int ('8') or a shape ('2x4')")


def parse_boundary(text: str) -> Boundary:
    """'dirichlet[:v]' | 'periodic' | 'reflect' | 'neumann[:flux]'
    → Boundary."""
    kind, _, val = text.partition(":")
    if kind == "dirichlet":
        return Boundary.dirichlet(float(val) if val else 0.0)
    if kind == "periodic":
        return Boundary.periodic()
    if kind == "reflect":
        return Boundary.reflect()
    if kind == "neumann":
        return Boundary.neumann(float(val) if val else 0.0)
    raise argparse.ArgumentTypeError(
        f"unknown boundary {text!r}; use dirichlet[:v] | periodic | "
        f"reflect | neumann[:flux]")


def cost_summary_line(spec: StencilSpec,
                      hw: rl.HardwareModel = rl.TPU_V5E) -> str:
    """One line of the derived §5 cost model (flagging any overrides)."""
    c = rl.spec_cost_summary(spec, hw)
    over = f" overrides={','.join(c['overridden'])}" if c["overridden"] else ""
    return (f"[spec]    {spec.name:11s} {c['ndim']}D r={c['radius']} "
            f"{c['npoints']}pt {c['shape_kind']} tap_sum={c['tap_sum']:.4g} | "
            f"flops/cell={c['flops_per_cell']:g} "
            f"a_sm={c['a_sm']:g} a_sm_rst={c['a_sm_rst']:g}{over} | "
            f"eq17 t*={c['desired_depth_eq17']:.1f} "
            f"eq23 w_min={c['min_tile_width_eq23']:.0f}")


def run_single(spec: StencilSpec | str, *, t: int | None = None,
               scale: int = 64, boundary: Boundary | None = None,
               check: bool = True, summary: bool = False):
    spec = get(spec) if isinstance(spec, str) else spec
    shape = reduced_domain(spec, scale)
    boundary = boundary or Boundary.dirichlet(0.0)
    # unnormalized Dirichlet admits only depth-1 sweeps (affine closure)
    depth_cap = 1 if (boundary.kind == "dirichlet" and boundary.value != 0.0
                      and abs(spec.tap_sum - 1.0) > 1e-6) else None
    prog = compile_stencil(spec, shape, boundary=boundary, interpret=True,
                           t=depth_cap)
    depth = t or min(prog.t, 6)
    x = init_domain(spec, shape)
    t0 = time.time()
    if depth > prog.t:
        # deeper than the plan's sweet spot: run T = depth total steps as
        # plan-depth sweeps through the program's zero-copy executor
        # instead of one over-deep sweep (whose halo would eat the tile)
        y = prog.run(x, depth)
        how = f"run(T={depth}, t={prog.t})"
    else:
        y = prog.apply(x, t=depth)
        how = "single-sweep"
    y.block_until_ready()
    dt = time.time() - t0
    plan = prog.plan
    if summary:
        print(cost_summary_line(spec, prog.hw), flush=True)
    line = (f"[stencil] {spec.name:11s} domain={shape} t={depth} {how} "
            f"boundary={boundary!r} "
            f"plan(t={plan.t}, tile={plan.block}, "
            f"lazy_batch={plan.lazy_batch}, "
            f"buffers={plan.parallelism.num_buffers}) "
            f"{dt*1e3:.0f}ms")
    if check:
        want = ref.reference(x, spec, depth, boundary=boundary)
        err = float(jnp.abs(y - want).max())
        line += f" maxerr={err:.2e}"
        assert err < 1e-4
    print(line, flush=True)
    return y


def run_sharded(spec: StencilSpec | str, mesh_shape: tuple[int, ...], *,
                t: int | None = None, scale: int = 64,
                boundary: Boundary | None = None, total_t: int | None = None,
                check: bool = True):
    """Drive ``compile_stencil(..., mesh=)`` + ``run_sharded`` end-to-end:
    shard the domain over the mesh, run ``T`` steps with one deep-halo
    exchange per temporal block, and (optionally) check against the
    per-step oracle.  Domain dims are rounded up to shard uniformly."""
    from repro.api import planned_exchange_rounds

    spec = get(spec) if isinstance(spec, str) else spec
    boundary = boundary or Boundary.dirichlet(0.0)
    shape = list(reduced_domain(spec, scale))
    for d, n in enumerate(mesh_shape):
        # uniform shards, each wide enough for the deep block halo
        min_shard = (t or 2) * spec.radius + 1
        shape[d] = n * max(-(-shape[d] // n), min_shard)
    shape = tuple(shape)
    if t is None:
        # default depth: run_single's cap, further bounded so the block
        # halo t*radius fits inside one shard (one neighbor hop)
        caps = [shape[d] // n // spec.radius
                for d, n in enumerate(mesh_shape) if n > 1]
        cap = min(caps) - (boundary.kind == "reflect") if caps else 6
        t = max(1, min(6, cap))
    prog = compile_stencil(spec, shape, t=t, boundary=boundary,
                           mesh=mesh_shape, interpret=True)
    total = total_t if total_t is not None else 2 * prog.t + 1
    x = init_domain(spec, shape)
    t0 = time.time()
    y = prog.run_sharded(x, total)
    y.block_until_ready()
    dt = time.time() - t0
    rounds = planned_exchange_rounds(total, prog.t)
    line = (f"[sharded] {spec.name:11s} domain={shape} "
            f"mesh={'x'.join(map(str, mesh_shape))} T={total} t={prog.t} "
            f"exchanges={rounds} (vs {total} per-step) {dt*1e3:.0f}ms")
    if check:
        want = ref.reference(x, spec, total, boundary=boundary)
        err = float(jnp.abs(y - want).max())
        line += f" maxerr={err:.2e}"
        assert err < 1e-4
    print(line, flush=True)
    return y


def run_campaign_cli(spec: StencilSpec | str, *, checkpoint_dir: str,
                     mesh_shape: tuple[int, ...] | None = None,
                     t: int | None = None, scale: int = 64,
                     boundary: Boundary | None = None,
                     total_t: int | None = None, every: int = 1,
                     resume: str = "auto", kill_after_leg: int | None = None,
                     out: str | None = None):
    """Drive a checkpointed campaign (``docs/resilience.md``): ``T`` steps
    as legs of ``every`` temporal blocks, checkpointing into
    ``checkpoint_dir``, resumable after a crash and bit-exact equal to
    the uninterrupted run.  ``kill_after_leg`` SIGKILLs the process after
    that leg's checkpoint lands — the CI crash-restart smoke:

        python -m repro.launch.stencil_run --stencil j2d5pt \\
            --checkpoint-dir /tmp/ck --T 24 --kill-after-leg 2   # dies (137)
        python -m repro.launch.stencil_run --stencil j2d5pt \\
            --checkpoint-dir /tmp/ck --T 24 --resume auto --out y.npy
    """
    import numpy as np

    from repro.resilient import CampaignStore

    spec = get(spec) if isinstance(spec, str) else spec
    boundary = boundary or Boundary.dirichlet(0.0)
    if mesh_shape:
        shape = list(reduced_domain(spec, scale))
        for d, n in enumerate(mesh_shape):
            min_shard = (t or 2) * spec.radius + 1
            shape[d] = n * max(-(-shape[d] // n), min_shard)
        shape = tuple(shape)
        prog = compile_stencil(spec, shape, t=t or 2, boundary=boundary,
                               mesh=mesh_shape, interpret=True)
    else:
        shape = reduced_domain(spec, scale)
        prog = compile_stencil(spec, shape, t=t, boundary=boundary,
                               interpret=True)
    total = total_t if total_t is not None else 2 * prog.t + 1
    x = init_domain(spec, shape)
    store = CampaignStore(checkpoint_dir)
    on_leg = None
    if kill_after_leg is not None:
        import os
        import signal

        def on_leg(leg, steps_done):
            if leg >= kill_after_leg:
                store.wait()     # the landed checkpoint survives the kill
                print(f"[campaign] injected crash after leg {leg} "
                      f"({steps_done}/{total} steps)", flush=True)
                os.kill(os.getpid(), signal.SIGKILL)

    t0 = time.time()
    runner = (prog.run_sharded_resumable if mesh_shape
              else prog.run_resumable)
    rep = runner(x, total, store=store, every=every, resume=resume,
                 on_leg=on_leg)
    rep.result.block_until_ready()
    dt = time.time() - t0
    resumed = (f" resumed@leg{rep.resumed_from}"
               if rep.resumed_from is not None else "")
    print(f"[campaign] {spec.name:11s} domain={shape} T={total} "
          f"t={prog.t} legs={rep.legs_total} every={every}"
          f"{resumed} ckpts={rep.checkpoints_written} "
          f"rms={rep.final_rms:.4g} {dt*1e3:.0f}ms", flush=True)
    if out:
        np.save(out, np.asarray(rep.result))
        print(f"[campaign] final field -> {out}", flush=True)
    return rep


def run_system_cli(name: str, *, t: int | None = None, scale: int = 64,
                   boundary: Boundary | None = None,
                   total_t: int | None = None, check: bool = True):
    """Drive a coupled system end-to-end (``docs/systems.md``): compile
    the library system, run ``T`` steps as fused multi-field sweeps, and
    (optionally) check the result is finite and matches the unfused
    per-field-per-step lockstep reference.

        python -m repro.launch.stencil_run --system gray-scott --t 4
    """
    import numpy as np

    from repro.systems import compile_system, get_system

    spec = get_system(name)
    boundary = boundary or Boundary.periodic()
    shape = (scale, scale)[:spec.ndim] if spec.ndim == 2 else \
        (scale, scale, scale)
    prog = compile_system(spec, shape, t=t or 4, boundary=boundary)
    total = total_t if total_t is not None else 2 * prog.t + 1
    rng = np.random.default_rng(0)
    fields = {f: jnp.asarray(rng.uniform(0.2, 0.8, shape).astype(np.float32))
              for f in spec.fields}
    t0 = time.time()
    out = prog.run(fields, total)
    jax.block_until_ready(out)
    dt = time.time() - t0
    line = (f"[system]  {spec.name:20s} fields={len(spec.fields)} "
            f"domain={shape} T={total} t={prog.t} "
            f"boundary={boundary!r} {dt*1e3:.0f}ms")
    if check:
        assert all(bool(jnp.isfinite(v).all()) for v in out.values()), \
            f"{spec.name}: non-finite output"
        want = prog.run_lockstep(fields, total)
        err = max(float(jnp.abs(out[f] - want[f]).max())
                  for f in spec.fields)
        line += f" maxerr_vs_lockstep={err:.2e}"
        assert err < 2e-5
    print(line, flush=True)
    return out


def run_distributed(name: str, *, t_total: int = 4, t_block: int = 2,
                    scale: int = 64):
    # lazy: the mesh helpers need jax.sharding.AxisType (newer jax); the
    # single-device path must keep working without it
    from repro.core.distributed import make_distributed_stencil
    from repro.launch.mesh import make_mesh

    spec = get(name)
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    shape = list(reduced_domain(spec, scale))
    shape[0] = (shape[0] + n - 1) // n * n
    fn, pspec = make_distributed_stencil(spec, mesh, {0: "data"},
                                         tuple(shape), t_total, t_block)
    x = init_domain(spec, tuple(shape))
    from jax.sharding import NamedSharding
    xs = jax.device_put(x, NamedSharding(mesh, pspec))
    t0 = time.time()
    y = fn(xs)
    y.block_until_ready()
    dt = time.time() - t0
    want = ref.reference(x, spec, t_total)
    err = float(jnp.abs(y - want).max())
    print(f"[stencil-dist] {name:11s} domain={tuple(shape)} shards={n} "
          f"t={t_total}(x{t_block}) {dt*1e3:.0f}ms maxerr={err:.2e}",
          flush=True)
    assert err < 1e-4
    return y


QUICKSTART = """\
quick start (compile once, run many — any tap set):
  from repro.api import Boundary, compile_stencil, define_stencil
  spec = define_stencil([((0,0),0.6), ((0,1),0.1), ...])  # or get("j2d5pt")
  prog = compile_stencil(spec, x.shape, t=6,
                         boundary=Boundary.periodic())
  y = prog.run(x, T=64)     # or prog.apply(x) / prog.run_batched(xs, T)

custom stencils from the CLI (derived cost model printed):
  --taps '[[[0,0],0.6],[[0,1],0.1],[[0,-1],0.1],[[1,0],0.1],[[-1,0],0.1]]'
  --spec-json my_stencil.json   # {"taps": [...], "name": ..., ...}

sharded execution over a device mesh (docs/sharding.md):
  --mesh 2x4                    # one deep-halo exchange per temporal block
  (CPU hosts fake the device count automatically)

legacy ops.ebisu_stencil / sweep.run_sweeps are deprecated shims over
compiled programs (policy in README.md)."""


def main():
    ap = argparse.ArgumentParser(
        epilog=QUICKSTART,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--stencil", default="all",
                    help="Table-2 names (comma-separated) or 'all'")
    ap.add_argument("--taps", default=None,
                    metavar="'[[[0,0],0.6],...]'",
                    help="define a custom stencil from a JSON tap list")
    ap.add_argument("--spec-json", default=None, metavar="FILE",
                    help="define a custom stencil from a JSON spec file")
    ap.add_argument("--normalize", action="store_true",
                    help="rescale --taps coefficients to sum to 1")
    ap.add_argument("--name", default=None,
                    help="name for the --taps stencil")
    ap.add_argument("--system", default=None, metavar="NAME",
                    help="run a coupled multi-field system (gray-scott | "
                         "fdtd-acoustic | advection-diffusion) — "
                         "docs/systems.md")
    ap.add_argument("--t", type=int, default=None)
    ap.add_argument("--scale", type=int, default=64)
    ap.add_argument("--boundary", type=parse_boundary, default=None,
                    metavar="dirichlet[:v]|periodic|reflect|neumann[:flux]",
                    help="boundary condition (default zero Dirichlet)")
    ap.add_argument("--mesh", type=parse_mesh, default=None,
                    metavar="N|ZxY",
                    help="device mesh for run_sharded (axis k shards dim k);"
                         " CPU hosts fake the device count automatically")
    ap.add_argument("--T", type=int, default=None, dest="total_t",
                    help="total steps for --mesh/--checkpoint-dir runs "
                         "(default 2*t+1)")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="run as a checkpointed resumable campaign into DIR"
                         " (docs/resilience.md)")
    ap.add_argument("--resume", default="auto",
                    choices=("auto", "never", "always"),
                    help="campaign resume mode (default auto: pick up the "
                         "newest good checkpoint in --checkpoint-dir)")
    ap.add_argument("--every", type=int, default=1, metavar="N",
                    help="temporal blocks per campaign leg (default 1)")
    ap.add_argument("--kill-after-leg", type=int, default=None, metavar="K",
                    help="SIGKILL the process after leg K's checkpoint "
                         "lands (crash-restart testing)")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="np.save the final field to FILE")
    args = ap.parse_args()
    if args.taps and args.spec_json:
        ap.error("--taps and --spec-json are mutually exclusive")
    if args.mesh and args.distributed:
        ap.error("--mesh (run_sharded) and --distributed (jnp reference "
                 "scheme) are mutually exclusive")
    if args.checkpoint_dir and args.distributed:
        ap.error("--checkpoint-dir (resumable campaigns) drives compiled "
                 "programs; --distributed is the jnp reference scheme")
    if args.kill_after_leg is not None and not args.checkpoint_dir:
        ap.error("--kill-after-leg needs --checkpoint-dir")
    if args.mesh:
        # must happen before the backend initializes (main() is the first
        # device use); no-op when a device-count flag is already set, and
        # the forced count only affects the host CPU platform
        import math

        from repro.launch.mesh import ensure_fake_devices
        ensure_fake_devices(math.prod(args.mesh))
    if args.system:
        if args.taps or args.spec_json or args.mesh or args.distributed \
                or args.checkpoint_dir:
            ap.error("--system runs single-device fused system programs; "
                     "it composes with --t/--T/--scale/--boundary only")
        run_system_cli(args.system, t=args.t, scale=args.scale,
                       boundary=args.boundary, total_t=args.total_t)
        return
    if args.taps or args.spec_json:
        if args.distributed:
            ap.error("--distributed drives the Table-2 suite; custom specs "
                     "run single-device (for now)")
        spec = (define_stencil(parse_taps(args.taps),
                               normalize=args.normalize, name=args.name)
                if args.taps else spec_from_json(args.spec_json))
        if args.checkpoint_dir:
            run_campaign_cli(
                spec, checkpoint_dir=args.checkpoint_dir,
                mesh_shape=args.mesh, t=args.t, scale=args.scale,
                boundary=args.boundary, total_t=args.total_t,
                every=args.every, resume=args.resume,
                kill_after_leg=args.kill_after_leg, out=args.out)
        elif args.mesh:
            print(cost_summary_line(spec), flush=True)
            run_sharded(spec, args.mesh, t=args.t, scale=args.scale,
                        boundary=args.boundary, total_t=args.total_t)
        else:
            run_single(spec, t=args.t, scale=args.scale,
                       boundary=args.boundary, summary=True)
        return
    names = list(TABLE2) if args.stencil == "all" else args.stencil.split(",")
    for n in names:
        if args.checkpoint_dir:
            run_campaign_cli(
                n, checkpoint_dir=args.checkpoint_dir, mesh_shape=args.mesh,
                t=args.t, scale=args.scale, boundary=args.boundary,
                total_t=args.total_t, every=args.every, resume=args.resume,
                kill_after_leg=args.kill_after_leg, out=args.out)
        elif args.mesh:
            run_sharded(n, args.mesh, t=args.t, scale=args.scale,
                        boundary=args.boundary, total_t=args.total_t)
        elif args.distributed:
            run_distributed(n, scale=args.scale)
        else:
            run_single(n, t=args.t, scale=args.scale,
                       boundary=args.boundary)


if __name__ == "__main__":
    main()
