"""Stencil driver: run the paper's suite end-to-end (single- or multi-device).

Quick start (the three-line compile→run flow):

    from repro.api import Boundary, compile_stencil
    prog = compile_stencil(spec, x.shape, t=4, boundary=Boundary.periodic())
    y = prog.run(x, T=64)         # 64 steps as chained zero-copy sweeps

``--distributed`` shards the domain over the host mesh and uses the deep-halo
communication-avoiding schedule; otherwise the compiled program drives the
Pallas kernels (interpret mode on CPU)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import Boundary, compile_stencil
from repro.core.stencil_spec import TABLE2, get
from repro.kernels import ref
from repro.stencils.data import init_domain, reduced_domain


def parse_boundary(text: str) -> Boundary:
    """'dirichlet[:v]' | 'periodic' | 'reflect' → Boundary."""
    kind, _, val = text.partition(":")
    if kind == "dirichlet":
        return Boundary.dirichlet(float(val) if val else 0.0)
    if kind == "periodic":
        return Boundary.periodic()
    if kind == "reflect":
        return Boundary.reflect()
    raise argparse.ArgumentTypeError(
        f"unknown boundary {text!r}; use dirichlet[:v] | periodic | reflect")


def run_single(name: str, *, t: int | None = None, scale: int = 64,
               boundary: Boundary | None = None, check: bool = True):
    spec = get(name)
    shape = reduced_domain(spec, scale)
    boundary = boundary or Boundary.dirichlet(0.0)
    prog = compile_stencil(spec, shape, boundary=boundary, interpret=True)
    depth = t or min(prog.t, 6)
    x = init_domain(spec, shape)
    t0 = time.time()
    if depth > prog.t:
        # deeper than the plan's sweet spot: run T = depth total steps as
        # plan-depth sweeps through the program's zero-copy executor
        # instead of one over-deep sweep (whose halo would eat the tile)
        y = prog.run(x, depth)
        how = f"run(T={depth}, t={prog.t})"
    else:
        y = prog.apply(x, t=depth)
        how = "single-sweep"
    y.block_until_ready()
    dt = time.time() - t0
    plan = prog.plan
    line = (f"[stencil] {name:11s} domain={shape} t={depth} {how} "
            f"boundary={boundary!r} "
            f"plan(t={plan.t}, tile={plan.block}, "
            f"lazy_batch={plan.lazy_batch}, "
            f"buffers={plan.parallelism.num_buffers}) "
            f"{dt*1e3:.0f}ms")
    if check:
        want = ref.reference(x, spec, depth, boundary=boundary)
        err = float(jnp.abs(y - want).max())
        line += f" maxerr={err:.2e}"
        assert err < 1e-4
    print(line, flush=True)
    return y


def run_distributed(name: str, *, t_total: int = 4, t_block: int = 2,
                    scale: int = 64):
    # lazy: the mesh helpers need jax.sharding.AxisType (newer jax); the
    # single-device path must keep working without it
    from repro.core.distributed import make_distributed_stencil
    from repro.launch.mesh import make_mesh

    spec = get(name)
    n = len(jax.devices())
    mesh = make_mesh((n,), ("data",))
    shape = list(reduced_domain(spec, scale))
    shape[0] = (shape[0] + n - 1) // n * n
    fn, pspec = make_distributed_stencil(spec, mesh, {0: "data"},
                                         tuple(shape), t_total, t_block)
    x = init_domain(spec, tuple(shape))
    from jax.sharding import NamedSharding
    xs = jax.device_put(x, NamedSharding(mesh, pspec))
    t0 = time.time()
    y = fn(xs)
    y.block_until_ready()
    dt = time.time() - t0
    want = ref.reference(x, spec, t_total)
    err = float(jnp.abs(y - want).max())
    print(f"[stencil-dist] {name:11s} domain={tuple(shape)} shards={n} "
          f"t={t_total}(x{t_block}) {dt*1e3:.0f}ms maxerr={err:.2e}",
          flush=True)
    assert err < 1e-4
    return y


QUICKSTART = """\
quick start (compile once, run many):
  from repro.api import Boundary, compile_stencil
  prog = compile_stencil(get("j2d5pt"), x.shape, t=6,
                         boundary=Boundary.periodic())
  y = prog.run(x, T=64)     # or prog.apply(x) / prog.run_batched(xs, T)

legacy ops.ebisu_stencil / sweep.run_sweeps are deprecated shims over
compiled programs (policy in README.md)."""


def main():
    ap = argparse.ArgumentParser(
        epilog=QUICKSTART,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--stencil", default="all")
    ap.add_argument("--t", type=int, default=None)
    ap.add_argument("--scale", type=int, default=64)
    ap.add_argument("--boundary", type=parse_boundary, default=None,
                    metavar="dirichlet[:v]|periodic|reflect",
                    help="boundary condition (default zero Dirichlet)")
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()
    names = list(TABLE2) if args.stencil == "all" else args.stencil.split(",")
    for n in names:
        if args.distributed:
            run_distributed(n, scale=args.scale)
        else:
            run_single(n, t=args.t, scale=args.scale,
                       boundary=args.boundary)


if __name__ == "__main__":
    main()
