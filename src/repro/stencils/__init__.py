from repro.core.stencil_spec import TABLE2, TABLE3_DEPTHS, StencilSpec, get, names  # noqa: F401
