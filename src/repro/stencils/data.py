"""Domain initialization for the stencil suite (STENCILGEN-style test data)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil_spec import StencilSpec


def init_domain(spec: StencilSpec, shape=None, dtype=jnp.float32,
                seed: int = 0) -> jnp.ndarray:
    """Random-in-[0,1) domain, like the STENCILGEN generator the paper uses."""
    shape = tuple(shape or spec.domain)
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, shape, dtype=jnp.float32).astype(dtype)


def reduced_domain(spec: StencilSpec, scale: int = 64):
    """A CPU-sized domain with the same aspect ratio as the paper's (Table 2)."""
    return tuple(max(2 * spec.radius + 2, d // scale) for d in spec.domain)
