"""Recovery policy: bounded retries, fault classification, the typed bottom.

The campaign runner mirrors the serving ladder's contract (DESIGN.md
§13.3): every failure walks a *bounded* recovery path and the bottom of
that path is a typed error, never a hang or a raw traceback.  For
campaigns the ladder is:

    leg fault -> roll back to last good checkpoint
              -> retry with exponential backoff + seeded jitter
                 (elastic mesh shrink first, when the fault is a lost
                  device on a sharded campaign)
              -> typed CampaignFault after ``max_retries`` per leg

:func:`classify` decides which exceptions enter the ladder at all:
transient kinds (injected :class:`~repro.faults.TransientFault`, a
:class:`~repro.resilient.health.HealthViolation` — a one-off corruption
re-runs clean) are retried; anything else is permanent and surfaces as
a ``CampaignFault('internal')`` immediately — retrying a genuine bug
just burns the budget.
"""
from __future__ import annotations

import dataclasses
import random

from repro.faults import TransientFault
from repro.resilient.health import HealthViolation

REASONS = ("health", "retries_exhausted", "checkpoints_corrupt",
           "no_checkpoint", "mesh_exhausted", "internal")


class CampaignFault(RuntimeError):
    """The campaign's typed bottom rung.  ``reason`` ∈ ``REASONS``;
    ``leg`` is where recovery gave up (None for pre-start faults like
    ``no_checkpoint``).  Raised instead of hanging or leaking the
    underlying exception — the cause is chained for forensics."""

    def __init__(self, reason: str, *, leg: int | None = None,
                 detail: str = ""):
        assert reason in REASONS, reason
        at = f" at leg {leg}" if leg is not None else ""
        super().__init__(f"campaign fault{at}: {reason}"
                         + (f" — {detail}" if detail else ""))
        self.reason = reason
        self.leg = leg


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded recovery knobs (defaults match the serving ladder's).

    * ``max_retries`` — rollback+retry attempts per leg index before the
      typed ``CampaignFault``; a leg replayed after a *later* leg's
      rollback keeps its own budget.
    * ``backoff_*`` — exponential backoff with seeded jitter, advanced
      on the injected clock (a ``SimClock`` soak spends no wall time).
    * ``elastic`` — on ``device_lost`` (sharded campaigns), recompile
      onto a smaller mesh and re-place the carry instead of failing; at
      resume, allow the checkpoint's mesh/plan to differ from the live
      program's (the carry is re-placed).  ``False`` = strict.
    * ``seed`` — the jitter RNG seed (determinism contract of
      ``repro.faults``).
    """

    max_retries: int = 2
    backoff_base_ms: float = 1.0
    backoff_factor: float = 2.0
    backoff_jitter_ms: float = 0.5
    elastic: bool = True
    seed: int = 0

    def backoff_ms(self, attempt: int, rng: random.Random) -> float:
        """Backoff before retry ``attempt`` (0-based), jittered."""
        return (self.backoff_base_ms * self.backoff_factor ** attempt
                + rng.uniform(0, self.backoff_jitter_ms))


def classify(exc: BaseException) -> str:
    """``'transient'`` (enter the rollback/retry ladder) or
    ``'permanent'`` (surface as ``CampaignFault('internal')`` now).

        classify(TransientFault("evicted"))          # 'transient'
        classify(HealthViolation("nonfinite", 3, 0)) # 'transient'
        classify(TypeError("boom"))                  # 'permanent'
    """
    if isinstance(exc, (TransientFault, HealthViolation)):
        return "transient"
    return "permanent"
