"""Per-leg health monitoring: ONE fused reduction, a configurable envelope.

A long campaign dies numerically in two ways: non-finite values (NaN/Inf
from blow-up or a flipped bit) and silent norm drift (an unstable tap
set amplifying round-off until the field is garbage while still
finite).  Both are caught by a single fused device reduction per leg —
``probe`` computes ``(all-finite, rms)`` in one jitted kernel and one
host sync, the campaign analogue of the serving guard's one-reduction-
per-batch rule (DESIGN.md §13.4): a health check that costs a device
round trip per tile would eat the temporal-blocking win it guards.

The verdict is judged against a :class:`HealthEnvelope`:

    env = HealthEnvelope(max_growth=1.05, max_rms=10.0)
    env.judge(finite=True, rms=3.2, prev_rms=3.1, leg=4)   # ok -> None
    env.judge(finite=False, rms=float("nan"), ...)         # raises

``max_growth`` bounds per-leg rms growth (diffusive/normalized tap sets
contract or preserve the norm, so sustained growth means instability);
``max_rms`` is an absolute ceiling.  Both default off — finiteness is
always checked.  Violations raise :class:`HealthViolation`, which the
runner classifies as *transient* (roll back, retry with backoff: a
one-off corruption re-runs clean) until the bounded retry budget turns
it into a typed ``CampaignFault``.
"""
from __future__ import annotations

import dataclasses
import functools


class HealthViolation(RuntimeError):
    """A leg's output failed the health envelope.  ``reason`` ∈
    {'nonfinite', 'rms_ceiling', 'rms_drift'}; carries the measured
    stats for the report/fault message."""

    def __init__(self, reason: str, leg: int, rms: float,
                 detail: str = ""):
        super().__init__(f"leg {leg}: {reason} (rms={rms:g})"
                         + (f" — {detail}" if detail else ""))
        self.reason = reason
        self.leg = leg
        self.rms = rms


@dataclasses.dataclass(frozen=True)
class HealthEnvelope:
    """What "healthy" means for a campaign carry, checked once per leg.

    * ``check_finite`` — refuse NaN/Inf anywhere in the field (on by
      default; turning it off is for fields that legitimately carry
      infinities).
    * ``max_growth`` — per-leg rms growth factor ceiling (None = off).
      Applied as ``rms > max_growth * prev_rms + atol``.
    * ``max_rms`` — absolute rms ceiling (None = off).
    * ``atol`` — additive slack so a near-zero field's round-off noise
      does not read as infinite relative growth.
    """

    check_finite: bool = True
    max_growth: float | None = None
    max_rms: float | None = None
    atol: float = 1e-12

    def judge(self, *, finite: bool, rms: float, prev_rms: float | None,
              leg: int) -> None:
        """Raise :class:`HealthViolation` if the leg's verdict falls
        outside the envelope; return None when healthy."""
        if self.check_finite and not finite:
            raise HealthViolation("nonfinite", leg, rms,
                                  "NaN/Inf in the carry")
        if self.max_rms is not None and rms > self.max_rms:
            raise HealthViolation(
                "rms_ceiling", leg, rms, f"ceiling {self.max_rms:g}")
        if (self.max_growth is not None and prev_rms is not None
                and rms > self.max_growth * prev_rms + self.atol):
            raise HealthViolation(
                "rms_drift", leg, rms,
                f"grew more than {self.max_growth:g}x from "
                f"{prev_rms:g} in one leg")


@functools.lru_cache(maxsize=1)
def _probe_fn():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def probe(v):
        w = v.astype(jnp.float32)
        return (jnp.isfinite(w).all(),
                jnp.sqrt(jnp.mean(jnp.square(w))))

    return probe


def probe(carry) -> tuple:
    """``(finite, rms)`` of a carry in ONE fused jitted reduction and one
    host transfer — works on single-device and mesh-sharded arrays alike
    (GSPMD inserts the cross-shard reduction under jit)."""
    import jax

    finite, rms = jax.device_get(_probe_fn()(carry))
    return bool(finite), float(rms)
