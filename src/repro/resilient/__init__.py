"""Crash-safe resumable stencil campaigns (guide: ``docs/resilience.md``).

The paper's EBISU regime is deep temporal blocking over *long* time
loops — exactly the runs that die to preemption, OOM, or numerical
blow-up in production.  ``StencilProgram.run``/``run_sharded`` are
all-or-nothing; this package runs the same ``T`` steps as
temporal-block-aligned **legs** with checkpointing, health monitoring,
and bounded recovery, and a resumed campaign is **bit-exact** equal to
the uninterrupted run (DESIGN.md §14):

    from repro.resilient import CampaignStore, HealthEnvelope
    store = CampaignStore("/ckpt/heat3d")
    y = prog.run_resumable(x, 512, store=store, every=2)   # leg = 2 blocks
    # ... SIGKILL / preemption / power loss ...
    y = prog.run_resumable(x, 512, store=store)            # resumes, bit-exact

Pieces:

  * :class:`~repro.resilient.store.CampaignStore` — atomic
    (tmp-dir + rename) checkpoints with async host-side serialization,
    a fingerprint manifest, and a content checksum; corrupt payloads are
    refused at load (:class:`~repro.resilient.store.CorruptCheckpoint`)
    and fingerprint drift at resume is refused with the fixes spelled
    out (:class:`~repro.resilient.store.ResumeMismatch`).
  * :mod:`~repro.resilient.health` — ONE fused NaN/Inf + norm reduction
    per leg, judged against a configurable
    :class:`~repro.resilient.health.HealthEnvelope`.
  * :mod:`~repro.resilient.policy` — bounded retry/backoff
    (:class:`~repro.resilient.policy.RetryPolicy`), transient/permanent
    fault classification, and the typed
    :class:`~repro.resilient.policy.CampaignFault` bottom rung — every
    rung bounded, no path hangs (the ``repro.serve`` ladder contract,
    applied to campaigns).
  * :mod:`~repro.resilient.runner` — the leg loop:
    :func:`~repro.resilient.runner.run_campaign` /
    :func:`~repro.resilient.runner.resume_campaign`, with rollback to
    the last good checkpoint and elastic restore onto a smaller mesh
    when a device drops from a sharded campaign.

Fault injection for all of it lives in :mod:`repro.faults` (shared with
the serving front door), seeded and deterministic.
"""
from repro.resilient.health import HealthEnvelope, HealthViolation
from repro.resilient.policy import CampaignFault, RetryPolicy, classify
from repro.resilient.runner import (CampaignReport, leg_schedule,
                                    resume_campaign, run_campaign)
from repro.resilient.store import (CampaignStore, CheckpointError,
                                   CorruptCheckpoint, ResumeMismatch)

__all__ = [
    "CampaignFault",
    "CampaignReport",
    "CampaignStore",
    "CheckpointError",
    "CorruptCheckpoint",
    "HealthEnvelope",
    "HealthViolation",
    "ResumeMismatch",
    "RetryPolicy",
    "classify",
    "leg_schedule",
    "resume_campaign",
    "run_campaign",
]
