"""The campaign loop: temporal-block-aligned legs with bounded recovery.

A campaign runs ``T`` steps as **legs** of ``every`` temporal blocks
each (``leg = every × t`` steps, remainder in the final leg).  Legs are
aligned to the program's sweep schedule, so the concatenation of the
per-leg schedules IS ``sweep_schedule(T, t)`` — which is why an
uninterrupted campaign, a crashed-and-resumed campaign, and a plain
``StencilProgram.run(x, T)`` are **bit-exact** equal (DESIGN.md §14):
no step is ever split or re-ordered by checkpointing.

Per leg:

  1. dispatch the leg (``program.run`` / ``run_sharded``),
  2. ONE fused health reduction (``resilient.health.probe``) judged
     against the :class:`~repro.resilient.health.HealthEnvelope`,
  3. checkpoint the carry asynchronously
     (:class:`~repro.resilient.store.CampaignStore` — atomic
     tmp-dir+rename, fingerprint manifest, content checksum).

On a fault the runner walks the bounded recovery ladder
(:mod:`~repro.resilient.policy`): roll back to the last good
checkpoint (corrupt ones are skipped at the cost of their legs), retry
with backoff — after an elastic mesh shrink when the fault is a lost
device — and resolve a typed
:class:`~repro.resilient.policy.CampaignFault` when the budget is
spent.  Nothing hangs: permanent faults surface immediately, transient
budgets are per-leg, mesh shrinks bottom out at one device, and a
global iteration guard backstops the lot.

    report = run_campaign(prog, x, 512, store=store, every=2)
    report.result            # == prog.run(x, 512), bitwise
    report = resume_campaign(prog, store)     # after a crash
"""
from __future__ import annotations

import dataclasses
import random

from repro.faults import FaultInjector, MonotonicClock, TransientFault
from repro.resilient.health import HealthEnvelope, HealthViolation, probe
from repro.resilient.policy import CampaignFault, RetryPolicy, classify
from repro.resilient.store import (CampaignStore, CheckpointError,
                                   CorruptCheckpoint)


def leg_schedule(total_t: int, t: int, every: int = 1) -> list:
    """``[(leg_index, steps), ...]`` covering ``total_t`` steps in legs
    of ``every`` temporal blocks (1-based leg indices; the final leg
    carries the remainder).  Concatenating each leg's internal sweep
    schedule reproduces ``sweep_schedule(total_t, t)`` exactly — the
    alignment behind the bit-exact resume contract.

        leg_schedule(10, 4, 1)   # -> [(1, 4), (2, 4), (3, 2)]
        leg_schedule(16, 4, 2)   # -> [(1, 8), (2, 8)]
    """
    if total_t < 0 or t < 1 or every < 1:
        raise ValueError(f"need total_t >= 0, t >= 1, every >= 1; got "
                         f"({total_t}, {t}, {every})")
    width = every * t
    out, done, leg = [], 0, 1
    while done < total_t:
        steps = min(width, total_t - done)
        out.append((leg, steps))
        done += steps
        leg += 1
    return out


@dataclasses.dataclass
class CampaignReport:
    """What happened: the result plus the recovery forensics the soak
    tests (and operators) assert on."""

    result: object = None
    total_t: int = 0
    every: int = 1
    legs_total: int = 0
    legs_run: int = 0                  # leg executions incl. replays
    resumed_from: int | None = None    # checkpoint leg a resume started at
    retries: int = 0
    rollbacks: int = 0
    checkpoints_written: int = 0
    corrupt_skipped: list = dataclasses.field(default_factory=list)
    mesh_history: list = dataclasses.field(default_factory=list)
    elastic_drift: list = dataclasses.field(default_factory=list)
    final_rms: float | None = None
    faults_injected: dict | None = None


def _fingerprint(program, kind: str) -> dict:
    fp = program.fingerprint()
    fp["kind"] = kind
    return fp


def _to_device(arr, program, sharded: bool):
    import jax
    import jax.numpy as jnp

    v = jnp.asarray(arr, program.dtype)
    if sharded and program.mesh is not None and program.mesh.size > 1:
        from repro.api.sharded import operand_sharding
        v = jax.device_put(v, operand_sharding(program))
    return v


def _poison(y):
    """NaN one cell of the carry (the injected numerical blow-up)."""
    import jax.numpy as jnp

    return y.at[tuple(0 for _ in y.shape)].set(jnp.nan)


def _shrunk_mesh_shape(program) -> tuple:
    """The next smaller mesh after a device loss: halve the last axis
    with more than one shard (even counts stay divisible; odd counts
    collapse to 1).  Raises ``CampaignFault('mesh_exhausted')`` at one
    device — there is nothing left to restore onto."""
    mesh = program.mesh
    dims = [int(mesh.shape[ax]) for ax in mesh.axis_names]
    for i in range(len(dims) - 1, -1, -1):
        if dims[i] > 1:
            dims[i] = dims[i] // 2 if dims[i] % 2 == 0 else 1
            return tuple(dims)
    raise CampaignFault("mesh_exhausted",
                        detail="mesh is already a single device")


def _recompiled(program, mesh_shape: tuple):
    """The same program on a smaller mesh (the elastic restore target);
    the §6 plan re-derives per the new, larger shard."""
    from repro.api.program import compile_stencil

    return compile_stencil(
        program.spec, program.shape, dtype=program.dtype, t=program.t,
        hw=program.hw, boundary=program.boundary, mode=program.mode,
        interpret=program.interpret, compute_dtype=program.compute_dtype,
        mesh=mesh_shape)


def run_campaign(program, x=None, total_t: int | None = None, *,
                 store, every: int = 1,
                 policy: RetryPolicy | None = None,
                 health: HealthEnvelope | None = None,
                 faults: FaultInjector | None = None,
                 clock=None, resume: str = "auto", sharded: bool = False,
                 on_leg=None) -> CampaignReport:
    """Run (or resume) a checkpointed campaign of ``total_t`` steps.

    ``resume`` ∈ {'auto', 'always', 'never'}: 'auto' resumes when the
    store holds a checkpoint and starts fresh otherwise; 'always'
    demands one (typed ``CampaignFault('no_checkpoint')`` if absent);
    'never' ignores existing checkpoints (and overwrites them leg by
    leg).  ``on_leg(leg, steps_done)`` fires after each successful
    leg's checkpoint is queued — the CLI's crash-injection hook.

    Returns a :class:`CampaignReport`; ``report.result`` is bit-exact
    equal to the uninterrupted ``program.run(x, total_t)`` (or
    ``run_sharded``) — see ``tests/test_resilient.py``.
    """
    store = CampaignStore(store) if isinstance(store, str) else store
    policy = policy or RetryPolicy()
    health = health or HealthEnvelope()
    clock = clock or MonotonicClock()
    jitter = random.Random(policy.seed)
    if resume not in ("auto", "always", "never"):
        raise ValueError(f"resume must be auto|always|never, got {resume!r}")
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")
    kind = "sharded" if sharded else "single"
    report = CampaignReport(every=every)

    # ------------------------------------------------------ start state ----
    manifest0 = None
    if resume != "never":
        try:
            store.wait()
            leg0, arr, manifest0, skipped = store.load_latest_good()
        except CheckpointError as e:
            if isinstance(e, CorruptCheckpoint):
                raise CampaignFault("checkpoints_corrupt",
                                    detail=str(e)) from e
            if resume == "always":
                raise CampaignFault("no_checkpoint", detail=str(e)) from e
        else:
            report.corrupt_skipped.extend(skipped)
    if manifest0 is not None:
        report.elastic_drift = CampaignStore.check_fingerprint(
            manifest0, _fingerprint(program, kind),
            total_t=total_t, every=every, elastic=policy.elastic)
        total_t = int(manifest0["total_t"])
        carry = _to_device(arr, program, sharded)
        steps_done = int(manifest0["steps_done"])
        prev_rms = manifest0.get("rms")
        report.resumed_from = leg0
    else:
        if x is None or total_t is None:
            raise ValueError(
                "a fresh campaign needs x and total_t "
                "(resume='always' resumes without them)")
        carry = _to_device(x, program, sharded)
        steps_done = 0
        _, prev_rms = probe(carry)
        # leg 0 anchors rollback before the first leg ever checkpoints
        store.save(0, carry, _manifest(program, kind, 0, total_t, every,
                                       prev_rms))
        report.checkpoints_written += 1
    report.total_t = total_t
    schedule = leg_schedule(total_t, program.t, every)
    report.legs_total = len(schedule)
    width = every * program.t

    # --------------------------------------------------------- leg loop ----
    attempts: dict = {}
    guard = len(schedule) * (policy.max_retries + 2) + 16
    while steps_done < total_t:
        guard -= 1
        if guard < 0:        # belt-and-braces no-hang backstop
            raise CampaignFault(
                "internal", detail="iteration guard tripped — recovery "
                "loop did not converge")
        leg = steps_done // width + 1
        steps = min(width, total_t - steps_done)
        try:
            if sharded and faults is not None and faults.lose_device(leg):
                raise TransientFault(
                    "device_lost", f"shard dropped before leg {leg}")
            y = (program.run_sharded(carry, steps) if sharded
                 else program.run(carry, steps))
            if faults is not None and faults.poison_leg(leg):
                y = _poison(y)
            finite, rms = probe(y)
            health.judge(finite=finite, rms=rms, prev_rms=prev_rms,
                         leg=leg)
        except Exception as e:  # noqa: BLE001 — classified below
            if classify(e) == "permanent":
                raise CampaignFault("internal", leg=leg,
                                    detail=repr(e)) from e
            lost = isinstance(e, TransientFault) and e.kind == "device_lost"
            if lost and sharded and policy.elastic:
                shape = _shrunk_mesh_shape(program)
                program = _recompiled(program, shape)
                report.mesh_history.append(shape)
            else:
                attempts[leg] = attempts.get(leg, 0) + 1
                if attempts[leg] > policy.max_retries:
                    reason = ("health" if isinstance(e, HealthViolation)
                              else "retries_exhausted")
                    raise CampaignFault(
                        reason, leg=leg,
                        detail=f"{attempts[leg]} attempts: {e}") from e
                report.retries += 1
            # roll back to the last good checkpoint (skipping corrupt
            # ones), pace the retry on the injected clock
            store.wait()
            try:
                leg_g, arr, man, skipped = store.load_latest_good()
            except CorruptCheckpoint as ce:
                raise CampaignFault("checkpoints_corrupt", leg=leg,
                                    detail=str(ce)) from ce
            report.corrupt_skipped.extend(skipped)
            report.rollbacks += 1
            carry = _to_device(arr, program, sharded)
            steps_done = int(man["steps_done"])
            prev_rms = man.get("rms")
            clock.advance(policy.backoff_ms(
                attempts.get(leg, 1) - 1, jitter))
            continue
        # ------------------------------------------------- leg landed ----
        carry, steps_done, prev_rms = y, steps_done + steps, rms
        report.legs_run += 1
        sabotage = (faults.checkpoint_sabotage(leg)
                    if faults is not None else None)
        store.save(leg, carry,
                   _manifest(program, kind, steps_done, total_t, every,
                             rms), sabotage=sabotage)
        if sabotage != "crash":
            report.checkpoints_written += 1
        if on_leg is not None:
            on_leg(leg, steps_done)

    store.wait()
    report.result = carry
    report.final_rms = prev_rms
    if faults is not None:
        report.faults_injected = faults.stats()
    return report


def resume_campaign(program, store, **kwargs) -> CampaignReport:
    """Resume a crashed campaign from its store — everything (carry,
    steps done, total steps) comes from the newest good checkpoint,
    after the manifest's fingerprints are validated against ``program``
    (mismatches refuse with the fix spelled out —
    :class:`~repro.resilient.store.ResumeMismatch`).

        report = resume_campaign(prog, CampaignStore(ckpt_dir))
        report.result      # bit-exact == the uninterrupted run
    """
    return run_campaign(program, None, None, store=store,
                        resume="always", **kwargs)


def _manifest(program, kind: str, steps_done: int, total_t: int,
              every: int, rms: float | None) -> dict:
    m = _fingerprint(program, kind)
    m.update(steps_done=int(steps_done), total_t=int(total_t),
             every=int(every), rms=rms)
    return m
