"""Atomic, checksummed, async checkpoint store for stencil campaigns.

One checkpoint = one directory ``leg_<k>/`` holding the carry field
(``carry.npy``) plus ``manifest.json``.  The manifest is the campaign's
identity card: the program fingerprint (spec signature, §6 plan
fingerprint, shape/dtype/boundary/depth/mode), the leg index and steps
done, a CRC-32 content checksum of the carry bytes, and the campaign
schedule (``total_t``, ``every``).  ``resume`` validates every
fingerprint field against the live program and refuses mismatches with
the fix spelled out — a checkpoint can never be silently replayed into
a different computation.

Write discipline (the proven pattern of ``train/checkpoint.py``):

  * **atomic** — everything lands in ``leg_<k>.tmp<ident>/`` first and
    is ``os.rename``d into place as the last act; a crash mid-save
    leaves a ``.tmp`` orphan that ``legs()`` never lists, so the latest
    *visible* checkpoint is always complete;
  * **async** — ``save`` snapshots the carry to host memory
    (``jax.device_get``) on the caller's thread, then hands
    serialization to a daemon thread; the campaign loop only blocks on
    the device fetch.  ``wait()`` is the barrier (the runner calls it
    before any rollback load and at campaign end);
  * **checksummed** — ``load`` recomputes the CRC and raises
    :class:`CorruptCheckpoint` on mismatch; ``load_latest_good`` walks
    backward past corrupt legs so a flipped bit on disk costs one leg
    of recompute, not the campaign.

    store = CampaignStore(tmpdir, keep=3)
    store.save(1, y, manifest_dict)
    store.wait()
    leg, arr, manifest, skipped = store.load_latest_good()
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import numpy as np

MANIFEST = "manifest.json"
PAYLOAD = "carry.npy"

# manifest fields that must match the live program exactly at resume;
# (mesh, plan) are validated separately — they may drift together under
# the elastic-restore policy (a smaller mesh replans per shard)
STRICT_FIELDS = ("spec_signature", "shape", "dtype", "compute_dtype",
                 "boundary", "t", "mode", "hw", "kind")
SCHEDULE_FIELDS = ("total_t", "every")

_FIX = {
    "spec_signature": "compile the same tap set (define_stencil with "
                      "identical taps/cost overrides)",
    "shape": "compile_stencil(spec, shape={want}) — a checkpoint cannot "
             "be resharded onto a different domain",
    "dtype": "compile_stencil(..., dtype={want})",
    "compute_dtype": "compile_stencil(..., compute_dtype={want})",
    "boundary": "compile_stencil(..., boundary={want})",
    "t": "compile_stencil(..., t={want}) — legs are temporal-block-"
         "aligned, so the sweep depth is part of the schedule",
    "mode": "compile_stencil(..., mode={want})",
    "hw": "compile_stencil(..., hw=<{want} model>)",
    "kind": "run the {want} entry point (run_resumable vs "
            "run_sharded_resumable) the campaign was started with",
    "total_t": "call run_resumable(..., {field}={want}) — changing the "
               "step count mid-campaign would break leg alignment",
    "every": "call run_resumable(..., {field}={want}) — changing the "
             "leg width mid-campaign would break leg alignment",
    "plan": "pin the checkpoint's plan (compile_stencil(..., plan=...)) "
            "or resume with RetryPolicy(elastic=True) on the same mesh "
            "family",
    "mesh": "compile_stencil(..., mesh={want}), or resume with "
            "RetryPolicy(elastic=True) to re-place the carry onto the "
            "live mesh",
}


class CheckpointError(RuntimeError):
    """Base of the store's typed failures; ``reason`` is machine-readable."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}" + (f": {detail}" if detail else ""))
        self.reason = reason


class CorruptCheckpoint(CheckpointError):
    """The on-disk payload does not match its manifest (checksum
    mismatch, unreadable manifest, missing payload).  Recoverable: fall
    back to an earlier leg (``load_latest_good`` does)."""

    def __init__(self, detail: str = ""):
        super().__init__("corrupt_checkpoint", detail)


class ResumeMismatch(CheckpointError):
    """The checkpoint was written by a different computation than the
    live program — refused, with the fix per field spelled out."""

    def __init__(self, mismatches: list):
        self.mismatches = mismatches
        lines = []
        for field, have, want in mismatches:
            fix = _FIX.get(field, "recompile to match").format(
                want=want, field=field)
            lines.append(f"  {field}: checkpoint has {want!r}, live "
                         f"program has {have!r} — fix: {fix}")
        super().__init__(
            "resume_mismatch",
            "checkpoint does not match the live program:\n"
            + "\n".join(lines))


def checksum(arr: np.ndarray) -> int:
    """CRC-32 of the carry's raw bytes (dtype/shape are covered by the
    manifest's fingerprint fields, so the payload bytes are enough)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


class CampaignStore:
    """Directory of ``leg_<k>/`` checkpoints with atomic writes, async
    serialization, checksums, and bounded retention.

    ``keep`` newest checkpoints are retained (older ones are pruned
    after each successful save) — deep rollback is bounded by design;
    a campaign that needs more history raises ``keep``.
    """

    def __init__(self, root: str, keep: int = 3):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.root = str(root)
        self.keep = keep
        self._threads: list = []
        self._lock = threading.Lock()
        os.makedirs(self.root, exist_ok=True)

    # ------------------------------------------------------------ paths ----
    def _dir(self, leg: int) -> str:
        return os.path.join(self.root, f"leg_{leg}")

    def legs(self) -> list:
        """Complete (renamed-into-place) leg indices, ascending.  ``.tmp``
        orphans from a crashed save are invisible by construction."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for d in os.listdir(self.root):
            if not d.startswith("leg_") or ".tmp" in d:
                continue
            if not os.path.exists(os.path.join(self.root, d, MANIFEST)):
                continue
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_leg(self) -> int | None:
        legs = self.legs()
        return legs[-1] if legs else None

    # ------------------------------------------------------------- save ----
    def save(self, leg: int, carry, manifest: dict, *, block: bool = False,
             sabotage: str | None = None) -> threading.Thread:
        """Checkpoint ``carry`` (device array or ndarray) at ``leg``.

        The device fetch happens here, synchronously — the snapshot is
        consistent even if the campaign keeps overwriting buffers — and
        the file writes happen on a daemon thread (``block=True`` joins
        it, for tests and the final barrier).

        ``sabotage`` is the fault-injection seam (``repro.faults``):
        ``'crash'`` abandons the ``tmp`` dir before the rename (what a
        mid-save SIGKILL leaves behind); ``'corrupt'`` flips payload
        bytes after the rename (a bad disk).  Production callers leave
        it ``None``.
        """
        import jax

        host = np.asarray(jax.device_get(carry))
        m = dict(manifest)
        m["leg"] = int(leg)
        m["checksum"] = checksum(host)
        m["payload"] = PAYLOAD

        def write():
            tmp = self._dir(leg) + f".tmp{threading.get_ident()}"
            final = self._dir(leg)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            np.save(os.path.join(tmp, PAYLOAD), host)
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(m, f, indent=1)
            if sabotage == "crash":      # die before the atomic rename
                return
            shutil.rmtree(final, ignore_errors=True)
            try:
                os.rename(tmp, final)
            except OSError:              # concurrent save of the leg won
                shutil.rmtree(tmp, ignore_errors=True)
                return
            if sabotage == "corrupt":
                _flip_payload_bytes(os.path.join(final, PAYLOAD))
            self._prune()

        t = threading.Thread(target=write, daemon=True,
                             name=f"ckpt-leg-{leg}")
        with self._lock:
            self._threads.append(t)
        t.start()
        if block:
            t.join()
        return t

    def wait(self) -> None:
        """Barrier: join every outstanding writer (rollback loads and
        campaign completion call this first)."""
        with self._lock:
            threads, self._threads = self._threads, []
        for t in threads:
            t.join()

    def _prune(self) -> None:
        with self._lock:
            for leg in self.legs()[:-self.keep] if self.keep else []:
                shutil.rmtree(self._dir(leg), ignore_errors=True)

    # ------------------------------------------------------------- load ----
    def load(self, leg: int) -> tuple:
        """``(carry_ndarray, manifest)`` for ``leg``; raises
        :class:`CorruptCheckpoint` on an unreadable manifest, a missing
        payload, or a checksum mismatch."""
        d = self._dir(leg)
        try:
            with open(os.path.join(d, MANIFEST)) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise CorruptCheckpoint(
                f"leg {leg}: unreadable manifest ({e})") from e
        try:
            arr = np.load(os.path.join(d, manifest.get("payload", PAYLOAD)))
        except (OSError, ValueError) as e:
            raise CorruptCheckpoint(
                f"leg {leg}: unreadable payload ({e})") from e
        want = manifest.get("checksum")
        have = checksum(arr)
        if want != have:
            raise CorruptCheckpoint(
                f"leg {leg}: payload checksum {have} != manifest {want} "
                "(bytes changed on disk)")
        return arr, manifest

    def load_latest_good(self) -> tuple:
        """``(leg, carry, manifest, skipped)`` for the newest checkpoint
        that passes its checksum; corrupt newer legs are listed in
        ``skipped`` (the rollback loses their compute, nothing else).
        Raises :class:`CorruptCheckpoint` when checkpoints exist but
        none loads, and :class:`CheckpointError('no_checkpoint')` when
        the store is empty."""
        legs = self.legs()
        if not legs:
            raise CheckpointError("no_checkpoint",
                                  f"{self.root} holds no checkpoints")
        skipped = []
        for leg in reversed(legs):
            try:
                arr, manifest = self.load(leg)
            except CorruptCheckpoint as e:
                skipped.append((leg, str(e)))
                continue
            return leg, arr, manifest, skipped
        raise CorruptCheckpoint(
            f"every checkpoint in {self.root} is corrupt: "
            + "; ".join(msg for _, msg in skipped))

    # ------------------------------------------------------- validation ----
    @staticmethod
    def check_fingerprint(manifest: dict, fingerprint: dict, *,
                          total_t: int, every: int,
                          elastic: bool = True) -> list:
        """Refuse (``ResumeMismatch``) any drift between the checkpoint's
        manifest and the live program's fingerprint + schedule.  Returns
        the list of *elastic* drifts (mesh/plan) that were allowed —
        empty on an exact match; with ``elastic=False`` those refuse
        too (strict resume)."""
        mismatches, allowed = [], []
        for field in STRICT_FIELDS:
            have, want = fingerprint.get(field), manifest.get(field)
            if have != want:
                mismatches.append((field, have, want))
        for field, want in (("total_t", total_t), ("every", every)):
            if manifest.get(field) != want and want is not None:
                mismatches.append((field, want, manifest.get(field)))
        mesh_drift = manifest.get("mesh") != fingerprint.get("mesh")
        plan_drift = manifest.get("plan") != fingerprint.get("plan")
        if mesh_drift or (plan_drift and mesh_drift):
            (allowed if elastic else mismatches).append(
                ("mesh", fingerprint.get("mesh"), manifest.get("mesh")))
        if plan_drift and not mesh_drift:
            # same mesh but a different plan is a different computation
            # schedule on the same hardware — always refused
            mismatches.append(
                ("plan", fingerprint.get("plan"), manifest.get("plan")))
        if mismatches:
            raise ResumeMismatch(mismatches)
        return allowed


def _flip_payload_bytes(path: str, n: int = 8) -> None:
    """Corrupt ``n`` bytes in the middle of the payload (past the npy
    header, so ``np.load`` still parses and only the checksum catches
    it) — the fault-injection model of a bad disk/bit rot."""
    size = os.path.getsize(path)
    off = max(size // 2, 128)
    with open(path, "r+b") as f:
        f.seek(off)
        chunk = f.read(n)
        f.seek(off)
        f.write(bytes((b ^ 0xFF) for b in chunk))
