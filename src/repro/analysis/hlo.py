"""HLO-text analysis: collective bytes / counts from the compiled module.

``cost_analysis()`` has no collective information, so (per the assignment
brief) we parse the post-SPMD ``compiled.as_text()`` and sum the sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

Two numbers per op:
  * result_bytes — the op's output tensor size (raw);
  * wire_bytes   — estimated bytes crossing links per participating device,
    using ring-algorithm formulas with the group size parsed from
    replica_groups:
        all-reduce:          2·(g-1)/g · size
        all-gather:            (g-1)/g · result size
        reduce-scatter:        (g-1)/g · input size ≈ (g-1) · result size
        all-to-all:            (g-1)/g · size
        collective-permute:    size
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%?[\w.\-]+\s*=\s*)?"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}\/ ]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    count: dict
    result_bytes: dict
    wire_bytes: dict

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count.values()))

    def as_dict(self):
        return {"count": dict(self.count),
                "result_bytes": {k: float(v) for k, v in
                                 self.result_bytes.items()},
                "wire_bytes": {k: float(v) for k, v in
                               self.wire_bytes.items()},
                "total_wire_bytes": self.total_wire_bytes,
                "total_count": self.total_count}


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota v2 form [num_groups,group_size]
        return max(1, int(m.group(2)))
    return default


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    count: dict = defaultdict(int)
    result_bytes: dict = defaultdict(float)
    wire_bytes: dict = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, op, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue  # counted at -start
        size = _shape_bytes(type_str)
        g = _group_size(line, n_devices)
        if op == "all-reduce":
            wire = 2 * (g - 1) / g * size
        elif op == "all-gather":
            wire = (g - 1) / g * size
        elif op == "reduce-scatter":
            wire = (g - 1) * size          # input ≈ g × result
        elif op == "all-to-all":
            wire = (g - 1) / g * size
        else:                              # collective-permute
            wire = size
        count[op] += 1
        result_bytes[op] += size
        wire_bytes[op] += wire
    return CollectiveStats(dict(count), dict(result_bytes), dict(wire_bytes))


def scan_trip_counts(hlo_text: str) -> int:
    """Max while-loop trip count (collectives inside run that many times) —
    used to scale per-iteration collective counts for scanned layers."""
    trips = [int(t) for t in re.findall(r"trip_count=(\d+)", hlo_text)]
    return max(trips) if trips else 1
