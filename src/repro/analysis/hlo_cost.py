"""Loop-aware HLO cost analysis from ``compiled.as_text()``.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified empirically
— a 4-iteration scan reports 1× the body flops), which under-counts every
scan-over-layers model by ~L×.  This module re-derives the roofline inputs
with call-graph trip-count multipliers:

  * computations are parsed from the HLO text;
  * a caller graph is built from ``while(body=%b)`` (×known_trip_count),
    ``fusion(calls=%f)``, ``call(to_apply=%f)`` and ``conditional`` branches;
  * per computation we count
      - dot flops: 2 · prod(result dims) · prod(lhs contracting dims)
        (matmuls dominate transformer flops);
      - elementwise flops: 1 · prod(result dims) per floating-point
        arithmetic op (add/multiply/…, transcendentals counted as 1) —
        zero for transformer-scale modules next to the dots, but the
        whole story for stencils, whose tap chains are dot-free FMA
        cascades (``repro.tuning.analytic`` consumes this);
      - byte traffic: Σ (result + operand bytes) over non-trivial top-level
        instructions — the same per-op approximation cost_analysis uses;
      - collective result/wire bytes and counts (see analysis.hlo);
  * totals are Σ over computations of (per-comp cost × multiplier).

All numbers are PER-DEVICE (the compiled module is the per-device SPMD
program).  Validated against the analytic 6·N·D model in tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.analysis.hlo import _DTYPE_BYTES, _shape_bytes

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(([^)]*(?:\([^)]*\))?[^)]*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:body|calls|to_apply)=%?([\w.\-]+)")
_COND_BRANCHES = re.compile(r"(?:branch_computations|true_computation|"
                            r"false_computation)=\{?%?([\w.\-,% ]+)\}?")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_SHAPE_DIMS = re.compile(r"\w+\[([\d,]*)\]")

_TRIVIAL = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "copy", "after-all", "partition-id", "replica-id", "iota",
            "get-dimension-size"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "all-to-all-start",
                "reduce-scatter-start"}
# floating-point arithmetic counted as 1 flop per result element; masks,
# selects, compares, and index math are bookkeeping, not flops — matching
# the paper's flops/cell convention (2 per tap FMA, §11.2)
_EW_ARITH = {"add", "subtract", "multiply", "divide", "negate", "abs",
             "maximum", "minimum", "power", "sqrt", "rsqrt", "exponential",
             "exponential-minus-one", "log", "log-plus-one", "tanh",
             "sine", "cosine", "atan2", "cbrt"}
_FLOAT_DTYPES = ("f64", "f32", "f16", "bf16", "f8e4m3fn", "f8e5m2")


def _dims(type_str):
    m = _SHAPE_DIMS.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


@dataclasses.dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    ew_flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    coll_result: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_wire: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    callees: list = dataclasses.field(default_factory=list)  # (name, trips, fused)
    root_op: str = ""
    fusion_charges: list = dataclasses.field(default_factory=list)
    # (callee_name, out_bytes, [operand_bytes]) — finalized in analyze()
    param_eff: dict = dataclasses.field(default_factory=dict)
    # param position -> effective read bytes (slice-only params read less)


_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symtab: dict[str, str] = {}
    param_of: dict[str, int] = {}       # instr name -> param position
    param_bytes: dict[int, int] = {}
    slice_reads: dict[int, float] = {}  # param position -> slice bytes read
    nonslice_use: set = set()

    def finalize(comp):
        for idx, pb in param_bytes.items():
            if idx in nonslice_use or idx not in slice_reads:
                continue
            comp.param_eff[idx] = min(pb, slice_reads[idx])

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(hdr.group(2))
            comps[cur.name] = cur
            symtab = {}
            param_of, param_bytes = {}, {}
            slice_reads, nonslice_use = {}, set()
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            finalize(cur)
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        symtab[name] = type_str
        if line.lstrip().startswith("ROOT"):
            cur.root_op = op
        if op == "parameter":
            pm = _PARAM_IDX.search(line)
            if pm:
                param_of[name] = int(pm.group(1))
                param_bytes[int(pm.group(1))] = _shape_bytes(type_str)
            continue
        # param usage bookkeeping (slice-only reads cost only the slice)
        paren0 = line[line.index(op + "(") + len(op):]
        for o in _OPERANDS.findall(paren0.split("),")[0]):
            if o in param_of:
                idx = param_of[o]
                if op in ("dynamic-slice", "gather", "slice"):
                    slice_reads[idx] = slice_reads.get(idx, 0.0)                         + _shape_bytes(type_str)
                elif op in _TRIVIAL:
                    pass
                else:
                    nonslice_use.add(idx)
        if op in _TRIVIAL:
            continue
        # call edges.  'fused' edges lead to computations whose instructions
        # execute in registers/local memory (fusion bodies, reduce lambdas):
        # they contribute FLOPs but no HBM traffic.
        if op == "while":
            t = _TRIP.search(line)
            trips = int(t.group(1)) if t else 1
            for callee in _CALLS.findall(line):
                cur.callees.append((callee, trips, False))
            continue
        if op in ("fusion", "call", "map", "reduce", "reduce-window", "sort",
                  "scatter", "select-and-scatter"):
            for callee in _CALLS.findall(line):
                cur.callees.append((callee, 1, True))
        if op == "conditional":
            for grp in _COND_BRANCHES.findall(line):
                for callee in _OPERANDS.findall(grp):
                    cur.callees.append((callee, 1, False))
        # costs
        if op in _EW_ARITH and type_str.lstrip().startswith(_FLOAT_DTYPES):
            elems = 1
            for d in _dims(type_str):
                elems *= d
            cur.ew_flops += float(elems)
        paren = line[line.index(op + "(") + len(op):]
        operand_names = _OPERANDS.findall(paren.split("),")[0])
        out_bytes = _shape_bytes(type_str)
        in_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in operand_names)
        if op in _COLLECTIVES:
            from repro.analysis.hlo import _GROUPS_RE, _GROUPS_V2_RE
            base = op.replace("-start", "")
            g = 2
            mg = _GROUPS_V2_RE.search(line)
            if mg:
                g = max(1, int(mg.group(2)))
            else:
                mg = _GROUPS_RE.search(line)
                if mg:
                    g = max(1, len([x for x in mg.group(1).split(",")
                                    if x.strip()]))
            size = out_bytes
            wire = {"all-reduce": 2 * (g - 1) / g,
                    "all-gather": (g - 1) / g,
                    "reduce-scatter": (g - 1),
                    "all-to-all": (g - 1) / g,
                    "collective-permute": 1.0}[base] * size
            cur.coll_count[base] += 1
            cur.coll_result[base] += size
            cur.coll_wire[base] += wire
            continue
        if op == "dynamic-slice":
            # reads only the slice (stacked scan weights are indexed, not
            # copied whole): read slice + write slice
            cur.bytes_accessed += 2 * out_bytes
        elif op == "dynamic-update-slice":
            # in-place update (XLA aliases the buffer): read+write the
            # update region only, not the whole carried tensor
            upd = (_shape_bytes(symtab.get(operand_names[1], ""))
                   if len(operand_names) > 1 else out_bytes)
            cur.bytes_accessed += 2 * upd
        elif op == "fusion":
            # deferred: in-place (DUS/scatter-rooted) fusions alias their
            # big operand — adjusted once all computations are parsed
            cur.fusion_charges.append(
                (_CALLS.findall(line)[0] if _CALLS.findall(line) else "",
                 out_bytes,
                 [_shape_bytes(symtab.get(o, "")) for o in operand_names]))
        else:
            cur.bytes_accessed += out_bytes + in_bytes
        if op == "dot":
            cm = _DOT_CONTRACT.search(line)
            contract = 1
            if cm and operand_names:
                lhs_dims = _dims(symtab.get(operand_names[0], ""))
                for ci in [int(c) for c in cm.group(1).split(",") if c]:
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
            result_elems = 1
            for d in _dims(type_str):
                result_elems *= d
            cur.dot_flops += 2.0 * result_elems * contract
    return comps


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    bytes_accessed: float
    coll_count: dict
    coll_result_bytes: dict
    coll_wire_bytes: dict
    ew_flops: float = 0.0

    @property
    def total_flops(self):
        """Dot plus elementwise flops — the full compute-term numerator
        (dot-dominated for transformers, elementwise-only for stencils)."""
        return float(self.dot_flops + self.ew_flops)

    @property
    def total_wire_bytes(self):
        return float(sum(self.coll_wire_bytes.values()))

    @property
    def total_coll_count(self):
        return int(sum(self.coll_count.values()))

    def as_dict(self):
        return {"dot_flops": self.dot_flops,
                "ew_flops": self.ew_flops,
                "total_flops": self.total_flops,
                "bytes_accessed": self.bytes_accessed,
                "coll_count": dict(self.coll_count),
                "coll_result_bytes": dict(self.coll_result_bytes),
                "coll_wire_bytes": dict(self.coll_wire_bytes),
                "total_wire_bytes": self.total_wire_bytes,
                "total_coll_count": self.total_coll_count}


def analyze(text: str, entry: str | None = None) -> HloCost:
    comps = _parse_computations(text)
    if not comps:
        return HloCost(0, 0, {}, {}, {})
    # find entry: the computation never called by others, or 'main'-ish
    called = {c for comp in comps.values() for c, _, _ in comp.callees}
    entries = [n for n in comps if n not in called]
    if entry is None:
        mains = [n for n in entries if "main" in n]
        entry = mains[0] if mains else (entries[0] if entries else
                                        next(iter(comps)))
    mult: dict[str, float] = defaultdict(float)        # execution multiplier
    mult_mem: dict[str, float] = defaultdict(float)     # HBM-level multiplier

    def visit(name: str, m: float, in_fused: bool, depth=0):
        if name not in comps or depth > 64:
            return
        mult[name] += m
        if not in_fused:
            mult_mem[name] += m
        for callee, trips, fused in comps[name].callees:
            visit(callee, m * trips, in_fused or fused, depth + 1)

    visit(entry, 1.0, False)
    # finalize fusion byte charges: a fusion whose callee roots in an
    # in-place op (dynamic-update-slice / scatter) aliases its largest
    # operand with its result — charge only the incremental traffic.
    for c in comps.values():
        for callee, out_b, op_bytes in c.fusion_charges:
            cal = comps.get(callee)
            eff = [min(b, cal.param_eff.get(i, b)) if cal else b
                   for i, b in enumerate(op_bytes)]
            charge = out_b + sum(eff)
            root = cal.root_op if cal else ""
            if root in ("dynamic-update-slice", "scatter") and eff:
                big = max(eff)
                if big >= out_b * 0.99:
                    charge = max(0.0, charge - 2 * big)
            c.bytes_accessed += charge
    flops = sum(c.dot_flops * mult[c.name] for c in comps.values())
    # execution multiplier (not mult_mem): fused-body arithmetic is real work
    ew = sum(c.ew_flops * mult[c.name] for c in comps.values())
    byts = sum(c.bytes_accessed * mult_mem[c.name] for c in comps.values())
    cc: dict = defaultdict(float)
    cr: dict = defaultdict(float)
    cw: dict = defaultdict(float)
    for c in comps.values():
        for k, v in c.coll_count.items():
            cc[k] += v * mult[c.name]
        for k, v in c.coll_result.items():
            cr[k] += v * mult[c.name]
        for k, v in c.coll_wire.items():
            cw[k] += v * mult[c.name]
    return HloCost(float(flops), float(byts), dict(cc), dict(cr), dict(cw),
                   ew_flops=float(ew))
