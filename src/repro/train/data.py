"""Synthetic deterministic data pipeline.

Every batch is a pure function of (seed, step, shard) — the straggler /
fault-tolerance property: a restarted or replaced host regenerates exactly
its shard of any step with no coordination (DESIGN.md §5).  Host-side
prefetch keeps ``prefetch`` batches in flight.
"""
from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np


def batch_for_step(cfg, shape_name: str, step: int, seed: int = 0,
                   reduced_shapes=None):
    """Deterministic synthetic batch matching cfg.input_specs(shape_name)."""
    specs = (cfg.input_specs(shape_name) if reduced_shapes is None
             else reduced_shapes)
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2**31 - 1))
    out = {}
    for k, sds in specs.items():
        if k in ("tokens", "labels"):
            # learnable structure: noisy arithmetic sequences (next = cur+1),
            # so example trainers measurably reduce loss on synthetic data
            b, s = sds.shape
            offs = rng.randint(0, cfg.vocab, size=(b, 1))
            seqs = (offs + np.arange(s)[None, :]) % cfg.vocab
            noise = rng.rand(b, s) < 0.05
            seqs = np.where(noise, rng.randint(0, cfg.vocab, size=(b, s)),
                            seqs)
            out[k] = jnp.asarray(seqs, jnp.int32)
        elif k == "mask":
            out[k] = jnp.asarray(rng.rand(*sds.shape) < 0.15)
        else:
            out[k] = jnp.asarray(rng.randn(*sds.shape), sds.dtype)
    if "tokens" in out and "labels" in out:
        out["labels"] = out["tokens"]          # LM: next-token via shift
    return out


class Prefetcher:
    """Background-thread batch producer (host-side prefetch ≙ the paper's
    asynchronous copy: overlap data production with device compute)."""

    def __init__(self, cfg, shape_name: str, start_step: int = 0,
                 seed: int = 0, prefetch: int = 2, reduced_shapes=None):
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = batch_for_step(cfg, shape_name, step, seed,
                                   reduced_shapes)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
