"""Fault-tolerant checkpointing: sharded npz + manifest, async, elastic.

Design (DESIGN.md §5):
  * a checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per pytree
    leaf (flattened path-keyed) + ``manifest.json`` (step, tree structure,
    logical PartitionSpecs, mesh shape);
  * saves are atomic: written to ``step_<N>.tmp/`` then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint;
  * saves are async: a daemon thread does the host-side serialization so the
    train loop only blocks on ``jax.device_get`` (and an explicit barrier at
    shutdown);
  * restore is *elastic*: specs are stored logically (axis names), so loading
    onto a different mesh shape just re-``device_put``s with the new mesh —
    resharding is free at load time.  ``latest_step`` + ``--resume auto``
    give crash-restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {jax.tree_util.keystr(path): leaf for path, leaf in flat}
    return keyed, treedef


def save(ckpt_dir: str, step: int, tree, *, block: bool = False):
    """Asynchronously persist ``tree`` (params/opt_state/...) at ``step``."""
    keyed, _ = _flatten(tree)
    # device_get before handing to the thread: snapshot is consistent even if
    # the train loop keeps donating/overwriting buffers.
    host = {k: np.asarray(jax.device_get(v)) for k, v in keyed.items()}
    structure = jax.tree.map(lambda _: 0, tree)

    def write():
        tmp = os.path.join(ckpt_dir,
                           f"step_{step}.tmp{threading.get_ident()}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        names = {}
        for i, (k, v) in enumerate(host.items()):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), v)
            names[k] = f"leaf_{i}.npy"
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": names,
                       "treedef": jax.tree_util.tree_structure(
                           structure).serialize_using_proto().hex()},
                      f)
        shutil.rmtree(final, ignore_errors=True)
        try:
            os.rename(tmp, final)
        except OSError:            # concurrent save of the same step won
            shutil.rmtree(tmp, ignore_errors=True)

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if block:
        t.join()
    return t


def _readable_manifest(path: str) -> bool:
    """True when ``path`` parses as a checkpoint manifest — a truncated or
    garbage ``manifest.json`` (half-written before power loss, bit-rotted
    on disk) must make its checkpoint invisible, not crash the resume."""
    try:
        with open(path) as f:
            m = json.load(f)
        return isinstance(m, dict) and "leaves" in m
    except (OSError, ValueError):
        return False


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and ".tmp" not in d
             and _readable_manifest(os.path.join(ckpt_dir, d,
                                                 "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load ``step`` into the structure of ``like_tree``; if ``shardings``
    (a matching tree of NamedSharding) is given, device_put each leaf with
    it — this is the elastic-reshard path (new mesh, same logical specs).
    A corrupt or unreadable manifest raises ``ValueError`` (resume via
    ``latest_step`` never selects one)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise ValueError(
            f"checkpoint step_{step} has no readable manifest ({e}); "
            "it is corrupt or was never finalized — pick a step from "
            "latest_step(), which skips such checkpoints") from e
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        raise ValueError(
            f"checkpoint step_{step} manifest is not a leaves table; "
            "the checkpoint is corrupt")
    keyed, treedef = _flatten(like_tree)
    leaves = []
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(keyed))
    for (k, like), sh in zip(keyed.items(), shard_flat):
        arr = np.load(os.path.join(d, manifest["leaves"][k]))
        assert arr.shape == tuple(like.shape), (k, arr.shape, like.shape)
        leaves.append(jax.device_put(arr.astype(like.dtype), sh)
                      if sh is not None else jax.numpy.asarray(
                          arr.astype(like.dtype)))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, like_tree)),
        leaves)
