"""train_step: microbatched (grad-accumulation) loss/grad/update.

The microbatch loop is a ``lax.scan`` — gradients accumulate in f32 across
``cfg.microbatches`` slices of the global batch, and the cross-'data' (and
cross-'pod') gradient all-reduce happens once per *step*, not per microbatch:
the EBISU discipline (amortize synchronization over fused work) applied to
data parallelism.  XLA fuses the reduce into the optimizer update (ZeRO
moments are 'data'-sharded ⇒ reduce-scatter + all-gather)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api.attention import attention_program_for
from repro.models import transformer
from repro.train import optimizer as opt

ATTENTION_FAMILIES = ("dense", "moe", "vlm", "encoder", "hybrid")


def shift_labels(batch):
    """Next-token targets from tokens when labels are the same sequence."""
    if "tokens" in batch and "labels" in batch:
        lab = batch["labels"]
        mask = jnp.concatenate([jnp.ones_like(lab[:, :-1]),
                                jnp.zeros_like(lab[:, -1:])], axis=1)
        batch = dict(batch)
        batch["labels"] = jnp.concatenate(
            [lab[:, 1:], lab[:, -1:]], axis=1)
        batch["loss_mask"] = mask.astype(jnp.float32)
    return batch


def loss_fn(cfg, params, batch):
    return transformer.train_loss(cfg, params, shift_labels(batch))


def make_train_step(cfg, ocfg: opt.OptConfig):
    """Returns train_step(params, opt_state, batch) -> (params', state', metrics)."""
    n_micro = max(1, cfg.microbatches)
    # Resolve the attention program once at build time (compile-once
    # discipline): the traced model then hits the memoized handle, and a
    # bad head/chunk layout fails here, not deep inside the first trace.
    if cfg.family in ATTENTION_FAMILIES and cfg.attention_impl in (
            "flash_jnp", "flash_pallas"):
        attention_program_for(cfg, causal=cfg.family != "encoder")

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(
                functools.partial(loss_fn, cfg))(params, batch)
        else:
            def slice_micro(x, i):
                b = x.shape[0] // n_micro
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def body(carry, i):
                acc, tot = carry
                mb = jax.tree.map(lambda x: slice_micro(x, i), batch)
                l, g = jax.value_and_grad(
                    functools.partial(loss_fn, cfg))(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, tot + l), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0),
                                           jnp.arange(n_micro))
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        params, opt_state, stats = opt.adamw_update(ocfg, params, grads,
                                                    opt_state)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step
