"""Gradient compression for cross-pod (DCN) reductions.

The 'pod' axis of the production mesh crosses data-center networking, an
order of magnitude slower than ICI — the cross-pod gradient all-reduce is
the one collective worth compressing (DESIGN.md §5).  This module provides
an int8 stochastic-rounding quantized psum:

    q = clip(round_sr(x / scale), -127, 127)      scale = max|x| / 127
    y = dequant(psum(q)) · psum happens on int32 to avoid overflow

Stochastic rounding keeps the estimator unbiased (E[q·scale] = x), so SGD
convergence is preserved in expectation; the wire moves 1 byte/grad instead
of 4 (f32) or 2 (bf16).  ``compressed_psum_tree`` applies it leaf-wise with
per-leaf scales; exact-zero leaves stay exact.

Used by ``make_compressed_allreduce_step`` — a shard_map data-parallel
wrapper demonstrating the pattern end-to-end (tests/multidev_compress_child
checks the quantization error bound and training parity on 8 devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.distributed import shard_map_compat


def _stochastic_round(x, key):
    lo = jnp.floor(x)
    frac = x - lo
    return lo + (jax.random.uniform(key, x.shape) < frac).astype(x.dtype)


def quantize_int8(x, key):
    """x -> (int8 codes, f32 scale), unbiased under stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)).astype(jnp.float32), 1e-30) / 127.0
    q = _stochastic_round(x.astype(jnp.float32) / scale, key)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name, key):
    """Quantized all-reduce over ``axis_name``: int8 on the wire (psum in
    int32), scales max-combined. Returns the f32 mean-preserving sum."""
    # decorrelate rounding noise across shards (keeps unbiasedness)
    key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    q, scale = quantize_int8(x, key)
    # a shared scale keeps the sum linear: use the max scale across shards
    scale = jax.lax.pmax(scale, axis_name)
    q = _stochastic_round(x.astype(jnp.float32) / scale, key)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(tree, axis_name, key):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = [compressed_psum(leaf, axis_name, k)
           for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def make_compressed_allreduce_step(loss_fn, mesh, axis_name="data",
                                   lr: float = 1e-2):
    """Data-parallel SGD step with an int8-compressed gradient all-reduce —
    the demonstration harness for the DCN-compression pattern (in the full
    trainer the same compressed_psum_tree slots in for the 'pod' axis)."""
    n = mesh.shape[axis_name]

    def step(params, batch, key):
        def local_loss(p, b):
            return loss_fn(p, b)
        grads = jax.grad(local_loss)(params, batch)
        grads = compressed_psum_tree(grads, axis_name, key)
        grads = jax.tree.map(lambda g: g / n, grads)
        return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                            params, grads)

    return shard_map_compat(
        step, mesh,
        in_specs=(P(), P(axis_name), P()),
        out_specs=P())
