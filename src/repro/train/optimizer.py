"""AdamW + LR schedules (cosine, and minicpm's WSD) with ZeRO-sharded moments.

No optax in this container — the optimizer is ~100 lines of pytree math.
Moments are stored f32 regardless of param dtype, and their PartitionSpecs
extend the param specs with a 'data'-axis shard on the first divisible dim
(ZeRO-style: optimizer state is *fully* sharded over data×model, params stay
replicated over data so the forward pass needs no gathers; XLA turns the
grad-into-moment update into a reduce-scatter + the param update into an
all-gather automatically)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamDef, is_def


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"      # cosine | wsd | constant
    stable_frac: float = 0.8      # WSD: fraction of steps at peak LR


def schedule_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup)
                    / max(1, cfg.total_steps - cfg.warmup), 0.0, 1.0)
    if cfg.schedule == "wsd":
        # warmup -> stable plateau -> 1-sqrt decay (minicpm, arXiv:2404.06395)
        decay_frac = jnp.clip((frac - cfg.stable_frac)
                              / max(1e-6, 1 - cfg.stable_frac), 0.0, 1.0)
        return cfg.lr * warm * (1.0 - (1 - 0.1) * jnp.sqrt(decay_frac))
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def zero_pspec(d: ParamDef, data_axis: str = "data",
               data_size: int = 16) -> P:
    """Extend a param PartitionSpec with a 'data' shard on the first dim that
    is unsharded and divisible by the data-axis size (ZeRO-1)."""
    spec = list(d.pspec) + [None] * (len(d.shape) - len(d.pspec))
    flat = [a for s in spec if s for a in (s if isinstance(s, tuple)
                                           else (s,))]
    if data_axis in flat:              # FSDP params: already data-sharded
        return P(*spec)
    for i, (dim, cur) in enumerate(zip(d.shape, spec)):
        if cur is None and dim % data_size == 0 and dim >= data_size:
            spec[i] = data_axis
            break
    return P(*spec)


def opt_state_defs(param_tree, data_size: int = 16):
    """ParamDef tree for (m, v) moments, f32, ZeRO-sharded."""
    def mom(d: ParamDef) -> ParamDef:
        return ParamDef(d.shape, zero_pspec(d, data_size=data_size), "zeros",
                        dtype=jnp.float32)
    return {
        "m": jax.tree.map(mom, param_tree, is_leaf=is_def),
        "v": jax.tree.map(mom, param_tree, is_leaf=is_def),
        "count": ParamDef((), P(), "zeros", dtype=jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, state):
    step = state["count"]
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    t = (step + 1).astype(jnp.float32)
    corr = jnp.sqrt(1 - b2 ** t) / (1 - b1 ** t)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        delta = corr * m_new / (jnp.sqrt(v_new) + cfg.eps)
        p_new = p.astype(jnp.float32) * (1 - lr * cfg.weight_decay) \
            - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": step + 1}, \
        {"lr": lr, "grad_norm": gnorm}
