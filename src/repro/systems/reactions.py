"""Named pointwise reaction terms for coupled systems.

A reaction is the nonlinear, *zero-radius* part of a system update: after
every linear coupling has been applied for a temporal step, the reaction
maps ``(lin, prev) -> new`` cell-by-cell, where ``lin[f]`` is field
``f``'s accumulated linear update and ``prev[f]`` is its pre-step value
on the same (possibly trapezoid-narrowed) extent.  Because it reads no
neighbors, a reaction never changes the system radius — the deep-halo
geometry is derived from the couplings alone.

Reactions are *registered by name* so a :class:`~repro.systems.spec.
SystemSpec` stays a hashable value object (program/plan cache keys, JSON
round-trip): the spec stores a :class:`Reaction` — ``(name, params)`` —
and the executor resolves the callable through :data:`REACTIONS` at build
time.  Register your own with :func:`register_reaction`:

    @register_reaction("fisher", flops=4.0)
    def _fisher(r=1.0):
        def rx(lin, prev):
            return {f: lin[f] + r * prev[f] * (1.0 - prev[f]) for f in lin}
        return rx
"""
from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Reaction:
    """A registered reaction by name plus its (sorted, hashable) params.

        Reaction.make("gray_scott", {"F": 0.035, "k": 0.065})
    """

    name: str
    params: tuple[tuple[str, float], ...] = ()

    @staticmethod
    def make(name: str, params: dict | None = None) -> "Reaction":
        items = tuple(sorted((str(k), float(v))
                             for k, v in (params or {}).items()))
        return Reaction(name, items)

    def as_dict(self) -> dict:
        return dict(self.params)

    def __repr__(self) -> str:
        ps = ", ".join(f"{k}={v:g}" for k, v in self.params)
        return f"Reaction({self.name}{', ' + ps if ps else ''})"


# name -> (factory, flops_per_cell): factory(**params) returns the
# pointwise map ``rx(lin, prev) -> new`` over field dicts; flops is the
# per-cell estimate the system cost model adds (DESIGN.md §16).
REACTIONS: dict[str, tuple[Callable, float]] = {}


def register_reaction(name: str, *, flops: float = 0.0):
    """Decorator: register a reaction factory under ``name``.

    The factory takes the reaction's scalar parameters as keyword
    arguments and returns the ``rx(lin, prev) -> new`` callable; ``new``
    must hold a value for every field in ``lin``.
    """
    def deco(factory):
        REACTIONS[name] = (factory, float(flops))
        return factory
    return deco


def resolve_reaction(reaction: Reaction | None):
    """The executable ``rx(lin, prev)`` for a spec's reaction (or
    ``None``), with an unknown name refused naming the registry."""
    if reaction is None:
        return None
    try:
        factory, _ = REACTIONS[reaction.name]
    except KeyError:
        raise ValueError(
            f"unknown reaction {reaction.name!r}; registered reactions: "
            f"{sorted(REACTIONS)} — add one with "
            "repro.systems.register_reaction") from None
    return factory(**reaction.as_dict())


def reaction_flops(reaction: Reaction | None) -> float:
    if reaction is None:
        return 0.0
    try:
        return REACTIONS[reaction.name][1]
    except KeyError:
        raise ValueError(
            f"unknown reaction {reaction.name!r}; registered reactions: "
            f"{sorted(REACTIONS)}") from None


# ------------------------------------------------------------- built-ins ----
@register_reaction("gray_scott", flops=9.0)
def _gray_scott(F: float = 0.035, k: float = 0.065):
    """Gray–Scott kinetics on fields ``u`` (activator feed) and ``v``:

        u' = lin_u − u·v² + F·(1 − u)
        v' = lin_v + u·v² − (F + k)·v

    ``lin_*`` already carries identity + diffusion (the self-couplings),
    so this is the classic forward-Euler reaction-diffusion step.
    """
    def rx(lin, prev):
        u, v = prev["u"], prev["v"]
        uvv = u * v * v
        return {"u": lin["u"] - uvv + F * (1.0 - u),
                "v": lin["v"] + uvv - (F + k) * v}
    return rx
