"""Three worked coupled systems: the ``systems/`` counterpart of the
Table-2 registry — pre-built, parameterized, and driven through exactly
the open ``define_system`` path (specs are *input* to the machinery, the
registry is convenience).

  * ``gray-scott`` — the classic 2-field reaction-diffusion pattern
    former: diffusion self-couplings plus the registered ``gray_scott``
    kinetics (forward Euler, dt folded into the coefficients).
  * ``fdtd-acoustic`` — 2-D collocated-grid acoustic FDTD: pressure and
    two velocity components exchanging central-difference derivative
    couplings (antisymmetric taps — fine at any depth: systems re-pin
    non-periodic ghosts per step).  A simple collocated scheme, not a
    staggered Yee grid — DESIGN.md §16 records the assumption.
  * ``advection-diffusion`` — two species diffusing with an upwind
    advection drift on ``a`` (asymmetric taps) and a pointwise linear
    exchange between the species (identity cross-couplings: the
    radius-0 coupling case).

        from repro.systems import compile_system, get_system
        prog = compile_system(get_system("gray-scott"), (256, 256), t=4)
"""
from __future__ import annotations

from repro.systems.spec import SystemSpec, define_system


def _merge(*tapsets):
    acc: dict[tuple, float] = {}
    for taps in tapsets:
        for off, c in taps:
            acc[off] = acc.get(off, 0.0) + c
    return tuple((off, c) for off, c in acc.items() if c != 0.0)


def _ident(c: float = 1.0):
    return (((0, 0), c),)


def _lap(scale: float):
    """5-point Laplacian × scale."""
    return (((0, 0), -4.0 * scale), ((0, 1), scale), ((0, -1), scale),
            ((1, 0), scale), ((-1, 0), scale))


def _dx(c: float):
    """Central x-derivative × c (axis 1)."""
    return (((0, 1), 0.5 * c), ((0, -1), -0.5 * c))


def _dy(c: float):
    """Central y-derivative × c (axis 0)."""
    return (((1, 0), 0.5 * c), ((-1, 0), -0.5 * c))


def gray_scott(Du: float = 0.16, Dv: float = 0.08, F: float = 0.035,
               k: float = 0.065) -> SystemSpec:
    """Gray–Scott reaction-diffusion:  u' = u + Du·∇²u − u·v² + F(1−u),
    v' = v + Dv·∇²v + u·v² − (F+k)·v  (the u-spots/v-stripes regime)."""
    return define_system(
        fields=("u", "v"),
        couplings={("u", "u"): _merge(_ident(), _lap(Du)),
                   ("v", "v"): _merge(_ident(), _lap(Dv))},
        reactions=("gray_scott", {"F": F, "k": k}),
        name="gray-scott")


def fdtd_acoustic(kappa: float = 0.3, beta: float = 0.25) -> SystemSpec:
    """2-D acoustic FDTD on a collocated grid (p, vx, vy):

        p'  = p  − κ·(∂x vx + ∂y vy)
        vx' = vx − β·∂x p
        vy' = vy − β·∂y p

    Central differences; κ/β fold bulk modulus, density and dt."""
    return define_system(
        fields=("p", "vx", "vy"),
        couplings={("p", "p"): _ident(),
                   ("p", "vx"): _dx(-kappa),
                   ("p", "vy"): _dy(-kappa),
                   ("vx", "vx"): _ident(),
                   ("vx", "p"): _dx(-beta),
                   ("vy", "vy"): _ident(),
                   ("vy", "p"): _dy(-beta)},
        name="fdtd-acoustic")


def advection_diffusion(Da: float = 0.15, Db: float = 0.1,
                        ux: float = 0.4, uy: float = 0.2,
                        gamma: float = 0.05) -> SystemSpec:
    """Two exchanging species: ``a`` advects (first-order upwind for
    positive (ux, uy)) and diffuses; ``b`` only diffuses; both relax
    toward each other at rate γ (identity cross-couplings — the
    radius-0 coupling case the spec layer explicitly allows)."""
    adv = (((0, 0), -(ux + uy)), ((0, -1), ux), ((-1, 0), uy))
    return define_system(
        fields=("a", "b"),
        couplings={("a", "a"): _merge(_ident(1.0 - gamma), _lap(Da), adv),
                   ("a", "b"): _ident(gamma),
                   ("b", "b"): _merge(_ident(1.0 - gamma), _lap(Db)),
                   ("b", "a"): _ident(gamma)},
        name="advection-diffusion")


SYSTEMS = {"gray-scott": gray_scott,
           "fdtd-acoustic": fdtd_acoustic,
           "advection-diffusion": advection_diffusion}


def get_system(name: str, **params) -> SystemSpec:
    """Build a library system by name (``**params`` override the
    defaults of its builder)."""
    try:
        build = SYSTEMS[name]
    except KeyError:
        raise KeyError(
            f"unknown system {name!r} (choose from {sorted(SYSTEMS)}); "
            "arbitrary systems need no registry — build one with "
            "repro.systems.define_system(fields, couplings)") from None
    return build(**params)


def system_names() -> list[str]:
    return sorted(SYSTEMS)
