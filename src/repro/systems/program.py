"""``SystemProgram``: one fused trapezoid chain across the coupling.

The single-field executor's pitch — plan once, then drive deep temporal
blocking — generalizes to coupled systems by making the *system step* the
unit the trapezoid narrows: each temporal step applies every coupling
(valid-mode, cropping by the **system** radius) and then the pointwise
reaction, so all fields advance inside one fused jitted program and
temporal blocking spans the coupling instead of syncing per field per
step (the multi-field ``chain_trapezoid``):

    from repro.systems import compile_system, gray_scott
    prog = compile_system(gray_scott(), (256, 256), t=4,
                          boundary=Boundary.periodic())
    out = prog.run({"u": u0, "v": v0}, T=64)     # 16 fused sweeps

Boundary execution (DESIGN.md §16): **periodic** hoists the ghost fill —
every field is wrap-extended once by ``t·radius`` per sweep and the chain
narrows all fields by one radius per step (true deep blocking: halo
traffic amortized over ``t`` steps).  Every other kind (dirichlet of any
value, neumann of any flux, reflect) re-pins a one-radius ghost ring
**every step inside the same fused jit** — exact for arbitrary taps,
values and fluxes, which is why ``compile_system`` needs none of the
single-field path's closure refusals: the single-field reductions exist
to preserve the *zero-copy padded layout*, which the multi-field
executor does not use.

``run_lockstep`` is the deliberately-unfused reference: one separately
jitted dispatch per field per step (``T·n_fields`` dispatches) — the
baseline the ``systems/`` bench family measures the fused chain against,
and the equivalence target of the test suite.

All state lives in bounded :class:`~repro.api.program.ProgramCache`
instances; importing this module never initializes a JAX backend.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.api.boundary import ZERO, Boundary
from repro.api.program import (ProgramCache, _grouped,
                               resolve_compute_dtype, sweep_schedule)
from repro.kernels.taps import engine_for, ghost_extend
from repro.systems.reactions import resolve_reaction
from repro.systems.spec import SystemSpec

SYSTEM_PROGRAM_CACHE = ProgramCache(32, "system_programs")
SYSTEM_RUNNER_CACHE = ProgramCache(64, "system_runners")


def system_cache_stats() -> dict:
    """Hit/miss/size counters of the systems caches.

        from repro.systems import system_cache_stats
        system_cache_stats()["system_programs"]["hits"]
    """
    return {c.name: c.stats()
            for c in (SYSTEM_PROGRAM_CACHE, SYSTEM_RUNNER_CACHE)}


def clear_system_caches() -> None:
    for c in (SYSTEM_PROGRAM_CACHE, SYSTEM_RUNNER_CACHE):
        c.clear()


# ========================================================== the system step ==
def system_step(spec: SystemSpec, ext: dict, reaction_fn) -> dict:
    """One temporal step on ghost-extended fields, valid-mode.

    ``ext[f]`` carries at least one system-radius ring of context beyond
    the cells being produced; every coupling is applied with
    ``crops = radius`` (smaller-radius pairs still crop by the *system*
    radius — the tap engine's valid mode allows crop > tap reach), the
    per-destination terms are summed, and the reaction reads the
    pre-step values center-cropped to the output extent.  Every field
    shrinks by one system radius per side.
    """
    ndim, rad = spec.ndim, spec.radius
    crops = (rad,) * ndim
    lin: dict = {}
    for (dst, src), taps in spec.couplings:
        term = engine_for(taps, ndim).step(ext[src], crops=crops)
        lin[dst] = term if dst not in lin else lin[dst] + term
    if reaction_fn is None:
        return lin
    c = (Ellipsis,) + (slice(rad, -rad),) * ndim
    new = reaction_fn(lin, {f: ext[f][c] for f in spec.fields})
    missing = [f for f in spec.fields if f not in new]
    if missing:
        raise ValueError(
            f"reaction {spec.reaction!r} returned no value for field(s) "
            f"{missing}; a reaction must map (lin, prev) to every field")
    return {f: new[f] for f in spec.fields}


def _build_system_chain(spec: SystemSpec, shape, dtype, cdtype,
                        total_t: int, depth: int, boundary: Boundary):
    """The multi-sweep system schedule as an un-jitted f(fields) ->
    fields (the multi-field §9.3 executor)."""
    groups = _grouped(sweep_schedule(total_t, depth))
    ndim, rad = spec.ndim, spec.radius
    reaction_fn = resolve_reaction(spec.reaction)
    hoist = boundary.kind == "periodic"

    def sweep(cur: dict, d: int) -> dict:
        if hoist:
            # wrap-extend once per sweep by d·rad, narrow d times: the
            # ghost ring evolves exactly like the wrapped interior, so
            # the fill is hoisted out of the step loop (deep blocking)
            ext = {f: ghost_extend(cur[f], ndim, d * rad, boundary)
                   for f in spec.fields}
            for _ in range(d):
                ext = system_step(spec, ext, reaction_fn)
            return ext
        # dirichlet/neumann/reflect: the true boundary values depend on
        # the *evolved* field, so re-pin one ghost ring every step —
        # exact for any taps/value/flux, still one fused dispatch
        for _ in range(d):
            ext = {f: ghost_extend(cur[f], ndim, rad, boundary)
                   for f in spec.fields}
            cur = system_step(spec, ext, reaction_fn)
        return cur

    def run(fields: dict) -> dict:
        cur = {f: fields[f].astype(cdtype) for f in spec.fields}
        for d, count in groups:
            for _ in range(count):
                cur = sweep(cur, d)
        return {f: cur[f].astype(dtype) for f in spec.fields}

    return run


# ============================================================== programs ==
class SystemProgram:
    """An immutable compiled system: spec + domain shape + depth +
    boundary, with memoized jitted runners.  Construct via
    :func:`compile_system`:

        prog = compile_system(gray_scott(), (256, 256), t=4)
        out  = prog.apply(fields)          # one fused t-deep sweep
        out  = prog.run(fields, 64)        # 64 steps, chained sweeps
        outs = prog.run_batched(stacked, 64)
        ref  = prog.run_lockstep(fields, 64)   # unfused reference
    """

    def __init__(self, key, spec: SystemSpec, shape, dtype, t: int,
                 boundary: Boundary, compute_dtype):
        self._key = key
        self.spec = spec
        self.shape = shape
        self.dtype = dtype
        self.t = t
        self.boundary = boundary
        self.compute_dtype = compute_dtype

    # ------------------------------------------------------- execution ----
    def _check(self, fields: dict, batched: bool = False):
        if set(fields) != set(self.spec.fields):
            raise ValueError(
                f"system {self.spec.name} has fields "
                f"{list(self.spec.fields)}; got {sorted(fields)}")
        want = self.shape
        for f in self.spec.fields:
            got = tuple(fields[f].shape)
            body = got[1:] if batched else got
            if body != want:
                raise ValueError(
                    f"field {f!r} has shape {got}, but the program is "
                    f"compiled for {'batched ' if batched else ''}domain "
                    f"{want}; every field shares one domain — "
                    "compile_system a new program for a new shape")

    def _run_fn(self, total_t: int, depth: int | None = None):
        return _build_system_chain(
            self.spec, self.shape, self.dtype, self.compute_dtype,
            total_t, depth or max(1, min(self.t, total_t)), self.boundary)

    def apply(self, fields: dict, t: int | None = None) -> dict:
        """One fused sweep of depth ``t`` (default: the compiled depth)."""
        self._check(fields)
        depth = self.t if t is None else t
        if depth < 1:
            raise ValueError(f"temporal depth must be >= 1, got {depth} "
                             "(run(fields, 0) is the identity)")
        fn = SYSTEM_RUNNER_CACHE.get_or_build(
            (self._key, "apply", depth),
            lambda: jax.jit(self._run_fn(depth, depth)))
        return fn(fields)

    def run(self, fields: dict, total_t: int) -> dict:
        """``total_t`` steps as chained fused sweeps under one cached jit
        (remainder sweep included when ``t`` does not divide it)."""
        self._check(fields)
        if total_t == 0:
            return dict(fields)
        fn = SYSTEM_RUNNER_CACHE.get_or_build(
            (self._key, "run", total_t),
            lambda: jax.jit(self._run_fn(total_t)))
        return fn(fields)

    def run_batched(self, fields: dict, total_t: int | None = None) -> dict:
        """A leading batch axis on every field through ONE vmapped
        runner — a single jitted dispatch for the whole batch."""
        self._check(fields, batched=True)
        total_t = self.t if total_t is None else total_t
        if total_t == 0:
            return dict(fields)
        fn = SYSTEM_RUNNER_CACHE.get_or_build(
            (self._key, "batched", total_t),
            lambda: jax.jit(jax.vmap(self._run_fn(total_t))))
        return fn(fields)

    def run_lockstep(self, fields: dict, total_t: int) -> dict:
        """The unfused per-field-per-step reference: every step, each
        field's update is one separately jitted dispatch (``T·n_fields``
        dispatches, ghost ring re-pinned per step for every boundary) —
        the classic sync-per-field-per-step scheme the fused chain is
        benchmarked against, and numerically the same trajectory."""
        self._check(fields)
        cur = {f: fields[f].astype(self.compute_dtype)
               for f in self.spec.fields}
        for _ in range(total_t):
            cur = {f: self._lockstep_fn(f)(cur) for f in self.spec.fields}
        return {f: cur[f].astype(self.dtype) for f in self.spec.fields}

    def _lockstep_fn(self, dst: str):
        spec, boundary = self.spec, self.boundary
        reaction_fn = resolve_reaction(spec.reaction)

        def one(cur: dict):
            ext = {f: ghost_extend(cur[f], spec.ndim, spec.radius, boundary)
                   for f in spec.fields}
            return system_step(spec, ext, reaction_fn)[dst]

        return SYSTEM_RUNNER_CACHE.get_or_build(
            (self._key, "lockstep", dst), lambda: jax.jit(one))

    # ---------------------------------------------------- introspection ----
    def cost(self) -> dict:
        """The generalized §5 counting model for one step of the whole
        system over this domain: per-field and total flops, and the
        perfect-caching HBM bytes (``a_gm = 2·n_fields`` cells of the
        compute dtype per cell position)."""
        cells = math.prod(self.shape)
        per_field = self.spec.per_field_flops()
        return {
            "per_field_flops_per_cell": per_field,
            "flops_per_cell": self.spec.flops_per_cell,
            "flops_per_step": self.spec.flops_per_cell * cells,
            "hbm_bytes_per_step": (self.spec.a_gm * cells
                                   * self.compute_dtype.itemsize),
            "halo": self.spec.halo(self.t),
        }

    def cache_stats(self) -> dict:
        return system_cache_stats()

    def __repr__(self) -> str:
        return (f"SystemProgram({self.spec.name}, "
                f"fields={list(self.spec.fields)}, shape={self.shape}, "
                f"t={self.t}, boundary={self.boundary!r}, "
                f"dtype={self.dtype.name}/{self.compute_dtype.name})")


def compile_system(spec: SystemSpec, shape, *, t: int = 1,
                   dtype=jnp.float32, boundary: Boundary | None = None,
                   compute_dtype=None) -> SystemProgram:
    """Compile a :class:`~repro.systems.spec.SystemSpec` to an immutable
    :class:`SystemProgram` (memoized on the system *signature* — two
    structurally identical systems share one program regardless of name).

        from repro.systems import compile_system, get_system
        prog = compile_system(get_system("gray-scott"), (256, 256), t=4,
                              boundary=Boundary.neumann())
        out = prog.run({"u": u0, "v": v0}, 64)

    ``t`` is the fused sweep depth (there is no §6 planner for systems
    yet — DESIGN.md §16 records the default of 1 as explicit).  All four
    boundary kinds run exactly at any depth: periodic through the
    hoisted deep-halo trapezoid, the rest through per-step ghost
    re-pinning inside the fused chain — no closure refusals apply.
    """
    shape = tuple(int(n) for n in shape)
    if len(shape) != spec.ndim:
        raise ValueError(
            f"system {spec.name} is {spec.ndim}-D; got shape {shape}")
    if any(n < 2 * spec.radius + 2 for n in shape):
        raise ValueError(
            f"{spec.name}: domain {shape} has an extent smaller than "
            f"2·radius+2 = {2 * spec.radius + 2}; the halo would cover it")
    if t < 1:
        raise ValueError(f"temporal depth must be >= 1, got {t}")
    boundary = ZERO if boundary is None else boundary
    cdtype = resolve_compute_dtype(dtype, compute_dtype)
    resolve_reaction(spec.reaction)     # fail at compile, not at trace
    key = (spec.signature, shape, jnp.dtype(dtype).name, int(t),
           boundary, cdtype.name)
    cached = SYSTEM_PROGRAM_CACHE.get(key)
    if cached is not None:
        return cached
    prog = SystemProgram(key, spec, shape, jnp.dtype(dtype), int(t),
                         boundary, cdtype)
    SYSTEM_PROGRAM_CACHE.put(key, prog)
    return prog
