"""Coupled multi-field stencil systems with fused temporal blocking.

    from repro.api import Boundary
    from repro.systems import compile_system, define_system, get_system

    prog = compile_system(get_system("gray-scott"), (256, 256), t=4,
                          boundary=Boundary.periodic())
    out = prog.run({"u": u0, "v": v0}, T=64)   # 16 fused multi-field sweeps

A system is named fields + per-pair linear couplings + an optional
registered pointwise reaction (``repro.systems.reactions``); the
executor advances all fields inside ONE fused trapezoid-chained program,
so temporal blocking spans the coupling (guide: ``docs/systems.md``,
contract: DESIGN.md §16).  Importing this package never initializes a
JAX backend.
"""
from repro.systems.library import (SYSTEMS, advection_diffusion,
                                   fdtd_acoustic, get_system, gray_scott,
                                   system_names)
from repro.systems.program import (SystemProgram, clear_system_caches,
                                   compile_system, system_cache_stats,
                                   system_step)
from repro.systems.reactions import (REACTIONS, Reaction, register_reaction)
from repro.systems.spec import (SystemSpec, define_system, system_from_json,
                                system_to_json)

__all__ = [
    "REACTIONS",
    "Reaction",
    "SYSTEMS",
    "SystemProgram",
    "SystemSpec",
    "advection_diffusion",
    "clear_system_caches",
    "compile_system",
    "define_system",
    "fdtd_acoustic",
    "get_system",
    "gray_scott",
    "register_reaction",
    "system_cache_stats",
    "system_from_json",
    "system_names",
    "system_step",
    "system_to_json",
]
