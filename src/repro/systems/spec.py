"""Coupled multi-field system specs: the open definition layer, lifted.

A *system* is a set of named fields advanced together, where each field's
update is a sum of linear stencil couplings from (possibly other) fields
plus an optional pointwise reaction:

    f'  =  Σ_{(f, g) ∈ couplings} taps_{f,g} ⊛ g   then   reaction

``define_system`` is the one constructor, the multi-field twin of
``repro.core.stencil_spec.define_stencil``: it validates every per-pair
tap set through the same ``validate_taps`` machinery (``min_radius=0`` —
an identity-only coupling such as a reaction partner's pointwise feed is
legitimate; the *system* radius still has to clear 1), derives the
geometry and cost model from the coupling structure, and returns an
immutable, hashable :class:`SystemSpec`:

  * ``radius`` — the system radius: max over all coupling pairs.  One
    temporal step of the whole system reaches ``radius`` cells, so deep
    blocking extends every field by ``t·radius`` regardless of which
    pair contributed the reach (the shared-cache lesson of Wittmann et
    al.: the blocking geometry must span *all* fields updated per step).
  * cost model — flops per cell summed over destination fields (2 per
    tap, as in the single-field derivation) plus the reaction's
    registered estimate; ``a_gm = 2·n_fields`` (one load + one store per
    cell *per field* under perfect caching, §6.2 lifted).

``signature`` is the registry-free planning/caching identity (structure
only, no names) — ``compile_system`` keys its program cache on it.
JSON round-trip via :func:`system_to_json` / :func:`system_from_json`
(``repro.api.spec_from_json`` dispatches here on a ``"fields"`` key).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence, Tuple

from repro.core.stencil_spec import (MAX_RADIUS, taps_radius, validate_taps)
from repro.systems.reactions import (Reaction, reaction_flops,
                                     resolve_reaction)

Taps = Tuple[Tuple[Tuple[int, ...], float], ...]
Pair = Tuple[str, str]          # (dst, src)

DEFAULT_DOMAINS = {2: (512, 512), 3: (96, 96, 96)}


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    name: str
    ndim: int
    radius: int                                  # max over coupling pairs
    fields: Tuple[str, ...]                      # declaration order
    couplings: Tuple[Tuple[Pair, Taps], ...]     # sorted by (dst, src)
    reaction: Reaction | None
    flops_per_cell: float                        # summed over dst + reaction
    a_gm: float                                  # 2·n_fields (§6.2 lifted)
    domain: Tuple[int, ...]

    @property
    def nfields(self) -> int:
        return len(self.fields)

    @property
    def signature(self) -> tuple:
        """Registry-free caching identity: the coupling structure and the
        reaction, not the system's name — two differently-named systems
        with identical structure share compiled programs."""
        return (self.ndim, self.fields, self.couplings, self.reaction)

    def halo(self, t: int) -> int:
        """Deep-block halo: every field extends ``t·radius`` per side."""
        return self.radius * t

    def taps_into(self, dst: str) -> Tuple[Tuple[str, Taps], ...]:
        """The ``(src, taps)`` couplings feeding field ``dst``."""
        return tuple((src, taps) for (d, src), taps in self.couplings
                     if d == dst)

    def per_field_flops(self) -> dict[str, float]:
        """Per-destination-field flops/cell (2 per tap, reaction spread
        evenly) — the generalized §5 counting model."""
        out = {f: 0.0 for f in self.fields}
        for (dst, _), taps in self.couplings:
            out[dst] += 2.0 * len(taps)
        rx = reaction_flops(self.reaction)
        for f in out:
            out[f] += rx / len(self.fields)
        return out

    def __repr__(self) -> str:
        return (f"SystemSpec({self.name}, fields={list(self.fields)}, "
                f"ndim={self.ndim}, radius={self.radius}, "
                f"couplings={len(self.couplings)}, "
                f"reaction={self.reaction!r})")


# =============================================================== builder ===
def define_system(fields: Sequence[str], couplings, reactions=None, *,
                  name: str | None = None,
                  domain: Tuple[int, ...] | None = None) -> SystemSpec:
    """Build a validated :class:`SystemSpec`.

        from repro.systems import define_system
        sys = define_system(
            fields=["u", "v"],
            couplings={("u", "u"): u_taps, ("v", "v"): v_taps},
            reactions=("gray_scott", {"F": 0.035, "k": 0.065}))

    ``couplings`` maps ``(dst, src)`` field-name pairs to tap sets (any
    mapping or iterable of ``((dst, src), taps)`` pairs).  ``reactions``
    is ``None``, a registered reaction name, ``(name, params)``, or a
    :class:`~repro.systems.reactions.Reaction`.  Every field must be the
    destination of at least one coupling (its update is undefined
    otherwise — feed it an identity coupling ``{(f, f): (((0,)*ndim,
    1.0),)}`` to carry it unchanged into the reaction).
    """
    fields = tuple(str(f) for f in fields)
    if not fields:
        raise ValueError("a system needs at least one field; got none")
    dup = {f for f in fields if fields.count(f) > 1}
    if dup:
        raise ValueError(f"duplicate field name(s) {sorted(dup)}; field "
                         "names must be unique")

    items = list(couplings.items()) if hasattr(couplings, "items") \
        else list(couplings)
    if not items:
        raise ValueError("a system needs at least one coupling; got none "
                         "(couplings={(dst, src): taps, ...})")
    norm: dict[Pair, Taps] = {}
    ndim = None
    for pair, taps in items:
        pair = tuple(pair)
        if len(pair) != 2 or not all(isinstance(p, str) for p in pair):
            raise ValueError(
                f"coupling keys are (dst, src) field-name pairs; got "
                f"{pair!r}")
        dst, src = pair
        for end, role in ((dst, "destination"), (src, "source")):
            if end not in fields:
                raise ValueError(
                    f"coupling ({dst!r}, {src!r}) has a dangling {role} "
                    f"{end!r} — not one of the declared fields "
                    f"{list(fields)}")
        if pair in norm:
            raise ValueError(
                f"duplicate coupling ({dst!r}, {src!r}); merge the tap "
                "sets into one coupling per (dst, src) pair")
        taps = tuple((tuple(int(o) for o in off), float(c))
                     for off, c in taps)
        nd, _ = validate_taps(taps, min_radius=0)
        if ndim is None:
            ndim = nd
        elif nd != ndim:
            raise ValueError(
                f"coupling ({dst!r}, {src!r}) has {nd}-D offsets but the "
                f"system is {ndim}-D — every coupling must share one "
                "dimensionality")
        norm[pair] = taps

    uncovered = [f for f in fields if not any(d == f for d, _ in norm)]
    if uncovered:
        raise ValueError(
            f"field(s) {uncovered} are the destination of no coupling, so "
            "their update is undefined; add an identity self-coupling "
            "{(f, f): (((0,)*ndim, 1.0),)} to carry them into the "
            "reaction")

    radius = max(taps_radius(t) for t in norm.values())
    if radius < 1:
        raise ValueError(
            "system radius is 0 (every coupling is identity-only); "
            "temporal blocking needs at least one spatial tap somewhere "
            "(radius >= 1)")
    assert radius <= MAX_RADIUS     # per-pair validate_taps enforced it

    if reactions is None or isinstance(reactions, Reaction):
        reaction = reactions
    elif isinstance(reactions, str):
        reaction = Reaction.make(reactions)
    else:
        rname, params = reactions
        reaction = Reaction.make(rname, params)
    resolve_reaction(reaction)      # unknown names refused at define time

    flops = (sum(2.0 * len(t) for t in norm.values())
             + reaction_flops(reaction))
    spec = SystemSpec(
        name=name or f"sys{ndim}d{len(fields)}f",
        ndim=ndim, radius=radius, fields=fields,
        couplings=tuple(sorted(norm.items())),
        reaction=reaction, flops_per_cell=flops,
        a_gm=2.0 * len(fields),
        domain=tuple(domain) if domain is not None else DEFAULT_DOMAINS[ndim])
    return spec


# ========================================================= JSON round-trip ==
def system_to_json(spec: SystemSpec) -> dict:
    """A JSON-safe dict that :func:`system_from_json` rebuilds exactly
    (field order, per-pair taps, reaction by registered name)."""
    return {
        "name": spec.name,
        "fields": list(spec.fields),
        "couplings": [[dst, src, [[list(off), c] for off, c in taps]]
                      for (dst, src), taps in spec.couplings],
        "reaction": (None if spec.reaction is None else
                     {"name": spec.reaction.name,
                      "params": spec.reaction.as_dict()}),
        "domain": list(spec.domain),
    }


def system_from_json(source) -> SystemSpec:
    """Rebuild a :class:`SystemSpec` from :func:`system_to_json` output
    (a dict, a JSON string, or a path to a JSON file).

        spec2 = system_from_json(system_to_json(spec))
        assert spec2.signature == spec.signature
    """
    if isinstance(source, str):
        if source.lstrip().startswith("{"):
            obj = json.loads(source)
        else:
            with open(source) as f:
                obj = json.load(f)
    else:
        obj = dict(source)
    if "fields" not in obj or "couplings" not in obj:
        raise ValueError(
            "system JSON needs 'fields' and 'couplings' keys — see "
            "repro.systems.system_to_json for the schema")
    couplings = {}
    for entry in obj["couplings"]:
        if len(entry) != 3:
            raise ValueError(
                f"each coupling entry is [dst, src, taps]; got {entry!r}")
        dst, src, taps = entry
        couplings[(dst, src)] = tuple(
            (tuple(int(o) for o in off), float(c)) for off, c in taps)
    rx = obj.get("reaction")
    reactions = None if rx is None else (rx["name"], rx.get("params", {}))
    kw = {}
    if "domain" in obj:
        kw["domain"] = tuple(int(d) for d in obj["domain"])
    return define_system(obj["fields"], couplings, reactions,
                         name=obj.get("name"), **kw)
