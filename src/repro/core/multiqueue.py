"""§4.2 of the paper: the (circular) multi-queue data structure.

A multi-queue is one queue per temporal-blocking step; queue ``s`` holds the
most recent ``2·rad+1`` planes of the time-``s`` field.  When input plane ``z``
(time 0) is enqueued, planes ``z - s·rad`` of time ``s`` become computable for
``s = 1..t`` ("streaming"); dequeue of step ``s`` overlaps enqueue of step
``s+1`` (paper Fig. 5).

Two circular addressing modes (§4.2.2):
  * ``computing``: ring size is a power of two so slot = ``z & (R-1)``
    (the paper's `index % range == index & (range-1)` trick);
  * ``shifting``: indices are physically shifted at the per-tile "shuffle".

This module is the *index algebra*, shared by the Pallas kernels (which bake
it into VMEM scratch indexing) and by the hypothesis property tests (which
check the invariants on a host-side queue simulation).

Lazy-batched streaming (§4.3.2) generalizes the queue advance from one
plane to ``B`` planes per stage: ``choose_batch``/``stream_schedule`` are
the shared batch-granularity algebra used by the 3-D streamer kernel and
by the planner's ``lazy_batch`` decision, so both always agree on the
batch a launch will actually run.
"""
from __future__ import annotations

import dataclasses

from repro.core.planner import next_pow2


def choose_batch(span: int, halo: int, target: int) -> int:
    """Batch granularity for lazy streaming over ``span = zc + 2·halo`` planes.

    The batch must be a multiple of ``halo`` (so every batch is whole
    halo-sub-blocks of the halo-exact fetch) and divide ``span`` (so the
    statically-unrolled schedule has no partial stage).  Returns the
    largest such batch not exceeding ``max(target, halo)`` — ``target``
    is the planner's ``lazy_batch``; the floor is one halo sub-block.
    """
    assert span % halo == 0 and span > 0, (span, halo)
    d_max = span // halo
    best = halo
    for d in range(1, d_max + 1):
        if d_max % d == 0 and halo * d <= max(target, halo):
            best = halo * d
    return best


def stream_schedule(zc: int, halo: int, rad: int, target: int):
    """(batch, window, stages) the batched streamer will use for a chunk."""
    span = zc + 2 * halo
    batch = choose_batch(span, halo, target)
    return batch, batch + 2 * rad, span // batch


@dataclasses.dataclass(frozen=True)
class MultiQueueLayout:
    depth: int          # t, number of temporal steps (queues)
    radius: int         # stencil radius
    ring: int           # slots per queue (pow2 for 'computing' mode)
    addressing: str = "computing"

    @classmethod
    def make(cls, depth: int, radius: int, addressing: str = "computing"):
        need = 2 * radius + 2            # 2·rad+1 live planes + 1 write slot
        ring = next_pow2(need) if addressing == "computing" else need
        return cls(depth, radius, ring, addressing)

    # ---------------------------------------------------------------- slots
    def slot(self, z: int) -> int:
        """Ring slot for plane index z (same algebra for every queue)."""
        if self.addressing == "computing":
            return z & (self.ring - 1)
        return z % self.ring

    def producible(self, s: int, z_in: int) -> int:
        """Highest plane of time-step ``s`` computable once input plane
        ``z_in`` (time 0) has been enqueued: z_in - s·rad."""
        return z_in - s * self.radius

    def window(self, s: int, z_out: int) -> list[int]:
        """Plane indices of time-step ``s-1`` read to produce plane ``z_out``
        of time-step ``s``."""
        return list(range(z_out - self.radius, z_out + self.radius + 1))

    def live_span(self) -> int:
        """Number of planes that must stay live per queue (ring lower bound)."""
        return 2 * self.radius + 1

    def total_planes(self) -> int:
        return self.depth * self.ring

    def check(self) -> None:
        """Invariants the kernels rely on."""
        assert self.ring >= self.live_span() + 1, "write slot would clobber a live plane"
        if self.addressing == "computing":
            assert self.ring & (self.ring - 1) == 0, "computing mode needs pow2 ring"
