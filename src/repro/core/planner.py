"""§6 of the paper: EBISU's design decisions, as an executable planner.

Given a stencil spec + hardware model, the planner reproduces the paper's
decision procedure (Table 1):

  1. *Minimal necessary parallelism* (§6.1, Little's law): the minimum
     in-flight work that saturates the device.  On TPU this fixes the DMA
     pipeline depth (num_buffers) and the vector unroll factor (ILP).
  2. *Desired depth* (§6.2): deep enough to shift the bottleneck gm→sm
     (2-D, Eq 17), or as deep as on-chip capacity allows (3-D, Eq 18/19).
  3. *Device tiling or SM tiling* (§6.3): compare PP_Dtile vs PP_SMtile.
     (On TPU, a Pallas grid step *is* a device tile; "SM tiling" maps to
     overlapped halo tiles with redundant compute.)
  4. *Deeper or wider* (§6.4, Eq 23): minimum tile width so that halo traffic
     stays sub-dominant; then spend remaining capacity on depth.
  5. Circular multi-queue addressing mode (Table 1): computing (2-D) /
     shifting (3-D) — on TPU we always use the power-of-two "computing"
     ring (idx & (R-1)); the planner records the paper's choice for the
     A100 model.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core import roofline as rl
from repro.core.stencil_spec import StencilSpec


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class Parallelism:
    """§6.1 output: minimal parallelism that saturates the device."""
    bytes_in_flight: float     # Little's law: L × THR for device memory
    num_buffers: int           # DMA pipeline depth (≥2 = double buffering)
    ilp: int                   # vector unroll factor per plane-step
    min_tile_elems: int        # ≥ 8×128 × ilp elements of vector work


@dataclasses.dataclass(frozen=True)
class EbisuPlan:
    spec_name: str             # display/debug only — plan caching keys on
    # the tap-structure signature (repro.api.plan_bucketed), never the name
    hw_name: str
    tiling: str                # 'device' | 'sm'
    t: int                     # temporal blocking depth
    block: tuple[int, ...]     # per-grid-step tile (2-D: (bh, W); 3-D: (zc, Y, X))
    halo: int                  # t · rad
    ring: int                  # circular multi-queue ring size (pow2)
    addressing: str            # 'computing' | 'shifting'
    lazy_batch: int            # planes processed per ring advance (lazy streaming)
    parallelism: Parallelism
    vmem_bytes: int            # scratch footprint the kernel will claim
    pp: rl.RooflineResult      # predicted practical attainable performance


def minimal_parallelism(hw: rl.HardwareModel, plane_bytes: int) -> Parallelism:
    """Little's law (Eq 13–16): concurrency = latency × throughput.

    For a memory-bound stencil the binding resource is device-memory traffic:
    we need `L_gm × B_gm` bytes in flight.  The Pallas pipeline provides
    parallelism in units of buffered blocks, so num_buffers =
    ceil(bytes_in_flight / plane_bytes) + 1, clamped to [2, 4] (the same
    role as the paper's ILP=4 @ occupancy 12.5%)."""
    bif = hw.mem_latency * hw.b_gm
    nbuf = max(2, min(4, int(math.ceil(bif / max(plane_bytes, 1))) + 1))
    ilp = 4                    # paper §6.1: ILP=4 saturates ALU/smem/gm paths
    return Parallelism(bytes_in_flight=bif, num_buffers=nbuf, ilp=ilp,
                       min_tile_elems=8 * 128 * ilp)


def vmem_required_2d(spec: StencilSpec, t: int, bh: int, width: int,
                     s_cell: int, num_buffers: int) -> int:
    """2-D strip kernel: two ping-pong strip buffers + pipeline buffers."""
    strip = (bh + 2 * spec.halo(t)) * (width + 2 * spec.radius)
    io = num_buffers * bh * width * 2          # in + out pipeline blocks
    return int((2 * strip + io) * s_cell)


def vmem_required_3d(spec: StencilSpec, t: int, zc: int, ny: int, nx: int,
                     s_cell: int, num_buffers: int) -> int:
    """3-D streaming kernel: t queue rings of pow2(2·rad+2) planes + I/O.

    Legacy plane-at-a-time model (kept as the capacity-affordability
    yardstick the A100-vs-TPU comparison tests use); the planner itself
    budgets with ``vmem_required_3d_batched``, which models the batched
    shifting windows the kernel actually allocates.
    """
    ring = next_pow2(2 * spec.radius + 2)
    planes = t * ring * ny * nx
    # I/O staging is per-plane (the kernel streams planes; the Pallas pipeline
    # may buffer more on TPU — Mosaic verifies the real budget at compile).
    io = num_buffers * 2 * ny * nx
    del zc
    return int((planes + io) * s_cell)


def vmem_required_3d_batched(spec: StencilSpec, t: int, zc: int, batch: int,
                             ny: int, nx: int, s_cell: int,
                             num_buffers: int) -> int:
    """Batched z-streaming footprint: what ``ebisu3d`` actually claims.

    ``t`` shifting windows of ``batch + 2·rad`` planes each (§4.2.2
    shifting mode, advanced ``batch`` planes per stage), plus
    ``num_buffers``-deep staging of the whole-block I/O the Pallas
    pipeline delivers: ``zc + 2·halo`` input planes (the halo-exact
    views) and ``zc`` output planes per grid step — the same quantity
    the kernel's own ``vmem_limit_bytes`` hint is sized from.
    """
    w = batch + 2 * spec.radius
    planes = t * w * ny * nx
    io = num_buffers * (2 * zc + 2 * spec.halo(t)) * ny * nx
    return int((planes + io) * s_cell)


def fit_streaming_batch(spec: StencilSpec, t: int, zc: int, ny: int, nx: int,
                        s_cell: int, num_buffers: int,
                        budget: float) -> int | None:
    """Largest streaming batch whose windows + I/O staging fit ``budget``.

    The batch must be a halo-multiple divisor of the ``zc + 2·halo`` span
    (``multiqueue.choose_batch``); shrinks one halo at a time, ``None``
    if even a single halo sub-block does not fit.  Shared by the §6
    planner and the multi-sweep executor so both always budget a launch
    with the same model (``vmem_required_3d_batched`` at the *haloed*
    working extents ``ny × nx``)."""
    from repro.core.multiqueue import choose_batch

    halo = spec.halo(t)
    span = zc + 2 * halo
    b = choose_batch(span, halo, zc)
    while (vmem_required_3d_batched(spec, t, zc, b, ny, nx,
                                    s_cell, num_buffers) > budget):
        if b <= halo:
            return None
        b = choose_batch(span, halo, b - halo)
    return b


def plan(spec: StencilSpec, hw: rl.HardwareModel,
         domain: tuple[int, ...] | None = None,
         max_t: int = 32) -> EbisuPlan:
    domain = domain or spec.domain
    rad = spec.radius

    if spec.ndim == 2:
        height, width = domain
        budget = hw.onchip_device_bytes or hw.onchip_bytes
        rad = spec.radius

        def q_bytes(t_c, w):
            ring = next_pow2(2 * rad + 2)
            return t_c * ring * (w + 2 * rad) * hw.s_cell

        # §6.2 Eq 17: depth that shifts the bottleneck gm->sm (paper: 6.3 ->
        # t=7 for j2d5pt; its +10%-at-t=12 fine-tune stems from imperfect
        # caching, outside the model — we keep the analytic depth).
        t = min(max_t, max(1, int(math.ceil(
            rl.desired_depth(spec, hw, rst=True)))))
        # §6.4 deeper-or-wider: prefer full-width streaming; shrink the tile
        # width toward max(256, Eq 23) only if the queues don't fit.
        min_w = max(256, int(math.ceil(rl.min_tile_width(spec, hw))))
        tile_w = width
        while q_bytes(t, tile_w) > 0.5 * budget and tile_w // 2 >= min_w:
            tile_w //= 2
        while t > 1 and q_bytes(t, tile_w) > 0.5 * budget:
            t -= 1
        zc = max(64, 4 * spec.halo(t))
        par = minimal_parallelism(hw, tile_w * hw.s_cell)
        if tile_w < width:
            # x-halo overlap (Eq 8, one-sided), continuous streaming in y
            v_spatial = max(0.05, (tile_w - 2 * spec.halo(t)) / tile_w)
        else:
            # full-width stream, chunked in y (neighbor-block kernel):
            # per-chunk halo overlap along the streamed dim
            v_spatial = zc / (zc + 2 * spec.halo(t))
        res = rl.attainable(spec, t, hw, rst=True,
                            v=v_spatial * rl.v_dtile(
                                _tile_time(spec, t, hw, zc * tile_w), hw, 1),
                            d_all=math.prod(domain))
        vmem = q_bytes(t, tile_w) + par.num_buffers * 2 * zc * tile_w * hw.s_cell
        return EbisuPlan(spec.name, hw.name, "device", t, (zc, tile_w),
                         spec.halo(t), next_pow2(2 * rad + 2), "computing",
                         lazy_batch=zc, parallelism=par,
                         vmem_bytes=int(vmem), pp=res)

    # --- 3-D: device tiling (§6.3.2), stream z, model-driven depth ---------
    _, ny, nx = domain
    # §6.4 "deeper or wider": start from the widest XY tile (halo overhead
    # confined to z) and shrink toward the Eq-23 minimum width until t=1 fits
    # the scratchpad.  The A100 model lands near the paper's 32x32 Table-1
    # choice; the TPU model keeps full planes (128 MiB VMEM).
    budget = hw.onchip_device_bytes or hw.onchip_bytes
    min_w = max(8, int(math.ceil(rl.min_tile_width(spec, hw, rst=True))))
    ty, tx = ny, nx

    def _work_xy(ty_c: int, tx_c: int, halo: int) -> tuple[int, int]:
        """In-plane extents the kernel actually allocates/fetches: tiled
        axes carry their fetched halo (``tile + 2·halo``); untiled axes
        are the bare domain extent."""
        return (ty_c + 2 * halo if ty_c < ny else ty_c,
                tx_c + 2 * halo if tx_c < nx else tx_c)

    def _floor_footprint(ty_c: int, tx_c: int, nbuf: int = 2) -> int:
        """Smallest possible launch (t=1, minimal batch) at this xy tile."""
        halo1 = spec.radius
        zc1 = -(-max(16, 4 * halo1) // halo1) * halo1
        ey, ex = _work_xy(ty_c, tx_c, halo1)
        return vmem_required_3d_batched(spec, 1, zc1, halo1, ey, ex,
                                        hw.s_cell, nbuf)

    while _floor_footprint(ty, tx) > budget and max(ty, tx) > min_w:
        if ty >= tx:
            ty = max(min_w, ty // 2)
        else:
            tx = max(min_w, tx // 2)
    par = minimal_parallelism(hw, ty * tx * hw.s_cell)
    # Little's law wants deep pipelining, but capacity wins: clamp the
    # buffer depth back to what leaves room for at least a t=1 launch.
    nbuf = par.num_buffers
    while nbuf > 2 and _floor_footprint(ty, tx, nbuf) > budget:
        nbuf -= 1
    if nbuf != par.num_buffers:
        par = dataclasses.replace(par, num_buffers=nbuf)

    # §5-model-driven choice of (t, zc, lazy_batch): maximize PP subject to
    # capacity, budgeting the batched shifting windows the kernel allocates.
    def _snap_xy(t_c: int) -> tuple[int, int]:
        """Round the capacity-driven xy tile to what the kernel can launch:
        a halo(t_c) multiple (block-aligned rim sub-blocks, DESIGN.md §8.4).
        A tile that rounds up to the full extent means the axis is untiled."""
        if (ty, tx) == (ny, nx):
            return ny, nx
        h = spec.halo(t_c)
        return (min(ny, -(-max(ty, h) // h) * h),
                min(nx, -(-max(tx, h) // h) * h))

    def _fit_batch(t_c: int, zc_c: int, ty_c: int, tx_c: int) -> int | None:
        ey, ex = _work_xy(ty_c, tx_c, spec.halo(t_c))
        return fit_streaming_batch(spec, t_c, zc_c, ey, ex, hw.s_cell,
                                   par.num_buffers, budget)

    best = None
    for t_c in range(1, max_t + 1):
        halo = spec.halo(t_c)
        # keep z-overlap V >= 2/3; rounded so halo sub-blocks tile the chunk
        zc_c = -(-max(16, 4 * halo) // halo) * halo
        ty_c, tx_c = _snap_xy(t_c)
        b = _fit_batch(t_c, zc_c, ty_c, tx_c)
        if b is None:
            break
        v = zc_c / (zc_c + 2 * halo)
        if (ty_c, tx_c) != (ny, nx):         # xy redundancy when tiled (Eq 9)
            v = max(0.01, v * rl.v_smtile(spec, t_c, (ty_c, tx_c)))
        v *= rl.v_dtile(_tile_time(spec, t_c, hw, zc_c * ty_c * tx_c), hw, 1)
        cand = rl.attainable(spec, t_c, hw, rst=True, v=v,
                             d_all=math.prod(domain))
        if best is None or cand.pp_cells_per_s > best[4].pp_cells_per_s:
            best = (t_c, zc_c, b, (ty_c, tx_c), cand)
    if best is None:
        raise ValueError(
            f"{spec.name}: on-chip budget {budget:.0f}B on {hw.name} cannot "
            f"fit even a t=1 launch at xy tile ({ty}, {tx}) — no feasible "
            f"EBISU plan")
    t, zc, lazy, (ty, tx), res = best
    ey, ex = _work_xy(ty, tx, spec.halo(t))
    return EbisuPlan(spec.name, hw.name, "device", t, (zc, ty, tx),
                     spec.halo(t), next_pow2(2 * rad + 2),
                     "shifting" if hw.name.startswith("a100") else "computing",
                     lazy_batch=lazy, parallelism=par,
                     vmem_bytes=vmem_required_3d_batched(
                         spec, t, zc, lazy, ey, ex, hw.s_cell,
                         par.num_buffers),
                     pp=res)


def _tile_time(spec: StencilSpec, t: int, hw: rl.HardwareModel,
               tile_cells: int) -> float:
    tg, ts, tc, _ = rl.component_times(spec, t, hw, rst=True, d_all=tile_cells)
    return max(tg, ts, tc)
