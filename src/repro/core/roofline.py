"""§5 of the paper: Practical Attainable Performance  PP = P × V.

``P`` is a three-pressure-point roofline (device memory, scratchpad, compute):

    T_gm  = a_gm · D_gm / B_gm · S_cell                     (Eq 2)
    T_sm  = a_sm · D_sm · t / B_sm · S_cell                 (Eq 3)
    T_cmp = a_cmp · D_cmp · t / THR_cmp                     (Eq 4)
    T     = max(T_gm, T_sm, T_cmp)                          (Eq 5)
    P     = D_all · t / T                                   (Eq 7)

``V`` is the valid fraction lost to overlapped-tiling redundancy (Eq 8/9) or to
device-wide synchronization (Eq 11).

Two hardware models are registered:
  * ``A100_FP64`` — the paper's platform, with the paper's published constants;
    used by the tests to check that this implementation of the model reproduces
    the paper's own derivations (t ≥ 6.3 for j2d5pt, t > 18.34 for j3d7pt,
    V_Dtile ≈ 63% / ≈ 67%, …).
  * ``TPU_V5E`` — the target platform for this repo (f32 cells, VPU compute).
    HBM/ICI/MXU constants are the assignment's given numbers; VMEM bandwidth
    and VPU f32 throughput are documented estimates (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.stencil_spec import StencilSpec


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    b_gm: float          # device memory bandwidth, B/s
    b_sm: float          # scratchpad bandwidth, B/s
    thr_cmp: float       # stencil-relevant compute throughput, FLOP/s
    t_dsync: float       # device-wide sync overhead, s
    s_cell: int          # bytes per cell
    onchip_bytes: float  # scratchpad capacity usable by one resident tile
    onchip_device_bytes: float = 0.0  # device-wide aggregate (device tiling:
    # the paper's 3-D scheme spans ALL SMs' shared memory via grid sync)
    # --- distribution (TPU only; 0 on single-GPU models) ---
    b_ici: float = 0.0   # per-link ICI bandwidth, B/s
    ici_links: int = 0   # links per chip usable for halo exchange
    hbm_bytes: float = 0.0
    mxu_flops: float = 0.0        # bf16 matmul peak (for LM roofline)
    mem_latency: float = 0.0      # device-memory latency, s (Little's law)


# The paper's constants (§6.2.1, §5.2.2, Table in §6): FP64 cells.
A100_FP64 = HardwareModel(
    name="a100-fp64",
    b_gm=1555e9,
    b_sm=19.49e12,
    thr_cmp=9.7e12,          # A100 FP64 peak (non-tensor) ~9.7 TFLOP/s
    t_dsync=1.2e-6,          # grid sync, measured by [57] (paper §5.2.2)
    s_cell=8,
    onchip_bytes=164e3,      # shared memory per SM (A100)
    onchip_device_bytes=17.7e6,  # 108 SMs aggregate (paper §1: 17,712 KB)
    mem_latency=400e-9,
)

# Target platform. Given constants: 197 TFLOP/s bf16 MXU, 819 GB/s HBM,
# ~50 GB/s/link ICI. Estimates (documented in DESIGN.md): VMEM bw ~16 TB/s,
# VPU f32 ~4 TFLOP/s, per-grid-step overhead ~1 µs, VMEM 128 MiB.
TPU_V5E = HardwareModel(
    name="tpu-v5e-f32",
    b_gm=819e9,
    b_sm=16e12,
    thr_cmp=7.9e12,          # VPU f32 ~ MXU/25 (documented estimate)
    t_dsync=1.0e-6,
    s_cell=4,
    onchip_bytes=128 * 2**20,
    onchip_device_bytes=128 * 2**20,  # one core per v5e chip
    b_ici=50e9,
    ici_links=4,
    hbm_bytes=16 * 2**30,
    mxu_flops=197e12,
    mem_latency=500e-9,
)


@dataclasses.dataclass(frozen=True)
class RooflineResult:
    t_gm: float
    t_sm: float
    t_cmp: float
    bottleneck: str          # 'gm' | 'sm' | 'cmp'
    p_cells_per_s: float     # Eq 7 (attainable)
    v: float                 # valid fraction
    pp_cells_per_s: float    # Eq 1 (practical attainable)
    gflops: float            # PP expressed in FLOP/s via flops_per_cell

    @property
    def t_stencil(self) -> float:
        return max(self.t_gm, self.t_sm, self.t_cmp)


def component_times(spec: StencilSpec, t: int, hw: HardwareModel, *,
                    rst: bool = True,
                    d_gm: float | None = None,
                    d_sm: float | None = None,
                    d_cmp: float | None = None,
                    d_all: float | None = None):
    """Eq 2–4 for a domain of D cells (defaults: D_gm = D_sm = D_cmp)."""
    d_all = float(d_all if d_all is not None else math.prod(spec.domain))
    d_gm = float(d_gm if d_gm is not None else d_all)
    d_sm = float(d_sm if d_sm is not None else d_all)
    d_cmp = float(d_cmp if d_cmp is not None else d_all)
    a_sm = spec.a_sm_rst if rst else spec.a_sm
    t_gm = spec.a_gm * d_gm * hw.s_cell / hw.b_gm
    t_sm = a_sm * d_sm * t * hw.s_cell / hw.b_sm
    t_cmp = spec.flops_per_cell * d_cmp * t / hw.thr_cmp
    return t_gm, t_sm, t_cmp, d_all


def v_smtile(spec: StencilSpec, t: int, tile: tuple[int, ...]) -> float:
    """Eq 8 (2-D) / Eq 9 (3-D): valid fraction under overlapped tiling."""
    h = spec.halo(t)
    if spec.ndim == 2:
        return max(0.0, (tile[0] - h) / tile[0])
    return max(0.0, (tile[0] - h) / tile[0]) * max(0.0, (tile[1] - h) / tile[1])


def v_dtile(t_stencil: float, hw: HardwareModel, n_syncs: int = 1) -> float:
    """Eq 11: valid fraction under device tiling with n syncs per tile."""
    return t_stencil / (t_stencil + hw.t_dsync * n_syncs)


def attainable(spec: StencilSpec, t: int, hw: HardwareModel, *,
               rst: bool = True, v: float = 1.0, **dkw) -> RooflineResult:
    t_gm, t_sm, t_cmp, d_all = component_times(spec, t, hw, rst=rst, **dkw)
    t_stencil = max(t_gm, t_sm, t_cmp)
    bn = ("gm", "sm", "cmp")[(t_gm, t_sm, t_cmp).index(t_stencil)]
    p = d_all * t / t_stencil
    pp = p * v
    return RooflineResult(t_gm, t_sm, t_cmp, bn, p, v, pp,
                          gflops=pp * spec.flops_per_cell)


# ------------------------------------------------------------------- §6.2 ---
def desired_depth(spec: StencilSpec, hw: HardwareModel, *, rst: bool = True) -> float:
    """Eq 17 with D_sm == D_gm: minimum t that moves the bottleneck gm→sm."""
    a_sm = spec.a_sm_rst if rst else spec.a_sm
    return (spec.a_gm / hw.b_gm) * (hw.b_sm / a_sm)


def desired_depth_device_tiled(spec: StencilSpec, hw: HardwareModel,
                               tile: tuple[int, int], *, rst: bool = True) -> float:
    """Eq 18/19: depth at which sm time covers the (halo-inflated) gm time.

    D_gm = tile_x·tile_y + (tile_x+tile_y)·2·t·rad ; D_sm = tile_x·tile_y.
    Solve  a_sm·D_sm·t/B_sm  >  a_gm·D_gm/B_gm  for t.
    """
    a_sm = spec.a_sm_rst if rst else spec.a_sm
    tx, ty = tile
    d_sm = tx * ty
    # a_sm·d_sm/B_sm · t  >  a_gm·(d_sm + (tx+ty)·2·rad·t)/B_gm
    lhs_slope = a_sm * d_sm / hw.b_sm
    rhs_slope = spec.a_gm * (tx + ty) * 2 * spec.radius / hw.b_gm
    rhs_const = spec.a_gm * d_sm / hw.b_gm
    denom = lhs_slope - rhs_slope
    if denom <= 0:
        return math.inf
    return rhs_const / denom


# ------------------------------------------------------------------- §6.4 ---
def min_tile_width(spec: StencilSpec, hw: HardwareModel, *, rst: bool = True) -> float:
    """Eq 23: minimum square-tile width so halo gm traffic stays sub-dominant."""
    a_sm = spec.a_sm_rst if rst else spec.a_sm
    return 4 * spec.a_gm * hw.b_sm / (a_sm * hw.b_gm) * spec.radius


# ------------------------------------------------- derived-spec summary ---
def spec_cost_summary(spec: StencilSpec, hw: HardwareModel = TPU_V5E) -> dict:
    """The §5/§6 view of a spec: its cost-model numbers (derived or
    overridden — see ``stencil_spec.derive_cost_model``), whether each one
    matches the pure derivation, and the model's headline decisions
    (Eq 17 desired depth, Eq 23 minimum tile width, arithmetic intensity).
    The CLI prints this for user-defined stencils so the derived cost
    model is inspectable, not implicit."""
    from repro.core.stencil_spec import derive_cost_model
    derived = derive_cost_model(spec.taps, spec.ndim)
    return {
        "name": spec.name,
        "ndim": spec.ndim,
        "radius": spec.radius,
        "npoints": spec.npoints,
        "shape_kind": spec.shape_kind,
        "tap_sum": spec.tap_sum,
        "flops_per_cell": spec.flops_per_cell,
        "a_sm": spec.a_sm,
        "a_sm_rst": spec.a_sm_rst,
        "a_gm": spec.a_gm,
        "overridden": sorted(k for k, v in derived.items()
                             if getattr(spec, k) != v),
        "arith_intensity": spec.flops_per_cell / (spec.a_gm * hw.s_cell),
        "desired_depth_eq17": desired_depth(spec, hw, rst=True),
        "min_tile_width_eq23": min_tile_width(spec, hw, rst=True),
    }


# --------------------------------------------------- distributed extension ---
def halo_exchange_time(spec: StencilSpec, t: int, hw: HardwareModel,
                       shard_shape: tuple[int, ...], n_neighbors: int = 2) -> float:
    """Beyond-paper: ICI time for a deep-halo (t·rad) exchange, amortized over
    the t steps it buys. Exchanging once per t steps divides the per-step
    collective cost by t — EBISU's sync amortization applied across chips."""
    if hw.b_ici <= 0:
        return 0.0
    face = math.prod(shard_shape[1:]) if len(shard_shape) > 1 else 1
    halo_cells = spec.halo(t) * face * n_neighbors
    return halo_cells * hw.s_cell / (hw.b_ici * max(1, hw.ici_links // 2))
