"""Distributed EBISU: deep-halo exchange + temporal blocking across chips.

The paper amortizes *device-wide synchronization* over ``t`` fused time steps
(§4.1/§5.2.2).  Across a TPU pod the analogous synchronization is the halo
exchange: this module exchanges a ``t_block·rad``-deep halo **once per
t_block steps** (`ppermute` over ICI), which

  * divides the number of collective launches (and their latency / sync cost)
    by ``t_block`` — the distributed version of Eq 11's ``n`` reduction;
  * keeps total halo *bytes* constant (depth × 1/frequency), so the roofline
    collective-bytes term is flat while the collective-*count* term drops;
  * pays ``V_SMtile``-style redundant compute on the halo (Eq 8/9) — the same
    trade the paper makes inside a device, lifted to the pod level.

Domain decomposition is N-dimensional: each sharded tensor dim maps to a mesh
axis.  Halo exchange is sequential per axis on the progressively extended
array, so box-stencil corners arrive via two hops (standard corner trick).

Per-shard inner compute is the fused jnp blocked step with *global-coordinate*
masking (axis_index-dependent), which keeps zero-Dirichlet semantics exact at
the true domain edges while interior shard seams are healed by the halo.  The
single-device Pallas kernels remain the on-chip realization of the same
schedule; wiring them inside shard_map needs a per-shard scalar-prefetch
origin operand (see DESIGN.md §8 — stretch item).
"""
from __future__ import annotations

import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.stencil_spec import StencilSpec
from repro.kernels.ref import stencil_step


def shard_map_compat(f, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map`` (with ``check_vma``); the pinned
    0.4.x toolchain has ``jax.experimental.shard_map`` (with the older
    ``check_rep`` spelling).  Both checks are disabled: the halo-exchange
    bodies are intentionally per-shard-divergent (edge shards differ).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def _axis_size(mesh, ax) -> int:
    if isinstance(ax, str):
        return mesh.shape[ax]
    import math
    return math.prod(mesh.shape[a] for a in ax)


def _axis_index(ax):
    """Flattened index over a (possibly tuple) mesh axis, major-to-minor."""
    if isinstance(ax, str):
        return jax.lax.axis_index(ax)
    idx = jax.lax.axis_index(ax[0])
    for a in ax[1:]:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _exchange_one_axis(local: jnp.ndarray, dim: int, h: int, axis_name,
                       n: int, *, periodic: bool = False):
    """Extend ``local`` by h-deep halos along ``dim`` from mesh neighbors.

    Open chain (default): shards at the ends receive zeros (ppermute
    drops sourceless outputs), which is exactly the zero-extension the
    global Dirichlet boundary needs.  ``periodic=True`` closes the chain
    into a ring — shard 0's low halo is shard n−1's last rows, realizing
    the torus seam with the same one-round exchange.  ``axis_name`` may
    be a tuple of mesh axes (flattened ordering).
    """
    if n == 1:
        pad = [(0, 0)] * local.ndim
        pad[dim] = (h, h)
        mode = dict(mode="wrap") if periodic else {}
        return jnp.pad(local, pad, **mode)
    idx_lo = [slice(None)] * local.ndim
    idx_lo[dim] = slice(0, h)
    idx_hi = [slice(None)] * local.ndim
    idx_hi[dim] = slice(local.shape[dim] - h, local.shape[dim])
    last = n if periodic else n - 1    # ring closes the (n-1, 0) hop
    # shard i's top halo <- shard i-1's last rows (data flows "down": i->i+1)
    from_prev = jax.lax.ppermute(local[tuple(idx_hi)], axis_name,
                                 [(i, (i + 1) % n) for i in range(last)])
    # shard i's bottom halo <- shard i+1's first rows
    from_next = jax.lax.ppermute(local[tuple(idx_lo)], axis_name,
                                 [((i + 1) % n, i) for i in range(last)])
    return jnp.concatenate([from_prev, local, from_next], axis=dim)


def _blocked_steps(ext: jnp.ndarray, spec: StencilSpec, t_block: int,
                   origins: Mapping[int, jnp.ndarray],
                   global_shape: Sequence[int]) -> jnp.ndarray:
    """t_block fused steps on the extended shard, re-masking every step so
    cells outside the *global* domain stay zero (exact Dirichlet semantics).
    Unsharded dims are zero-extended by stencil_step's padding, which is
    already exact for them."""
    mask = None
    for dim, origin in origins.items():
        ids = jnp.arange(ext.shape[dim]) + origin
        ok = (ids >= 0) & (ids < global_shape[dim])
        shape = [1] * ext.ndim
        shape[dim] = ext.shape[dim]
        ok = ok.reshape(shape)
        mask = ok if mask is None else mask & ok
    for _ in range(t_block):
        ext = stencil_step(ext, spec)
        if mask is not None:
            ext = jnp.where(mask, ext, 0.0)
    return ext


def make_distributed_stencil(spec: StencilSpec, mesh: Mesh,
                             dim_to_axis: Mapping[int, str],
                             global_shape: Sequence[int],
                             t_total: int, t_block: int,
                             inner: str = "jnp"):
    """Build a jit-able ``fn(x_sharded) -> x_sharded`` applying ``t_total``
    steps in blocks of ``t_block`` with one deep-halo exchange per block.

    ``dim_to_axis`` maps tensor dims to mesh axis names, e.g. {0: 'data',
    1: 'model'} for a 2-D domain decomposition.
    """
    assert t_total % t_block == 0, "t_total must be a multiple of t_block"
    n_blocks = t_total // t_block
    h = spec.halo(t_block)
    pspec = P(*[dim_to_axis.get(d) for d in range(len(global_shape))])

    for d, ax in dim_to_axis.items():
        n_ax = _axis_size(mesh, ax)
        shard_len = global_shape[d] // n_ax
        assert global_shape[d] % n_ax == 0, (d, ax)
        assert h <= shard_len, (
            f"halo {h} exceeds shard extent {shard_len} on dim {d}; "
            f"reduce t_block or the mesh axis")

    def shard_fn(local: jnp.ndarray) -> jnp.ndarray:
        for _ in range(n_blocks):
            ext = local
            origins = {}
            for d, ax in dim_to_axis.items():
                ext = _exchange_one_axis(ext, d, h, ax, _axis_size(mesh, ax))
                origins[d] = (_axis_index(ax) * local.shape[d] - h)
            if inner == "stub":
                # kernel-adjusted accounting: on TPU the per-shard compute is
                # the VMEM-resident EBISU kernel (1 read + 1 write per cell
                # per block); the jnp inner materializes every tap shift.
                ext = ext * jnp.float32(0.999)
            else:
                ext = _blocked_steps(ext, spec, t_block, origins,
                                     global_shape)
            sl = [slice(None)] * ext.ndim
            for d in dim_to_axis:
                sl[d] = slice(h, ext.shape[d] - h)
            local = ext[tuple(sl)]
        return local

    fn = shard_map_compat(shard_fn, mesh, in_specs=(pspec,),
                          out_specs=pspec)
    return jax.jit(fn), pspec
