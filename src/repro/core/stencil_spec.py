"""The open stencil definition layer: specs are *user input*, Table 2 is data.

A stencil is a set of taps ``(offset, coefficient)`` applied to a regular grid
with zero (Dirichlet) boundary semantics by default: cells outside the domain
read as 0 at every time step.  The EBISU pipeline (plan → tile → deep temporal
chain) is generic over any tap set, so this module treats the tap set as the
source of truth and *derives* everything else from it:

  * geometry — ``ndim`` (offset arity), ``radius`` (max |component|),
    ``shape_kind`` (star iff every tap moves along at most one axis);
  * the §5 cost model — ``flops_per_cell``, ``a_sm`` (ideal scratchpad
    accesses per cell without redundant register streaming) and ``a_sm_rst``
    (with RST), via the counting models in :func:`derive_flops_per_cell`,
    :func:`derive_a_sm` and :func:`derive_a_sm_rst` (DESIGN.md §11.2).

``define_stencil`` is the one constructor: it validates the tap set (precise
errors, :func:`validate_taps`), derives the fields above, and accepts explicit
overrides for the cost-model quantities.  The paper's nine Table-2 benchmarks
are built through exactly this path with their published ``flops_per_cell`` /
``a_sm`` / ``a_sm_rst`` values passed as *registered overrides* — and the test
suite asserts the derivation reproduces the published numbers (paper fidelity
is a test, not a hardcode; the single divergence, j2d25pt's flop count, is
pinned as such — see ``tests/test_define.py``).

Planning identity: two specs with the same tap structure and cost numbers are
the same stencil to the planner regardless of their names — ``signature``
is the registry-free cache key (``repro.api.plan_bucketed`` keys on it).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import numbers
from typing import Tuple

Offset = Tuple[int, ...]

MAX_NDIM = 3
MAX_RADIUS = 8          # kernels/planner are validated up to this order
DEFAULT_DOMAINS = {2: (8192, 8192), 3: (512, 512, 512)}


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    ndim: int                      # 2 or 3
    radius: int                    # stencil order (paper: "Order")
    taps: Tuple[Tuple[Offset, float], ...]
    flops_per_cell: float          # derived (2/tap) unless overridden
    domain: Tuple[int, ...]        # evaluation domain (Table 2 / default)
    a_sm: float                    # smem accesses/cell w/o RST
    a_sm_rst: float                # smem accesses/cell w/  RST
    a_gm: float = 2.0              # §6.2: load+store per cell, perfect caching
    shape_kind: str = "star"       # "star" | "box"

    @property
    def npoints(self) -> int:
        return len(self.taps)

    @property
    def tap_sum(self) -> float:
        """Sum of tap coefficients — 1 for Jacobi-normalized sets; the
        affine Dirichlet closure depends on it (DESIGN.md §11.3)."""
        return sum(c for _, c in self.taps)

    @property
    def signature(self) -> tuple:
        """Registry-free planning identity: the tap structure plus the
        cost-model numbers the §5/§6 machinery consumes.  Excludes
        ``name`` and ``domain`` — two differently-named specs with the
        same structure share plans; a cost override changes identity."""
        return (self.ndim, self.taps, self.flops_per_cell,
                self.a_sm, self.a_sm_rst, self.a_gm)

    def halo(self, t: int) -> int:
        """Halo depth for ``t`` temporally-blocked steps."""
        return self.radius * t


# ===================================================== derived geometry ====
def taps_radius(taps) -> int:
    """Largest |offset| component over the tap set."""
    return max((max((abs(o) for o in off), default=0) for off, _ in taps),
               default=0)


def classify_shape(taps) -> str:
    """'star' iff every tap moves along at most one axis, else 'box'.

    Matches the paper's star/box taxonomy: multi-point sets that are not
    full boxes (j3d17pt, poisson) fall on the box side — what matters to
    the kernels is whether the axis-separable star path applies.
    """
    for off, _ in taps:
        if sum(1 for o in off if o) > 1:
            return "box"
    return "star"


# =================================================== derived cost model ====
def derive_flops_per_cell(taps) -> float:
    """FLOPs per cell update: one fused multiply-add (2 FLOPs) per tap.

    This is the convention eight of the nine Table-2 rows use; the paper
    counts j2d25pt's blur FMAs as 1 FLOP each (25), which the registry
    keeps as a verbatim override (DESIGN.md §11.2).
    """
    return 2.0 * len(taps)


def derive_a_sm(taps) -> float:
    """Ideal scratchpad accesses per cell *without* register streaming:
    one read per tap plus one write of the produced cell.  Reproduces the
    ``a_sm`` column of Table 2 exactly for all nine benchmarks."""
    return float(len(taps) + 1)


def derive_a_sm_rst(taps, ndim: int) -> float:
    """Scratchpad accesses per cell *with* redundant register streaming.

    Counting model (calibrated to the paper's A100 implementations;
    reproduces the ``a_sm (RST)`` column of Table 2 exactly for all nine
    benchmarks — asserted by ``tests/test_define.py``):

    2-D — registers shift along the unit-stride x axis, so each distinct
    tap row (distinct ``dy``) costs one amortized smem read per cell, plus
    the result write:  ``rows(dy) + 1``.

    3-D — planes stream along z and each thread's register queue carries
    its own column, so taps at in-plane offset (0,0) are free; the rows of
    the dz=0 plane cost one amortized read each (x shifting, as in 2-D);
    the 2r+1-deep z queue pays an amortized lazy-shift overhead of ``r/2``
    per cell; and off-column taps in dz≠0 planes (box-family sets) force
    one extra amortized re-read of the shifted window:

        rows(dy | dz=0) + 1 + r/2 + [any tap with dz≠0 and (dy,dx)≠(0,0)]
    """
    rad = taps_radius(taps)
    if ndim == 2:
        rows = {off[0] for off, _ in taps}
        return float(len(rows) + 1)
    inplane_rows = {off[1] for off, _ in taps if off[0] == 0}
    off_column = any(off[0] != 0 and any(off[1:]) for off, _ in taps)
    rst = len(inplane_rows) + 1 + 0.5 * rad + (1.0 if off_column else 0.0)
    return max(2.0, min(rst, derive_a_sm(taps)))


def derive_cost_model(taps, ndim: int) -> dict:
    """The analytically derived §5 quantities for a tap set."""
    return dict(flops_per_cell=derive_flops_per_cell(taps),
                a_sm=derive_a_sm(taps),
                a_sm_rst=derive_a_sm_rst(taps, ndim))


# ============================================================ validation ===
def validate_taps(taps, *, min_radius: int = 1) -> tuple[int, int]:
    """Validate a raw tap set; returns ``(ndim, radius)``.

    Raises ``ValueError`` with a precise message naming the offending tap
    for: empty sets, non-integer or mixed-arity offsets, unsupported
    dimensionality, duplicate offsets, non-finite or zero coefficients,
    and radii outside ``[min_radius, MAX_RADIUS]``.  Single-field specs
    keep the default ``min_radius=1`` (a pure center tap has nothing to
    temporally block); coupled systems pass ``min_radius=0`` because an
    identity-only coupling (e.g. a reaction partner's pointwise feed) is
    legitimate — the *system* radius still has to clear 1.
    """
    taps = tuple(taps)
    if not taps:
        raise ValueError("stencil needs a non-empty tap set; got no taps")
    first = taps[0][0]
    try:
        ndim = len(first)
    except TypeError:
        raise ValueError(
            f"tap offsets must be tuples of ints; got {first!r}") from None
    if not 2 <= ndim <= MAX_NDIM:
        raise ValueError(
            f"stencils must be 2-D or 3-D; offset {tuple(first)} is "
            f"{ndim}-D")
    seen: dict[tuple, float] = {}
    for off, c in taps:
        off = tuple(off)
        if len(off) != ndim:
            raise ValueError(
                f"inconsistent offset arity: {off} is {len(off)}-D but the "
                f"first tap {tuple(first)} is {ndim}-D — every offset must "
                f"have the same number of components")
        if not all(isinstance(o, numbers.Integral)
                   and not isinstance(o, bool) for o in off):
            raise ValueError(
                f"tap offset {off} has non-integer components; offsets are "
                "integer grid displacements")
        off = tuple(int(o) for o in off)   # normalize numpy ints
        if off in seen:
            raise ValueError(
                f"duplicate tap offset {off} (coefficients {seen[off]:g} "
                f"and {c:g}); merge them into one tap")
        if not math.isfinite(c):
            raise ValueError(f"tap {off} has non-finite coefficient {c!r}")
        if c == 0.0:
            raise ValueError(
                f"tap {off} has zero coefficient; drop it — zero taps "
                "inflate the derived cost model without contributing")
        seen[off] = float(c)
    radius = taps_radius(taps)
    if radius < min_radius:
        raise ValueError(
            "stencil radius is 0 (only the center tap?); temporal blocking "
            "needs at least one neighbor tap (radius >= 1)")
    if radius > MAX_RADIUS:
        raise ValueError(
            f"stencil radius {radius} exceeds the supported bound "
            f"{MAX_RADIUS} (offset {max((off for off, _ in taps), key=taps_radius_of)}"
            f"); deep-halo tiling above this order is untested")
    return ndim, radius


def taps_radius_of(off) -> int:
    return max(abs(o) for o in off)


def validate_spec(spec: StencilSpec) -> StencilSpec:
    """Validate an assembled spec (``compile_stencil`` calls this, so
    hand-built ``StencilSpec`` instances get the same precise errors as
    ``define_stencil`` input)."""
    ndim, radius = validate_taps(spec.taps)
    if spec.ndim != ndim:
        raise ValueError(
            f"{spec.name}: ndim={spec.ndim} but the tap offsets are "
            f"{ndim}-D")
    if spec.radius != radius:
        raise ValueError(
            f"{spec.name}: radius={spec.radius} but the tap set reaches "
            f"{radius} (max |offset| component); set radius={radius}")
    if len(spec.domain) != ndim:
        raise ValueError(
            f"{spec.name}: domain {spec.domain} is {len(spec.domain)}-D "
            f"for a {ndim}-D tap set")
    if any(d < 2 * radius + 2 for d in spec.domain):
        raise ValueError(
            f"{spec.name}: domain {spec.domain} has an extent smaller than "
            f"2·radius+2 = {2 * radius + 2}; the halo would cover it")
    for field in ("flops_per_cell", "a_sm", "a_sm_rst", "a_gm"):
        v = getattr(spec, field)
        if not (math.isfinite(v) and v > 0):
            raise ValueError(f"{spec.name}: {field}={v!r} must be a "
                             "positive finite number")
    return spec


# =============================================================== builder ===
def define_stencil(taps, *, name: str | None = None, normalize: bool = False,
                   domain: Tuple[int, ...] | None = None,
                   flops_per_cell: float | None = None,
                   a_sm: float | None = None,
                   a_sm_rst: float | None = None,
                   a_gm: float = 2.0) -> StencilSpec:
    """Build a :class:`StencilSpec` from a user tap set.

    ``ndim``, ``radius`` and ``shape_kind`` are derived from the offsets;
    ``flops_per_cell`` / ``a_sm`` / ``a_sm_rst`` are derived from the tap
    structure (DESIGN.md §11.2) unless explicitly overridden — which is
    how the Table-2 registry pins the paper's verbatim numbers.

    ``normalize=True`` rescales the coefficients to sum to 1 (Jacobi
    weights): iterates stay bounded under deep blocking and every
    boundary condition's exact reduction applies (DESIGN.md §11.3).
    ``domain`` is the evaluation domain used when planning without an
    explicit shape; defaults to ``DEFAULT_DOMAINS[ndim]``.
    """
    taps = tuple((tuple(off), float(c)) for off, c in taps)
    ndim, radius = validate_taps(taps)
    # post-validation normalization: components are Integral, so int() is
    # exact (numpy ints become plain ints — clean hashing/repr in keys)
    taps = tuple((tuple(int(o) for o in off), c) for off, c in taps)
    if normalize:
        taps = _norm(taps)
    cost = derive_cost_model(taps, ndim)
    if flops_per_cell is not None:
        cost["flops_per_cell"] = float(flops_per_cell)
    if a_sm is not None:
        cost["a_sm"] = float(a_sm)
    if a_sm_rst is not None:
        cost["a_sm_rst"] = float(a_sm_rst)
    spec = StencilSpec(
        name=name or f"user{ndim}d{len(taps)}pt",
        ndim=ndim, radius=radius, taps=taps,
        domain=tuple(domain) if domain is not None else DEFAULT_DOMAINS[ndim],
        a_gm=float(a_gm), shape_kind=classify_shape(taps), **cost)
    return validate_spec(spec)


def _norm(taps):
    """Normalize coefficients to sum to 1 (Jacobi smoothing weights).

    Keeps iterates bounded for arbitrarily deep temporal blocking, which makes
    the blocked-vs-reference equivalence tests numerically meaningful.
    """
    s = sum(c for _, c in taps)
    if s == 0:
        raise ValueError(
            "cannot normalize a tap set whose coefficients sum to 0 "
            "(e.g. a raw Laplacian); embed it in an update like "
            "u + alpha*L(u) first — see repro.api.define.diffusion")
    return tuple((o, c / s) for o, c in taps)


def star_taps(ndim: int, radius: int, center_w: float = 2.0,
              arm_w: float = 1.0, normalize: bool = True):
    taps = [((0,) * ndim, center_w)]
    for ax in range(ndim):
        for r in range(1, radius + 1):
            for sgn in (-1, 1):
                off = [0] * ndim
                off[ax] = sgn * r
                taps.append((tuple(off), arm_w / r))
    return _norm(taps) if normalize else tuple(taps)


def box_taps(ndim: int, radius: int, center_w: float = 4.0,
             normalize: bool = True):
    taps = []
    for off in itertools.product(range(-radius, radius + 1), repeat=ndim):
        w = center_w if all(o == 0 for o in off) else 1.0 / (1 + sum(abs(o) for o in off))
        taps.append((tuple(off), w))
    return _norm(taps) if normalize else tuple(taps)


def gaussian_taps(radius: int = 2, ndim: int = 2, sigma: float = 1.2):
    """Gaussian blur weights (j2d25pt in the suite is the 5x5 instance)."""
    taps = []
    for off in itertools.product(range(-radius, radius + 1), repeat=ndim):
        w = math.exp(-sum(o * o for o in off) / (2 * sigma * sigma))
        taps.append((tuple(off), w))
    return _norm(taps)


def j3d17pt_taps():
    """17-point radius-1 stencil: full 3x3 box in the z=0 plane (9 taps) plus
    the 4 in-plane-diagonal taps in each of the z=+-1 planes (8 taps).

    The paper does not give the exact tap set (it refers to [25, 40]); any
    17-point radius-1 set is a faithful stand-in because Table 2's
    flops/cell and a_sm — which are what the performance model consumes —
    are taken from the paper, and correctness is defined against our own
    oracle. Recorded as an assumption in DESIGN.md.
    """
    taps = []
    for dy, dx in itertools.product((-1, 0, 1), repeat=2):
        taps.append(((0, dy, dx), 2.0 if (dy, dx) == (0, 0) else 1.0))
    for dz in (-1, 1):
        for dy, dx in ((-1, -1), (-1, 1), (1, -1), (1, 1)):
            taps.append(((dz, dy, dx), 0.5))
    return _norm(taps)


def poisson19_taps():
    """Classic 19-point 3-D Poisson stencil: center + 6 faces + 12 edges."""
    taps = []
    for off in itertools.product((-1, 0, 1), repeat=3):
        dist = sum(abs(o) for o in off)
        if dist == 0:
            taps.append((off, 6.0))
        elif dist == 1:
            taps.append((off, 1.0))
        elif dist == 2:
            taps.append((off, 0.5))
    return _norm(taps)


# ---------------------------------------------------------------- Table 2 ---
# The paper's evaluation domains; ``flops_per_cell`` / ``a_sm`` / ``a_sm_rst``
# are passed as verbatim overrides of the derivation (they are the published
# Table-2 values; the derivation reproduces them — tests/test_define.py).
_PAPER_3D = (2560, 288, 384)


def _table2(name, taps, flops, domain, a_sm, a_sm_rst):
    return define_stencil(taps, name=name, domain=domain,
                          flops_per_cell=flops, a_sm=a_sm, a_sm_rst=a_sm_rst)


TABLE2: dict[str, StencilSpec] = {
    "j2d5pt": _table2("j2d5pt", star_taps(2, 1), 10, (8352, 8352), 6, 4),
    "j2d9pt": _table2("j2d9pt", star_taps(2, 2), 18, (8064, 8064), 10, 6),
    "j2d9pt-gol": _table2("j2d9pt-gol", box_taps(2, 1), 18, (8784, 8784), 10, 4),
    "j2d25pt": _table2("j2d25pt", gaussian_taps(2), 25, (8640, 8640), 26, 6),
    "j3d7pt": _table2("j3d7pt", star_taps(3, 1), 14, _PAPER_3D, 8, 4.5),
    "j3d13pt": _table2("j3d13pt", star_taps(3, 2), 26, _PAPER_3D, 14, 7),
    "j3d17pt": _table2("j3d17pt", j3d17pt_taps(), 34, _PAPER_3D, 18, 5.5),
    "j3d27pt": _table2("j3d27pt", box_taps(3, 1), 54, _PAPER_3D, 28, 5.5),
    "poisson": _table2("poisson", poisson19_taps(), 38, _PAPER_3D, 20, 5.5),
}

# Paper Table 3 — depth of temporal blocking chosen by each implementation.
TABLE3_DEPTHS = {
    #              STENCILGEN AN5D DRSTENCIL ARTEMIS EBISU
    "j2d5pt":     dict(stencilgen=4, an5d=10, drstencil=3, artemis=12, ebisu=12),
    "j2d9pt":     dict(stencilgen=4, an5d=5, drstencil=2, artemis=6, ebisu=8),
    "j2d9pt-gol": dict(stencilgen=4, an5d=7, drstencil=2, artemis=6, ebisu=6),
    "j2d25pt":    dict(stencilgen=2, an5d=5, drstencil=2, artemis=3, ebisu=4),
    "j3d7pt":     dict(stencilgen=4, an5d=6, drstencil=3, artemis=3, ebisu=8),
    "j3d13pt":    dict(stencilgen=2, an5d=4, drstencil=2, artemis=1, ebisu=5),
    "j3d17pt":    dict(stencilgen=2, an5d=3, drstencil=2, artemis=2, ebisu=6),
    "j3d27pt":    dict(stencilgen=2, an5d=3, drstencil=None, artemis=2, ebisu=5),
    "poisson":    dict(stencilgen=4, an5d=3, drstencil=2, artemis=2, ebisu=6),
}


def lift_2d_to_3d(spec: StencilSpec) -> StencilSpec:
    """View a 2-D stencil as a 3-D stencil with Y-extent 1: (dy,dx) taps
    become (dz,0,dx).  This is how EBISU streams 2-D domains — the streamed
    dimension carries the circular multi-queue, so there is NO overlapped
    halo along it (paper §2.1.3: 2.5-D streaming), unlike strip tiling."""
    taps = tuple(((dy, 0, dx), c) for (dy, dx), c in spec.taps)
    return dataclasses.replace(
        spec, name=spec.name + "+lifted", ndim=3, taps=taps,
        domain=(spec.domain[0], 1, spec.domain[1]))


def get(name: str) -> StencilSpec:
    try:
        return TABLE2[name]
    except KeyError:
        raise KeyError(
            f"unknown Table-2 stencil {name!r} (choose from {list(TABLE2)});"
            " arbitrary stencils need no registry — build one with "
            "repro.api.define_stencil(taps)") from None


def names() -> list[str]:
    return list(TABLE2)
