"""Stencil taxonomy: the paper's Table 2 benchmark suite as first-class specs.

A stencil is a set of taps ``(offset, coefficient)`` applied to a regular grid
with zero (Dirichlet) boundary semantics: cells outside the domain read as 0 at
every time step.  All of the paper's nine benchmarks (Table 2) are Jacobi-style
single-array stencils of this form.

``flops_per_cell``, ``a_sm`` (ideal shared-memory accesses per cell, with and
without redundant register streaming) and the evaluation domain sizes are taken
verbatim from Table 2 of the paper so the §5 performance model can reproduce
the paper's numbers.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Tuple

Offset = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    name: str
    ndim: int                      # 2 or 3
    radius: int                    # stencil order (paper: "Order")
    taps: Tuple[Tuple[Offset, float], ...]
    flops_per_cell: float          # Table 2
    domain: Tuple[int, ...]        # Table 2 evaluation domain
    a_sm: float                    # smem accesses/cell w/o RST (Table 2)
    a_sm_rst: float                # smem accesses/cell w/  RST (Table 2)
    a_gm: float = 2.0              # §6.2: load+store per cell, perfect caching
    shape_kind: str = "star"       # "star" | "box" | other

    @property
    def npoints(self) -> int:
        return len(self.taps)

    def halo(self, t: int) -> int:
        """Halo depth for ``t`` temporally-blocked steps."""
        return self.radius * t


def _norm(taps):
    """Normalize coefficients to sum to 1 (Jacobi smoothing weights).

    Keeps iterates bounded for arbitrarily deep temporal blocking, which makes
    the blocked-vs-reference equivalence tests numerically meaningful.
    """
    s = sum(c for _, c in taps)
    return tuple((o, c / s) for o, c in taps)


def star_taps(ndim: int, radius: int, center_w: float = 2.0, arm_w: float = 1.0):
    taps = [((0,) * ndim, center_w)]
    for ax in range(ndim):
        for r in range(1, radius + 1):
            for sgn in (-1, 1):
                off = [0] * ndim
                off[ax] = sgn * r
                taps.append((tuple(off), arm_w / r))
    return _norm(taps)


def box_taps(ndim: int, radius: int, center_w: float = 4.0):
    taps = []
    for off in itertools.product(range(-radius, radius + 1), repeat=ndim):
        w = center_w if all(o == 0 for o in off) else 1.0 / (1 + sum(abs(o) for o in off))
        taps.append((tuple(off), w))
    return _norm(taps)


def gaussian_taps(radius: int = 2):
    """5x5 Gaussian blur weights (j2d25pt in the suite)."""
    import math
    sig = 1.2
    taps = []
    for off in itertools.product(range(-radius, radius + 1), repeat=2):
        w = math.exp(-(off[0] ** 2 + off[1] ** 2) / (2 * sig * sig))
        taps.append((tuple(off), w))
    return _norm(taps)


def j3d17pt_taps():
    """17-point radius-1 stencil: full 3x3 box in the z=0 plane (9 taps) plus
    the 4 in-plane-diagonal taps in each of the z=+-1 planes (8 taps).

    The paper does not give the exact tap set (it refers to [25, 40]); any
    17-point radius-1 set is a faithful stand-in because Table 2's
    flops/cell and a_sm — which are what the performance model consumes —
    are taken from the paper, and correctness is defined against our own
    oracle. Recorded as an assumption in DESIGN.md.
    """
    taps = []
    for dy, dx in itertools.product((-1, 0, 1), repeat=2):
        taps.append(((0, dy, dx), 2.0 if (dy, dx) == (0, 0) else 1.0))
    for dz in (-1, 1):
        for dy, dx in ((-1, -1), (-1, 1), (1, -1), (1, 1)):
            taps.append(((dz, dy, dx), 0.5))
    return _norm(taps)


def poisson19_taps():
    """Classic 19-point 3-D Poisson stencil: center + 6 faces + 12 edges."""
    taps = []
    for off in itertools.product((-1, 0, 1), repeat=3):
        dist = sum(abs(o) for o in off)
        if dist == 0:
            taps.append((off, 6.0))
        elif dist == 1:
            taps.append((off, 1.0))
        elif dist == 2:
            taps.append((off, 0.5))
    return _norm(taps)


# ---------------------------------------------------------------- Table 2 ---
_D3 = (256, 288, 384)  # NOTE: full paper domain is (2560, 288, 384); the
# registry stores the paper's domain; benchmarks use reduced copies on CPU.
_PAPER_3D = (2560, 288, 384)

TABLE2: dict[str, StencilSpec] = {
    "j2d5pt": StencilSpec(
        "j2d5pt", 2, 1, star_taps(2, 1), 10, (8352, 8352), 6, 4, shape_kind="star"),
    "j2d9pt": StencilSpec(
        "j2d9pt", 2, 2, star_taps(2, 2), 18, (8064, 8064), 10, 6, shape_kind="star"),
    "j2d9pt-gol": StencilSpec(
        "j2d9pt-gol", 2, 1, box_taps(2, 1), 18, (8784, 8784), 10, 4, shape_kind="box"),
    "j2d25pt": StencilSpec(
        "j2d25pt", 2, 2, gaussian_taps(2), 25, (8640, 8640), 26, 6, shape_kind="box"),
    "j3d7pt": StencilSpec(
        "j3d7pt", 3, 1, star_taps(3, 1), 14, _PAPER_3D, 8, 4.5, shape_kind="star"),
    "j3d13pt": StencilSpec(
        "j3d13pt", 3, 2, star_taps(3, 2), 26, _PAPER_3D, 14, 7, shape_kind="star"),
    "j3d17pt": StencilSpec(
        "j3d17pt", 3, 1, j3d17pt_taps(), 34, _PAPER_3D, 18, 5.5, shape_kind="box"),
    "j3d27pt": StencilSpec(
        "j3d27pt", 3, 1, box_taps(3, 1), 54, _PAPER_3D, 28, 5.5, shape_kind="box"),
    "poisson": StencilSpec(
        "poisson", 3, 1, poisson19_taps(), 38, _PAPER_3D, 20, 5.5, shape_kind="box"),
}

# Paper Table 3 — depth of temporal blocking chosen by each implementation.
TABLE3_DEPTHS = {
    #              STENCILGEN AN5D DRSTENCIL ARTEMIS EBISU
    "j2d5pt":     dict(stencilgen=4, an5d=10, drstencil=3, artemis=12, ebisu=12),
    "j2d9pt":     dict(stencilgen=4, an5d=5, drstencil=2, artemis=6, ebisu=8),
    "j2d9pt-gol": dict(stencilgen=4, an5d=7, drstencil=2, artemis=6, ebisu=6),
    "j2d25pt":    dict(stencilgen=2, an5d=5, drstencil=2, artemis=3, ebisu=4),
    "j3d7pt":     dict(stencilgen=4, an5d=6, drstencil=3, artemis=3, ebisu=8),
    "j3d13pt":    dict(stencilgen=2, an5d=4, drstencil=2, artemis=1, ebisu=5),
    "j3d17pt":    dict(stencilgen=2, an5d=3, drstencil=2, artemis=2, ebisu=6),
    "j3d27pt":    dict(stencilgen=2, an5d=3, drstencil=None, artemis=2, ebisu=5),
    "poisson":    dict(stencilgen=4, an5d=3, drstencil=2, artemis=2, ebisu=6),
}


def lift_2d_to_3d(spec: StencilSpec) -> StencilSpec:
    """View a 2-D stencil as a 3-D stencil with Y-extent 1: (dy,dx) taps
    become (dz,0,dx).  This is how EBISU streams 2-D domains — the streamed
    dimension carries the circular multi-queue, so there is NO overlapped
    halo along it (paper §2.1.3: 2.5-D streaming), unlike strip tiling."""
    taps = tuple(((dy, 0, dx), c) for (dy, dx), c in spec.taps)
    return dataclasses.replace(
        spec, name=spec.name + "+lifted", ndim=3, taps=taps,
        domain=(spec.domain[0], 1, spec.domain[1]))


def get(name: str) -> StencilSpec:
    return TABLE2[name]


def names() -> list[str]:
    return list(TABLE2)
