"""qwen3-14b [dense]: qk-norm + GQA.

[hf:Qwen/Qwen3-14B] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120,
    n_heads=40, kv_heads=8, head_dim=128, d_ff=17408, vocab=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=False,
    microbatches=8,
    source="hf:Qwen/Qwen3-14B"))
