"""granite-moe-3b-a800m [moe]: 40 experts, top-8 (padded to 48 slots for the
16-way expert-parallel mesh axis; phantom experts masked in the router).

[hf:ibm-granite/granite-3.0-3b-a800m-base] 32L d_model=1536 24H (GQA kv=8)
expert d_ff=512 vocab=49155.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536,
    n_heads=24, kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
    n_experts=40, top_k=8, tie_embeddings=True,
    microbatches=4,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base"))
