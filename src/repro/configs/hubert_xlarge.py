"""hubert-xlarge [audio]: encoder-only masked-unit prediction.

[arXiv:2106.07447] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
The CNN waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, S, d); conv positional embedding replaced by nothing
(frames carry position) — recorded in DESIGN.md.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280,
    n_heads=16, kv_heads=16, head_dim=80, d_ff=5120, vocab=504,
    act="gelu", norm="ln", rope_theta=None, tie_embeddings=False,
    microbatches=4,
    source="arXiv:2106.07447"))
