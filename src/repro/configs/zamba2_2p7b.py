"""zamba2-2.7b [hybrid]: 54 mamba2 layers + shared attention block.

[arXiv:2411.15242; hf] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  The shared transformer block (one set of weights)
is applied every ``attn_every`` mamba layers — per-invocation LoRA deltas of
the original are omitted (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560,
    n_heads=32, kv_heads=32, head_dim=80, d_ff=10240, vocab=32000,
    act="geglu", qk_norm=False,
    ssm_state=64, ssm_inner=5120, ssm_head_dim=64, ssm_groups=1,
    attn_every=6, tie_embeddings=True,
    microbatches=4,
    source="arXiv:2411.15242; hf"))
