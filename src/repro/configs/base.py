"""ArchConfig: every assigned architecture as a selectable config.

Shapes (assignment brief): each (arch × shape) cell is one dry-run program —
``train_4k`` lowers train_step; ``prefill_32k`` lowers the serving prefill;
``decode_32k`` / ``long_500k`` lower one cached decode step (serve_step).

Skip rules (recorded in DESIGN.md §6):
  * encoder-only (hubert) has no decode → decode_32k & long_500k skipped;
  * long_500k needs sub-quadratic attention → runs for ssm/hybrid and for
    SWA archs (window-capped cache); skipped for pure full-attention archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

_REGISTRY: dict[str, "ArchConfig"] = {}


def register(cfg: "ArchConfig") -> "ArchConfig":
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> "ArchConfig":
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|encoder|vlm
    n_layers: int
    d_model: int
    n_heads: int = 1
    kv_heads: int = 1
    head_dim: int = 64
    d_ff: int = 0
    vocab: int = 32000
    act: str = "swiglu"
    norm: str = "rms"
    qk_norm: bool = False
    swa_window: int | None = None
    rope_theta: float | None = 10000.0
    embed_scale: bool = False
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_aux_weight: float = 0.01
    moe_capacity: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_inner: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # hybrid
    attn_every: int = 6
    # vlm stub frontend
    vlm_patch_dim: int = 1024
    vlm_patches: int = 256
    # execution
    activ_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    attention_impl: str = "flash_jnp"   # flash_jnp | boundary_stub (dry-run
    # stand-in for the Pallas flash kernel: same q/k/v/o boundary traffic,
    # no S x S intermediates — used for kernel-adjusted roofline terms)
    ssm_impl: str = "chunked_jnp"       # chunked_jnp | boundary_stub (ditto
    # for a fused SSD kernel: projections + output kept, no chunk-state
    # round-trips — the identified next kernel for the SSM cells)
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    microbatches: int = 1
    schedule: str = "cosine"         # cosine | wsd (minicpm)
    sharding: str = "tp"   # tp (Megatron tensor-parallel over 'model') |
    # fsdp (params fully sharded over ALL axes, batch over all axes —
    # beyond-paper §Perf scheme for dense train cells: ~11x less wire)
    # mesh hints (set by with_mesh)
    dp_axes: Any = ("data",)
    mesh_dp: int = 1
    mesh_model: int = 1
    source: str = ""                 # provenance note

    # ------------------------------------------------------------- derived --
    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim if self.ssm_inner else 0

    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts:
            return 0
        return ((self.n_experts + 15) // 16) * 16

    def n_params(self) -> int:
        from repro.models import transformer
        from repro.models.params import tree_count
        return tree_count(transformer.param_defs(self))

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        n = self.n_params()
        if self.family == "moe":
            from repro.models import moe as moe_mod
            per_expert = self.d_model * self.d_ff * (
                3 if self.act in ("swiglu", "geglu") else 2)
            n -= self.n_layers * per_expert * (self.n_experts_padded
                                               - self.top_k)
        return n

    # ------------------------------------------------------------- shaping --
    def supports(self, shape_name: str) -> tuple[bool, str]:
        kind = SHAPES[shape_name]["kind"]
        if self.family == "encoder" and kind == "decode":
            return False, "encoder-only: no decode step"
        if shape_name == "long_500k":
            subq = self.family in ("ssm", "hybrid") or self.swa_window
            if not subq:
                return False, "pure full-attention: long_500k skipped"
        return True, ""

    def with_mesh(self, mesh) -> "ArchConfig":
        import math
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if self.sharding == "fsdp":
            dp = tuple(a for a in ("pod", "data", "model") if a in axes)
            # NOTE §Perf iter 4 (refuted): disabling remat under FSDP
            # raised the memory term 2.27->6.76 s (saved activations
            # round-trip HBM: 110 GB temps) — recompute beats spill.
            return dataclasses.replace(
                self, dp_axes=dp, microbatches=1,
                mesh_dp=math.prod(axes.values()), mesh_model=1)
        dp = tuple(a for a in ("pod", "data") if a in axes)
        return dataclasses.replace(
            self, dp_axes=dp if len(dp) > 1 else (dp[0] if dp else None),
            mesh_dp=math.prod(v for k, v in axes.items()
                              if k in ("pod", "data")),
            mesh_model=axes.get("model", 1))

    def input_specs(self, shape_name: str):
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        info = SHAPES[shape_name]
        s, b, kind = info["seq"], info["batch"], info["kind"]
        i32 = jnp.int32
        if kind == "train":
            if self.family == "encoder":
                return {"frames": jax.ShapeDtypeStruct((b, s, self.d_model),
                                                       self.activ_dtype),
                        "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
                        "labels": jax.ShapeDtypeStruct((b, s), i32)}
            if self.family == "vlm":
                st = s - self.vlm_patches
                return {"tokens": jax.ShapeDtypeStruct((b, st), i32),
                        "patches": jax.ShapeDtypeStruct(
                            (b, self.vlm_patches, self.vlm_patch_dim),
                            self.activ_dtype),
                        "labels": jax.ShapeDtypeStruct((b, st), i32)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if kind == "prefill":
            if self.family == "encoder":
                return {"frames": jax.ShapeDtypeStruct((b, s, self.d_model),
                                                       self.activ_dtype)}
            if self.family == "vlm":
                st = s - self.vlm_patches
                return {"tokens": jax.ShapeDtypeStruct((b, st), i32),
                        "patches": jax.ShapeDtypeStruct(
                            (b, self.vlm_patches, self.vlm_patch_dim),
                            self.activ_dtype)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        # decode: one new token against a seq-long cache
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    def input_pspecs(self, shape_name: str):
        dp = self.dp_axes
        b = SHAPES[shape_name]["batch"]
        bs = dp if (self.mesh_dp > 1 and b % self.mesh_dp == 0) else None
        specs = {}
        for k, v in self.input_specs(shape_name).items():
            specs[k] = P(bs, *([None] * (len(v.shape) - 1)))
        return specs

    def reduced(self) -> "ArchConfig":
        """CPU-sized config of the same family for smoke tests."""
        kw = dict(
            n_layers=4 if self.family == "hybrid" else 2,
            d_model=64, n_heads=4, kv_heads=2, head_dim=16,
            d_ff=128, vocab=256,
            activ_dtype=jnp.float32, param_dtype=jnp.float32,
            remat=False, q_chunk=64, kv_chunk=64, loss_chunk=64,
            ssm_chunk=16, attn_every=2,
            vlm_patch_dim=32, vlm_patches=8, microbatches=1,
        )
        if self.family == "moe":
            # drop-free capacity so smoke tests can assert exact decode ==
            # forward equivalence (capacity truncation is order-dependent)
            kw.update(n_experts=8, top_k=2, moe_capacity=16.0)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_inner=128, ssm_head_dim=32, ssm_state=16,
                      ssm_groups=1)
        if self.family == "encoder":
            kw.update(kv_heads=4)   # hubert is MHA
        if self.kv_heads == self.n_heads:
            kw.update(kv_heads=4)
        return dataclasses.replace(self, **kw)
