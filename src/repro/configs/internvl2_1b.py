"""internvl2-1b [vlm]: InternViT patch embeddings (stub) + qwen2-like LM.

[arXiv:2404.16821; hf] LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The ViT frontend is a STUB: input_specs() provides 256
precomputed patch embeddings per image, projected into the LM stream.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896,
    n_heads=14, kv_heads=2, head_dim=64, d_ff=4864, vocab=151655,
    vlm_patch_dim=1024, vlm_patches=256, tie_embeddings=True,
    microbatches=4,
    source="arXiv:2404.16821; hf"))
