"""minicpm-2b [dense]: llama-like; trains with the WSD schedule.

[arXiv:2404.06395; hf] 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304,
    n_heads=36, kv_heads=36, head_dim=64, d_ff=5760, vocab=122753,
    schedule="wsd", tie_embeddings=True,
    microbatches=4,
    source="arXiv:2404.06395; hf"))
