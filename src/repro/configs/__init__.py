"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import (ArchConfig, SHAPES, get_config,  # noqa: F401
                                list_archs, register)

# importing the modules registers the configs
from repro.configs import (  # noqa: F401,E402
    zamba2_2p7b, hubert_xlarge, mamba2_130m, h2o_danube_1p8b, minicpm_2b,
    gemma_7b, qwen3_14b, internvl2_1b, qwen3_moe_235b_a22b,
    granite_moe_3b_a800m, stencil_suite)
