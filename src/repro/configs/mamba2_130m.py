"""mamba2-130m [ssm]: attention-free SSD — the paper's closest LM analogue.

[arXiv:2405.21060] 24L d_model=768 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*d_model = 1536, head_dim 64 -> 24 SSD heads, 1 B/C group.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, d_ff=0, vocab=50280,
    ssm_state=128, ssm_inner=1536, ssm_head_dim=64, ssm_groups=1,
    rope_theta=None, tie_embeddings=True,
    source="arXiv:2405.21060"))
