"""gemma-7b [dense]: GeGLU MLP, head_dim=256, embedding scaling.

[arXiv:2403.08295; hf] 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072,
    n_heads=16, kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
    act="geglu", embed_scale=True, tie_embeddings=True,
    microbatches=4,
    source="arXiv:2403.08295; hf"))
