"""qwen3-moe-235b-a22b [moe]: 128 experts, top-8, qk-norm.

[hf:Qwen/Qwen3-235B-A22B] 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936.  The memory heavyweight of the pool: train_4k uses gradient
accumulation (microbatches) to fit the v5e HBM budget.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096,
    n_heads=64, kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, qk_norm=True, rope_theta=1e6,
    tie_embeddings=False, microbatches=8,
    source="hf:Qwen/Qwen3-235B-A22B"))
