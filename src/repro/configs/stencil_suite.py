"""The paper's own workload as an arch config: Table-2 stencil suite.

Not an LM — selectable via --arch stencil-suite in the launcher/dry-run;
its "shapes" are the paper's domains, distributed over the production mesh
with deep-halo temporal blocking (core/distributed.py).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stencil-suite", family="stencil", n_layers=0, d_model=0,
    source="ICS'23 EBISU Table 2"))
