"""Seeded fault injection shared by the serving front door and the
resumable campaign runner.

A system that only ever sees healthy traffic is untested by
construction, so both hardened layers in the repo — the request path
(``repro.serve``) and the campaign runner (``repro.resilient``) — are
validated the other way around: :class:`FaultInjector` drives every
failure mode they defend against, from one seeded RNG, with **no
wall-clock or unseeded randomness in results** — the same
:class:`FaultConfig` always produces the same fault sequence, so the
soak tests (``tests/test_serve_soak.py``, ``tests/test_resilient.py``)
are deterministic regression tests, not flake generators.

Three kinds of faults:

  * **dispatch faults** the service core consults at its hook points —
    transient errors (:class:`TransientFault` with ``kind='evicted'`` /
    ``'oom'``) that the retry/backoff + degradation ladder must absorb,
    plus injected dispatch delays that push in-flight requests past
    their deadlines.  ``evicted`` really clears the runner cache before
    raising, so the retry exercises the true rebuild path, not a
    simulation of it.
  * **traffic faults** a driver weaves into synthetic load —
    NaN-poisoned inputs, oversized shapes, already-expired deadlines —
    via :meth:`FaultInjector.classify_request`.  These are *requests*,
    not errors: the service must resolve each to a typed error while its
    healthy batch-mates get correct results.
  * **campaign faults** the resumable runner consults between legs —
    NaN blow-up at leg ``k``, a checkpoint corrupted on disk, a save
    "crashed" mid-``tmp`` (abandoned before the atomic rename), a
    device lost from the mesh mid-run.  Each is listed per leg index so
    a test pins exactly where the campaign gets hurt; the runner must
    resolve every one to a recovery or a typed
    :class:`~repro.resilient.policy.CampaignFault` — nothing hangs.

This module also holds the injectable clocks (:class:`SimClock`,
:class:`MonotonicClock`) both layers pace their backoff with — they are
fault-injection infrastructure too: simulated time is what makes a 60 s
soak run in seconds, deterministically.

Usage (the CLI drivers and the soak tests are the real call sites):

    inj = FaultInjector(FaultConfig(seed=7, evict_rate=0.1,
                                    nan_at_leg=(3,)))
    core = ServiceCore(config, clock=SimClock(), faults=inj)
    prog.run_resumable(x, T, store=store, faults=inj)

This module is backend-free: importing it never touches JAX.
"""
from __future__ import annotations

import dataclasses
import random
import time


class TransientFault(RuntimeError):
    """An injected failure the retry/degradation machinery should absorb.

    ``kind`` ∈ {'evicted', 'oom', 'device_lost'}: a program/runner-cache
    eviction race (retryable at the same batch width — the rebuild
    succeeds), a simulated device OOM on an over-wide batch (retry at
    the same width keeps failing; the ladder must *narrow* the batch
    instead), or a device dropping out of the mesh mid-campaign (the
    runner must restore elastically onto a smaller mesh).
    """

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"injected {kind}" + (f": {detail}" if detail else ""))
        self.kind = kind


# ================================================================== clocks ==
class SimClock:
    """Manually-advanced milliseconds — the deterministic soak clock.
    Backoff sleeps and injected delays advance it; nothing else does."""

    def __init__(self, start_ms: float = 0.0):
        self._now = float(start_ms)

    def now_ms(self) -> float:
        return self._now

    def advance(self, ms: float) -> None:
        if ms > 0:
            self._now += ms


class MonotonicClock:
    """Real clock: ``time.monotonic``; ``advance`` really sleeps
    (backoff must let the transient condition clear)."""

    def now_ms(self) -> float:
        return time.monotonic() * 1e3

    def advance(self, ms: float) -> None:
        if ms > 0:
            time.sleep(ms / 1e3)


# ================================================================== config ==
@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs for :class:`FaultInjector` — all rates are per-event
    probabilities drawn from one RNG seeded with ``seed``; the
    ``*_at_leg`` knobs are explicit leg indices (1-based, matching the
    campaign runner's leg numbering).

    Dispatch-side (consumed by ``repro.serve``):
      * ``evict_rate`` — before a dispatch, clear ``RUNNER_CACHE`` and
        raise ``TransientFault('evicted')`` once (retry rebuilds).
      * ``oom_batch_limit`` — dispatches wider than this many requests
        raise ``TransientFault('oom')`` *deterministically* (0 disables);
        the ladder must degrade to narrower batches or solo runs.
      * ``delay_ms_range`` — (lo, hi) extra milliseconds a dispatch takes
        (advanced on the service clock), so deadlines can expire while a
        request is in flight.
      * ``nan_output_rate`` — corrupt one output row of a healthy batch
        after compute (tests the guard's batch-mate isolation without a
        poisoned input).

    Traffic-side (consumed by drivers via :meth:`classify_request`):
      * ``nan_input_rate`` — request field arrives NaN-poisoned.
      * ``oversized_rate`` — request shape exceeds the admission cap.
      * ``expired_rate`` — request arrives with an already-spent deadline.

    Campaign-side (consumed by ``repro.resilient.runner``):
      * ``nan_at_leg`` — poison the carry after computing each listed
        leg (a simulated numerical blow-up the health reduction must
        catch).  Transient by default: the injection is consumed, so the
        post-rollback retry of the leg runs clean.
      * ``nan_persistent`` — re-inject on every retry of a listed leg
        too, forcing the bounded retry ladder to exhaust into a typed
        ``CampaignFault`` (the no-hang regression case).
      * ``corrupt_ckpt_at_leg`` — after each listed leg's checkpoint
        lands, flip bytes in its on-disk payload; the store's checksum
        must refuse it at load and fall back to an earlier leg.
      * ``crash_save_at_leg`` — the listed legs' saves die mid-``tmp``
        (files written, atomic rename never happens) — what a SIGKILL
        mid-save leaves on disk; ``latest_leg`` must not see it.
      * ``device_loss_at_leg`` — before dispatching each listed leg of a
        *sharded* campaign, raise ``TransientFault('device_lost')``;
        the runner must restore elastically onto a smaller mesh (one
        loss per listed leg — consumed, like ``nan_at_leg``).
    """

    seed: int = 0
    evict_rate: float = 0.0
    oom_batch_limit: int = 0
    delay_ms_range: tuple = (0, 0)
    nan_output_rate: float = 0.0
    nan_input_rate: float = 0.0
    oversized_rate: float = 0.0
    expired_rate: float = 0.0
    nan_at_leg: tuple = ()
    nan_persistent: bool = False
    corrupt_ckpt_at_leg: tuple = ()
    crash_save_at_leg: tuple = ()
    device_loss_at_leg: tuple = ()


HEALTHY = "healthy"
TRAFFIC_KINDS = ("nan_input", "oversized", "expired")
CAMPAIGN_KINDS = ("nan_leg", "corrupt_ckpt", "crash_save", "device_lost")


class FaultInjector:
    """The seeded fault source; one instance per service/campaign run.

        inj = FaultInjector(FaultConfig(seed=3, evict_rate=0.5))
        inj.should_evict(), inj.should_evict()   # deterministic sequence
    """

    def __init__(self, config: FaultConfig | None = None):
        self.config = config or FaultConfig()
        self._rng = random.Random(self.config.seed)
        self.injected: dict = {"evicted": 0, "oom": 0, "delay_ms": 0,
                               "nan_output": 0, "nan_input": 0,
                               "oversized": 0, "expired": 0,
                               "nan_leg": 0, "corrupt_ckpt": 0,
                               "crash_save": 0, "device_lost": 0}
        # one-shot campaign injections: consumed the first time they fire
        # (unless pinned persistent), so the retry-after-rollback path is
        # exercised against a now-clean leg
        self._nan_pending = set(self.config.nan_at_leg)
        self._loss_pending = set(self.config.device_loss_at_leg)

    # ------------------------------------------------- dispatch hooks ----
    def should_evict(self) -> bool:
        """Roll the eviction-race die (counted when it comes up)."""
        hit = self._rng.random() < self.config.evict_rate
        if hit:
            self.injected["evicted"] += 1
        return hit

    def should_oom(self, batch_width: int) -> bool:
        """True when ``batch_width`` exceeds the configured OOM limit —
        deterministic, so retries at the same width keep failing and the
        ladder is forced to narrow."""
        limit = self.config.oom_batch_limit
        hit = bool(limit) and batch_width > limit
        if hit:
            self.injected["oom"] += 1
        return hit

    def dispatch_delay_ms(self) -> float:
        """Extra service time for this dispatch, in ms (0 when disabled)."""
        lo, hi = self.config.delay_ms_range
        if hi <= 0:
            return 0.0
        d = self._rng.uniform(lo, hi)
        self.injected["delay_ms"] += d
        return d

    def corrupt_output_row(self, batch_width: int) -> int | None:
        """Index of a batch row to NaN-poison post-compute, or None."""
        if self._rng.random() < self.config.nan_output_rate:
            self.injected["nan_output"] += 1
            return self._rng.randrange(batch_width)
        return None

    # -------------------------------------------------- traffic hooks ----
    def classify_request(self) -> str:
        """Draw the kind of the next synthetic request: ``'healthy'`` or
        one of ``TRAFFIC_KINDS`` — drivers shape the request to match."""
        r = self._rng.random()
        cfg = self.config
        edges = (("nan_input", cfg.nan_input_rate),
                 ("oversized", cfg.oversized_rate),
                 ("expired", cfg.expired_rate))
        acc = 0.0
        for kind, rate in edges:
            acc += rate
            if r < acc:
                self.injected[kind] += 1
                return kind
        return HEALTHY

    # ------------------------------------------------- campaign hooks ----
    def poison_leg(self, leg: int) -> bool:
        """True when leg ``leg``'s carry should be NaN-poisoned.  One
        shot per listed leg unless ``nan_persistent`` — the retry after
        rollback then sees a clean run of the same leg."""
        if self.config.nan_persistent:
            hit = leg in self.config.nan_at_leg
        else:
            hit = leg in self._nan_pending
            if hit:
                self._nan_pending.discard(leg)
        if hit:
            self.injected["nan_leg"] += 1
        return hit

    def lose_device(self, leg: int) -> bool:
        """True when a device should drop before dispatching ``leg`` of a
        sharded campaign (one loss per listed leg, consumed)."""
        hit = leg in self._loss_pending
        if hit:
            self._loss_pending.discard(leg)
            self.injected["device_lost"] += 1
        return hit

    def checkpoint_sabotage(self, leg: int) -> str | None:
        """What to do to leg ``leg``'s checkpoint on disk: ``'corrupt'``
        (flip payload bytes after the rename), ``'crash'`` (abandon the
        ``tmp`` dir before the rename — a mid-save SIGKILL), or None."""
        if leg in self.config.crash_save_at_leg:
            self.injected["crash_save"] += 1
            return "crash"
        if leg in self.config.corrupt_ckpt_at_leg:
            self.injected["corrupt_ckpt"] += 1
            return "corrupt"
        return None

    def stats(self) -> dict:
        """Counters of everything injected so far (reported by drivers so
        a soak's fault mix is visible next to its outcome mix)."""
        out = dict(self.injected)
        out["delay_ms"] = round(out["delay_ms"], 3)
        return out
