"""``python -m repro.tuning`` — the tuning front door.

Subcommands (guide with a walkthrough: ``docs/tuning.md``):

  sweep        budgeted measured search per spec; winners -> plan DB
  check        compile ``mode="tuned"`` and exit nonzero on a DB miss
               (the CI smoke's second process)
  show-db      list every record with its key, winner, and health
  prune-stale  delete corrupt records and records tuned under another
               jax version

    PYTHONPATH=src python -m repro.tuning sweep --stencil j2d5pt \\
        --scale 64 --budget 24 --db /tmp/plandb
    PYTHONPATH=src python -m repro.tuning check --stencil j2d5pt \\
        --scale 64 --db /tmp/plandb
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _specs(args, ap):
    from repro.core.stencil_spec import TABLE2, get

    if getattr(args, "taps", None) or getattr(args, "spec_json", None):
        from repro.api import define_stencil, parse_taps, spec_from_json

        return [define_stencil(parse_taps(args.taps),
                               normalize=args.normalize)
                if args.taps else spec_from_json(args.spec_json)]
    names = (list(TABLE2) if args.stencil == "all"
             else args.stencil.split(","))
    unknown = [n for n in names if n not in TABLE2]
    if unknown:
        ap.error(f"unknown stencil(s) {unknown}; choose from "
                 f"{list(TABLE2)} — or pass --taps/--spec-json for a "
                 "custom stencil")
    return [get(n) for n in names]


def _shape(spec, args):
    from repro.stencils.data import reduced_domain

    if args.shape:
        shape = tuple(int(d) for d in args.shape.split(","))
        if len(shape) != spec.ndim:
            raise SystemExit(f"--shape {args.shape} is {len(shape)}-D but "
                             f"{spec.name} is {spec.ndim}-D")
        return shape
    return reduced_domain(spec, args.scale)


def cmd_sweep(args, ap) -> int:
    from repro.tuning.plandb import PlanDB
    from repro.tuning.search import tune

    db = PlanDB(args.db)
    results = []
    for spec in _specs(args, ap):
        res = tune(spec, _shape(spec, args), db=db, budget=args.budget,
                   total_t=args.t_total, max_candidates=args.candidates,
                   log=lambda *a: print(*a, flush=True))
        results.append({"stencil": spec.name, "winner": res.winner.label(),
                        "record": res.record})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
            f.write("\n")
        print(f"[tune] wrote {args.json}")
    return 0


def cmd_check(args, ap) -> int:
    """Exit 0 iff every requested spec resolves mode='tuned' from the
    DB (``prog.tuned['source'] == 'plandb'``) — zero search either way."""
    from repro.api import compile_stencil

    status = 0
    for spec in _specs(args, ap):
        shape = _shape(spec, args)
        prog = compile_stencil(spec, shape, mode="tuned", plan_db=args.db)
        src = (prog.tuned or {}).get("source")
        ok = src == "plandb"
        print(f"[tuned-check] {spec.name} {shape}: source={src} "
              f"t={prog.t} mode={prog.mode} block={prog.plan.block} -> "
              f"{'HIT' if ok else 'MISS'}")
        if not ok:
            status = 1
    return status


def cmd_show_db(args, ap) -> int:
    from repro.tuning.plandb import PlanDB, jax_version

    db = PlanDB(args.db)
    entries = db.entries()
    print(f"[plandb] {db.root}: {len(entries)} record(s)")
    live = jax_version()
    for path, rec in entries:
        name = os.path.basename(path)
        if rec is None:
            print(f"  {name}  CORRUPT (skipped at lookup; prune-stale "
                  "removes it)")
            continue
        key, plan, m = rec.get("key", {}), rec.get("plan", {}), \
            rec.get("measured", {})
        stale = ("" if rec.get("jax_version") == live
                 else f"  STALE (jax {rec.get('jax_version')} != {live})")
        print(f"  {name}  sig={key.get('signature', '?')[:40]}... "
              f"bucket={key.get('shape_bucket')} hw={key.get('hw')} "
              f"tier={key.get('tier')}{stale}")
        print(f"    t={plan.get('t')} block={plan.get('block')} "
              f"lazy_batch={plan.get('lazy_batch')} "
              f"mode={plan.get('exec_mode')} | "
              f"{m.get('best_us')}us ({m.get('ratio_to_naive')}x naive, "
              f"{m.get('timing_calls')} calls) {rec.get('created', '')}")
    return 0


def cmd_prune_stale(args, ap) -> int:
    from repro.tuning.plandb import PlanDB

    removed = PlanDB(args.db).prune_stale()
    for path in removed:
        print(f"[plandb] removed {path}")
    print(f"[plandb] pruned {len(removed)} stale/corrupt record(s)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tuning",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    def common(p, tuning_knobs: bool):
        p.add_argument("--db", default=None,
                       help="plan DB directory (default: $REPRO_PLANDB "
                            "or ~/.cache/repro/plandb)")
        if tuning_knobs:
            p.add_argument("--stencil", default="all")
            p.add_argument("--scale", type=int, default=64)
            p.add_argument("--shape", default=None,
                           help="explicit comma-separated domain "
                                "(overrides --scale)")
            p.add_argument("--taps", default=None,
                           help="tune a custom stencil from a JSON tap "
                                "list (define_stencil)")
            p.add_argument("--spec-json", default=None,
                           help="tune a custom stencil from a JSON spec "
                                "file")
            p.add_argument("--normalize", action="store_true",
                           help="rescale --taps coefficients to sum to 1")

    p = sub.add_parser("sweep", help="measured search; winners -> DB")
    common(p, True)
    p.add_argument("--budget", type=int, default=64,
                   help="max timing calls across all halving rounds")
    p.add_argument("--t-total", type=int, default=None,
                   help="chain length timed per candidate")
    p.add_argument("--candidates", type=int, default=12)
    p.add_argument("--json", default=None)
    p.set_defaults(fn=cmd_sweep)

    p = sub.add_parser("check",
                       help="mode='tuned' compile; exit 1 on DB miss")
    common(p, True)
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("show-db", help="list records + health")
    common(p, False)
    p.set_defaults(fn=cmd_show_db)

    p = sub.add_parser("prune-stale",
                       help="delete corrupt/stale-jax records")
    common(p, False)
    p.set_defaults(fn=cmd_prune_stale)

    args = ap.parse_args(argv)
    return args.fn(args, ap)


if __name__ == "__main__":
    sys.exit(main())
