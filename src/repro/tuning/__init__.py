"""Measured autotuning with a persistent plan database (ROADMAP item 2).

The §6 planner is analytic; ARTEMIS/DRSTENCIL — the paper's strongest
baselines — are empirical searchers.  This package closes the loop:

  * :mod:`repro.tuning.search` — budgeted successive-halving over
    (t, block, lazy_batch, exec mode) candidates seeded by the analytic
    plan's neighborhood, each timed min-of-N through the real
    ``StencilProgram`` runners and scored by the ratio to an interleaved
    naive-reference control (shared-CPU load hits both sides alike);
  * :mod:`repro.tuning.plandb` — winners persisted as checksummed JSON
    records keyed on (spec signature, shape bucket, hw fingerprint,
    interpret/native), written atomically (tmp + ``os.rename``), so
    ``compile_stencil(..., mode="tuned")`` resolves a measured plan with
    ZERO search or timing on a warm DB;
  * :mod:`repro.tuning.analytic` — the dormant ``analysis/hlo_cost``
    wired to each candidate's *lowered* computation: byte/flop counts
    that prune traffic-pathological candidates before any wall clock is
    spent, and a load-immune bench gate signal (``analytic_bytes=``).

CLI: ``python -m repro.tuning {sweep,show-db,prune-stale,check}``
(guide: ``docs/tuning.md``).
"""
from repro.tuning.analytic import analytic_cost, analytic_bytes_per_step
from repro.tuning.plandb import PlanDB, db_key, default_db_path, \
    hw_fingerprint, plan_from_record
from repro.tuning.search import Candidate, TuneResult, neighborhood, tune

__all__ = [
    "Candidate", "PlanDB", "TuneResult", "analytic_bytes_per_step",
    "analytic_cost", "db_key", "default_db_path", "hw_fingerprint",
    "neighborhood", "plan_from_record", "tune",
]
