"""HLO-analytic candidate costs: bytes/flops from the LOWERED program.

``analysis/hlo_cost`` re-derives roofline inputs from ``as_text()`` with
loop-aware trip multipliers; until now only ``launch/dryrun.py`` used
it.  Here it prices *stencil tuning candidates*: each candidate's
multi-sweep chain is lowered and compiled (no execution — XLA:CPU
compiles the interpret-mode Pallas calls into plain HLO) and its HBM
byte traffic + elementwise flops are counted exactly.  Two consumers:

  * the measured search prunes candidates whose per-step traffic is a
    multiple of the best candidate's before spending any wall clock on
    them (``prune_ratio`` in :func:`repro.tuning.search.tune`);
  * benchmarks carry ``analytic_bytes=`` per row, giving
    ``scripts/bench_gate.py`` a traffic gate that shared-CPU load
    cannot contaminate (wall time swings 1.4→70 ms on a noisy box;
    lowered byte counts are deterministic).

A deliberate non-goal: comparing blocked-candidate bytes against the
*naive* reference's bytes.  Interpret-mode lowering materializes mask /
iota / dynamic-slice machinery whose traffic exceeds the naive loop's
on small domains, so the analytic numbers are meaningful RELATIVE to
each other (same lowering pipeline, same machinery), not as an absolute
roofline bound — docs/tuning.md, "What the analytic gate is not".
"""
from __future__ import annotations

from repro.analysis.hlo_cost import HloCost, analyze
from repro.api.program import ProgramCache

# lowering+compiling a chain is ~0.2-0.5 s; candidates within a tune()
# call and repeated bench/gate runs in one process share this cache
ANALYTIC_CACHE = ProgramCache(128, "analytic")


def lowered_text(program, total_t: int | None = None) -> str:
    """The compiled HLO text of ``program.run(x, total_t)``'s chain —
    lowered via ``jax.jit(...).lower(ShapeDtypeStruct)``: shapes only,
    no arrays touched, no execution."""
    import jax

    total_t = program.t if total_t is None else int(total_t)
    fn = jax.jit(program._run_fn(total_t))
    arg = jax.ShapeDtypeStruct(program.shape, program.dtype)
    return fn.lower(arg).compile().as_text()


def analytic_cost(program, total_t: int | None = None) -> HloCost:
    """Loop-aware :class:`HloCost` of the program's ``total_t``-step
    chain (default: one sweep at the program's depth), memoized per
    program key.

        cost = analytic_cost(prog, total_t=prog.t)
        cost.bytes_accessed, cost.ew_flops    # deterministic, load-immune
    """
    total_t = program.t if total_t is None else int(total_t)
    return ANALYTIC_CACHE.get_or_build(
        (program._key, total_t),
        lambda: analyze(lowered_text(program, total_t)))


def analytic_bytes_per_step(program, total_t: int | None = None) -> float:
    """HBM bytes per simulated time step — the search's pruning metric
    and the bench gate's traffic column (normalizing by ``total_t``
    makes depths comparable: a deeper sweep amortizes its traffic over
    more steps)."""
    total_t = program.t if total_t is None else int(total_t)
    return analytic_cost(program, total_t).bytes_accessed / max(1, total_t)
