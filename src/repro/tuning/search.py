"""Budgeted successive-halving search over stencil tuning candidates.

The candidate space is the §6 analytic plan's NEIGHBORHOOD — halve /
keep / double the planner's depth, leading tile, and streaming batch
(2-D additionally tries the scratch kernel) — on the thesis that the
analytic optimum is near-right and measurement should correct it, not
replace it (ARTEMIS/DRSTENCIL search blind; AN5D searches a pruned
neighborhood; we seed from the model).

Noise discipline on a shared CPU (the same protocol as
``scripts/bench_gate.py``):

  * every candidate is timed min-of-N through the real
    ``StencilProgram.run`` chain — one-sided contamination makes the
    minimum the stable estimator (``benchmarks/common.py``);
  * each round ALSO times the untouched naive reference and scores
    candidates by the ratio ``candidate / naive`` — a neighbor-load
    burst slows both sides, so the ranking survives machine load that
    would flip a raw-wall-time argmin;
  * successive halving: every surviving candidate is re-timed each
    round at doubled repetitions, so the total timing budget
    concentrates on the contenders.

Before any wall clock is spent, candidates are priced analytically
(:mod:`repro.tuning.analytic`): a candidate whose per-step lowered HBM
traffic exceeds ``prune_ratio`` × the cheapest candidate's cannot win
on a memory-bound stencil and is dropped unmeasured (the analytic seed
itself is never pruned).

Every timing call increments ``TIMING["calls"]`` — the injected counter
``tests/test_tuning.py`` uses to assert that a warm-DB
``compile_stencil(..., mode="tuned")`` performs ZERO timing.
"""
from __future__ import annotations

import dataclasses
import math
import time

from repro.core import roofline as rl
from repro.tuning import plandb as _plandb
from repro.tuning.analytic import analytic_bytes_per_step

# the ONE seam through which the search observes wall time; the tuned
# compile path must never touch it (asserted in tests)
TIMING = {"calls": 0}


def _timed(fn, reps: int) -> float:
    """Best wall time per call in µs over ``reps`` calls (min-of-N)."""
    import jax

    best = float("inf")
    for _ in range(reps):
        TIMING["calls"] += 1
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space: sweep depth, per-grid-step block,
    streaming batch, and which kernel family executes it."""
    t: int
    block: tuple
    lazy_batch: int
    exec_mode: str     # 'fused' | 'scratch' (2-D only)

    def label(self) -> str:
        b = "x".join(str(int(v)) for v in self.block)
        return f"t{self.t}-b{b}-lb{self.lazy_batch}-{self.exec_mode}"


def pinned_plan(spec, shape, hw, cand: Candidate):
    """The analytic plan with the candidate's knobs pinned over it — the
    front door honors an explicit plan verbatim, so the search and tuned
    replay drive the exact same dispatch path."""
    from repro.api.program import plan_bucketed

    base = plan_bucketed(spec, shape, hw)
    return dataclasses.replace(
        base, t=cand.t, halo=spec.halo(cand.t), block=cand.block,
        lazy_batch=max(1, min(cand.lazy_batch, cand.block[0])))


def neighborhood(spec, shape, plan, *,
                 max_candidates: int = 12) -> list[Candidate]:
    """Candidates around the §6 plan: {½, 1, 2}× depth × {½, 1, 2}× the
    leading tile (× kernel family in 2-D; × {1, plan} streaming batch in
    3-D), deduplicated, seed first, nearest-to-seed order, truncated to
    ``max_candidates`` (the CI smoke runs with 4)."""
    ts = sorted({max(1, plan.t // 2), plan.t, plan.t * 2})
    ts = [t for t in ts if 2 * spec.halo(t) <= min(shape)] or [1]
    lead = plan.block[0]
    tiles = sorted({max(1, lead // 2), lead, lead * 2})
    if spec.ndim == 2:
        modes, lazies = ("fused", "scratch"), (plan.lazy_batch,)
    else:
        modes, lazies = ("fused",), tuple(sorted({1, plan.lazy_batch}))
    seed = Candidate(plan.t, tuple(plan.block), plan.lazy_batch, "fused")
    cands = {seed}
    for t in ts:
        for tile in tiles:
            for lazy in lazies:
                for mode in modes:
                    cands.add(Candidate(t, (tile,) + tuple(plan.block[1:]),
                                        lazy, mode))

    def dist(c: Candidate):
        return (c is not seed and c != seed,
                abs(math.log2(c.t / plan.t)),
                abs(math.log2(c.block[0] / lead)),
                c.exec_mode != "fused", c.lazy_batch, c.label())

    ordered = sorted(cands, key=dist)
    return ordered[:max(1, max_candidates)]


@dataclasses.dataclass
class TuneResult:
    winner: Candidate
    plan: object               # the winner's pinned EbisuPlan
    record: dict               # the plandb record (written when db given)
    rounds: list               # per-round {reps, naive_us, scores}
    candidates: list           # everything the neighborhood proposed
    pruned: list               # (candidate, reason) dropped pre-timing
    timing_calls: int

    def summary(self) -> str:
        last = self.rounds[-1]["scores"] if self.rounds else {}
        us, ratio = last.get(self.winner, (float("nan"), float("nan")))
        return (f"winner {self.winner.label()}: {us:.0f}us "
                f"({ratio:.3f}x naive) after {len(self.rounds)} round(s), "
                f"{self.timing_calls} timing calls, "
                f"{len(self.pruned)} pruned analytically")


def tune(spec, shape, *, hw=rl.TPU_V5E, db=None, budget: int = 64,
         total_t: int | None = None, reps: int = 2,
         interpret: bool | None = None, prune_ratio: float = 3.0,
         max_candidates: int = 12, log=None) -> TuneResult:
    """Search the plan neighborhood under a timing-call ``budget`` and
    (when ``db`` is given) persist the winner for
    ``compile_stencil(..., mode="tuned")`` to replay with zero search.

        db = PlanDB(path)
        res = tune(get("j2d5pt"), (128, 128), db=db, budget=24)
        res.winner, res.summary()

    ``budget`` caps timing calls (min-of-N reps each count N); the first
    round always runs in full so every unpruned candidate is measured at
    least once.  ``total_t`` is the chain length timed (default: twice
    the deepest candidate, so deep sweeps amortize as they would in a
    campaign).  Candidates that fail to compile/warm up (e.g. a doubled
    depth that busts the VMEM model) are dropped with a reason, not
    fatal.
    """
    import jax

    from repro.api.program import compile_stencil, plan_bucketed
    from repro.kernels import ref
    from repro.stencils.data import init_domain

    say = log if log is not None else (lambda *_: None)
    base = plan_bucketed(spec, shape, hw)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tier = "interpret" if interpret else "native"
    candidates = neighborhood(spec, shape, base,
                              max_candidates=max_candidates)
    seed = candidates[0]
    total_t = (2 * max(c.t for c in candidates) if total_t is None
               else int(total_t))

    x = init_domain(spec, shape)
    progs, pruned = {}, []
    for c in candidates:
        try:
            progs[c] = compile_stencil(
                spec, shape, t=c.t, hw=hw, mode=c.exec_mode,
                interpret=interpret, plan=pinned_plan(spec, shape, hw, c))
        except ValueError as e:
            pruned.append((c, f"compile: {e}"))

    # analytic pruning: per-step lowered HBM bytes, relative to the
    # cheapest candidate (never to naive — see tuning/analytic.py)
    per_step = {}
    for c, prog in progs.items():
        try:
            per_step[c] = analytic_bytes_per_step(prog)
        except Exception as e:  # noqa: BLE001 — pruning is best-effort
            per_step[c] = float("inf")
            say(f"[tune] analytic lowering failed for {c.label()}: {e}")
    floor = min(per_step.values(), default=float("inf"))
    survivors = []
    for c in progs:
        if c != seed and per_step[c] > prune_ratio * floor:
            pruned.append((c, f"analytic: {per_step[c]:.0f} B/step > "
                              f"{prune_ratio:.1f}x floor {floor:.0f}"))
        else:
            survivors.append(c)
    say(f"[tune] {spec.name} {shape}: {len(candidates)} candidates, "
        f"{len(pruned)} pruned, timing {len(survivors)} (budget {budget})")

    # warm every survivor and the naive control OUTSIDE the timed region
    naive_fn = jax.jit(lambda v: ref.reference(v, spec, total_t))
    jax.block_until_ready(naive_fn(x))
    warmed = []
    for c in survivors:
        try:
            jax.block_until_ready(progs[c].run(x, total_t))
            warmed.append(c)
        except Exception as e:  # noqa: BLE001
            pruned.append((c, f"warmup: {e}"))
    survivors = warmed or [seed]

    rounds, spent, r = [], 0, max(1, reps)
    while True:
        cost = (len(survivors) + 1) * r
        if rounds and spent + cost > budget:
            break
        naive_us = _timed(lambda: naive_fn(x), r)
        scores = {}
        for c in survivors:
            us = _timed(lambda c=c: progs[c].run(x, total_t), r)
            scores[c] = (us, us / naive_us)
        spent += cost
        rounds.append({"reps": r, "naive_us": naive_us, "scores": scores})
        ranked = sorted(survivors, key=lambda c: scores[c][1])
        say("[tune] round {}: naive {:.0f}us | ".format(len(rounds),
                                                        naive_us)
            + " ".join(f"{c.label()}={scores[c][1]:.2f}x" for c in ranked))
        if len(survivors) == 1:
            break
        survivors = ranked[:max(1, math.ceil(len(survivors) / 2))]
        r *= 2

    winner = min(rounds[-1]["scores"],
                 key=lambda c: rounds[-1]["scores"][c][1])
    wplan = pinned_plan(spec, shape, hw, winner)
    us, ratio = rounds[-1]["scores"][winner]
    measured = {
        "best_us": round(us, 1),
        "naive_us": round(rounds[-1]["naive_us"], 1),
        "ratio_to_naive": round(ratio, 4),
        "total_t": total_t,
        "rounds": len(rounds),
        "timing_calls": spent,
        "budget": budget,
        "analytic_bytes_per_step": round(per_step.get(winner, 0.0), 1),
        "seed_was_winner": winner == seed,
    }
    key = _plandb.db_key(spec, shape, _plandb.hw_fingerprint(), tier)
    record = _plandb.make_record(key, wplan, winner.exec_mode, measured)
    if db is not None:
        path = _plandb.resolve_db(db).put(key, record)
        say(f"[tune] persisted winner -> {path}")
    res = TuneResult(winner=winner, plan=wplan, record=record,
                     rounds=rounds, candidates=candidates, pruned=pruned,
                     timing_calls=spent)
    say("[tune] " + res.summary())
    return res
