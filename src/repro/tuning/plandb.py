"""Persistent plan database: measured winners, keyed and checksummed.

One record = one JSON file under the DB directory, named by the SHA-256
digest of its key.  The key is everything a measured plan is conditioned
on — change any component and the record is a different plan:

  * ``spec.signature`` — the tap structure + cost-model numbers (the
    same registry-free identity ``plan_bucketed`` keys on);
  * the 64-rounded shape bucket (a plan tuned at (500, 500) serves
    (512, 512) but not (1024, 1024));
  * the hardware fingerprint (backend + device kind — a plan tuned on a
    CPU interpreter must never serve a TPU);
  * the execution tier, ``interpret`` or ``native`` (interpret-mode wall
    time ranks candidates differently from compiled-mode wall time).

The jax version is deliberately NOT part of the key: it is stored in
the record and checked at lookup, so an upgrade turns every old record
into a *stale* entry that is skipped with a warning (and reclaimed by
``prune_stale``) instead of silently orphaning files under dead keys.

Write discipline is the ``resilient/store.py`` pattern: payload lands in
``<digest>.json.tmp<pid>`` and is ``os.rename``d into place as the last
act — a SIGKILL mid-save leaves a ``.tmp`` orphan that ``get`` never
reads, never a torn visible record.  Every record carries a CRC-32 of
its canonical payload; corrupt or unparseable records are a *miss with
a warning*, never an exception — a flipped bit on disk costs one
re-tune, not the front door.

    db = PlanDB(path)
    db.put(key, record)                      # atomic + checksummed
    rec = db.get(key)                        # None on miss/corrupt/stale
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
import zlib

SCHEMA_VERSION = 1
_BUCKET = 64     # mirrors repro.api.plan_bucketed's shape rounding


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def default_db_path() -> str:
    """``$REPRO_PLANDB`` when set, else ``~/.cache/repro/plandb``."""
    env = os.environ.get("REPRO_PLANDB")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "plandb")


def hw_fingerprint() -> str:
    """``backend:device_kind`` of the default device — resolved lazily at
    call time (tune/tuned-compile paths), never at import, so importing
    the package initializes no JAX backend."""
    import jax

    backend = jax.default_backend()
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 — fingerprint must never raise
        kind = "unknown"
    return f"{backend}:{kind}".replace(" ", "_")


def jax_version() -> str:
    import jax

    return jax.__version__


def db_key(spec, shape, hw_fp: str, tier: str) -> dict:
    """The JSON-safe lookup key (see module docstring for the contract).

    ``tier`` is ``"interpret"`` or ``"native"`` — which executor family
    the wall times that picked this plan came from.
    """
    if tier not in ("interpret", "native"):
        raise ValueError(f"tier must be 'interpret' or 'native', got "
                         f"{tier!r}")
    return {
        "schema": SCHEMA_VERSION,
        "signature": repr(spec.signature),
        "shape_bucket": [_pad_to(int(d), _BUCKET) for d in shape],
        "hw": hw_fp,
        "tier": tier,
    }


def _canonical(obj) -> bytes:
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def key_digest(key: dict) -> str:
    return hashlib.sha256(_canonical(key)).hexdigest()[:24]


def record_checksum(record: dict) -> int:
    """CRC-32 over the canonical payload, ``checksum`` field excluded."""
    body = {k: v for k, v in record.items() if k != "checksum"}
    return zlib.crc32(_canonical(body))


def make_record(key: dict, plan, exec_mode: str, measured: dict) -> dict:
    """A winner as a self-describing JSON record (the plan fields are
    exactly what ``plan_from_record`` re-pins onto the analytic base)."""
    return {
        "key": key,
        "jax_version": jax_version(),
        "plan": {
            "t": int(plan.t),
            "block": [int(b) for b in plan.block],
            "lazy_batch": int(plan.lazy_batch),
            "num_buffers": int(plan.parallelism.num_buffers),
            "exec_mode": str(exec_mode),
        },
        "measured": dict(measured),
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def plan_from_record(spec, shape, hw, record: dict):
    """Rebuild a pinned :class:`EbisuPlan` from a DB record: the analytic
    plan for (spec, shape bucket, hw) with the measured (t, block,
    lazy_batch, num_buffers) pinned over it — the same pinning the
    search used to time the candidate, so tuned execution replays the
    measured configuration exactly."""
    from repro.api.program import plan_bucketed

    base = plan_bucketed(spec, shape, hw)
    p = record["plan"]
    t = int(p["t"])
    par = dataclasses.replace(base.parallelism,
                              num_buffers=int(p["num_buffers"]))
    return dataclasses.replace(
        base, t=t, halo=spec.halo(t),
        block=tuple(int(b) for b in p["block"]),
        lazy_batch=int(p["lazy_batch"]), parallelism=par)


class PlanDB:
    """Directory of one-record-per-file JSON plans (module docstring has
    the key/staleness/atomicity contract).

        db = PlanDB("/path/to/db")
        db.put(db_key(spec, shape, hw_fingerprint(), "interpret"), rec)
        db.get(key)       # record dict, or None (miss/corrupt/stale)
    """

    def __init__(self, root: str | None = None):
        self.root = str(root) if root else default_db_path()

    def _path(self, key: dict) -> str:
        return os.path.join(self.root, f"{key_digest(key)}.json")

    # ------------------------------------------------------------- put ----
    def put(self, key: dict, record: dict, *,
            sabotage: str | None = None) -> str:
        """Atomically persist ``record`` under ``key``; returns the path.

        ``sabotage`` is the fault-injection seam (tests only):
        ``'crash'`` abandons the ``.tmp`` file before the rename — what
        a mid-save SIGKILL leaves behind; ``'corrupt'`` flips payload
        bytes after the rename — a bad disk.
        """
        os.makedirs(self.root, exist_ok=True)
        rec = dict(record)
        rec["key"] = key
        rec["checksum"] = record_checksum(rec)
        final = self._path(key)
        tmp = final + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        if sabotage == "crash":      # die before the atomic rename
            return tmp
        os.rename(tmp, final)
        if sabotage == "corrupt":
            _flip_bytes(final)
        return final

    # ------------------------------------------------------------- get ----
    def get(self, key: dict) -> dict | None:
        """The record under ``key``, or ``None``.  Corrupt (unparseable /
        checksum mismatch / wrong key in the file) and stale (other jax
        version) records are misses WITH a warning — the caller falls
        back to the analytic plan, never crashes."""
        path = self._path(key)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                rec = json.load(f)
        except (OSError, ValueError) as e:
            warnings.warn(f"plandb: skipping corrupt record {path} "
                          f"(unparseable: {e})", stacklevel=2)
            return None
        if not isinstance(rec, dict) or "checksum" not in rec:
            warnings.warn(f"plandb: skipping corrupt record {path} "
                          "(no checksum)", stacklevel=2)
            return None
        if record_checksum(rec) != rec["checksum"]:
            warnings.warn(f"plandb: skipping corrupt record {path} "
                          "(checksum mismatch — bytes changed on disk)",
                          stacklevel=2)
            return None
        if rec.get("key") != key:
            warnings.warn(f"plandb: skipping record {path} whose stored "
                          "key does not match its digest (hand-edited?)",
                          stacklevel=2)
            return None
        live = jax_version()
        if rec.get("jax_version") != live:
            warnings.warn(
                f"plandb: skipping stale record {path} (tuned under jax "
                f"{rec.get('jax_version')}, running {live} — re-tune or "
                "`python -m repro.tuning prune-stale`)", stacklevel=2)
            return None
        return rec

    def lookup(self, spec, shape, tier: str) -> dict | None:
        """``get`` with the key derived from the live hardware."""
        return self.get(db_key(spec, shape, hw_fingerprint(), tier))

    # ------------------------------------------------------ maintenance ----
    def entries(self) -> list[tuple[str, dict | None]]:
        """Every visible ``(path, record-or-None)``; ``None`` marks a file
        that fails to parse (``show-db`` reports it, ``get`` skips it).
        ``.tmp`` orphans from crashed saves are never listed."""
        if not os.path.isdir(self.root):
            return []
        out = []
        for fname in sorted(os.listdir(self.root)):
            if not fname.endswith(".json"):
                continue
            path = os.path.join(self.root, fname)
            try:
                with open(path) as f:
                    rec = json.load(f)
                if record_checksum(rec) != rec.get("checksum"):
                    rec = None
            except (OSError, ValueError):
                rec = None
            out.append((path, rec))
        return out

    def prune_stale(self) -> list[str]:
        """Delete corrupt records and records tuned under another jax
        version (plus ``.tmp`` orphans); returns the removed paths."""
        removed = []
        live = jax_version()
        for path, rec in self.entries():
            if rec is None or rec.get("jax_version") != live:
                os.remove(path)
                removed.append(path)
        if os.path.isdir(self.root):
            for fname in os.listdir(self.root):
                if ".json.tmp" in fname:
                    path = os.path.join(self.root, fname)
                    os.remove(path)
                    removed.append(path)
        return removed


def resolve_db(plan_db) -> PlanDB:
    """``None`` → default path; ``str``/path → that directory; a
    :class:`PlanDB` passes through."""
    if isinstance(plan_db, PlanDB):
        return plan_db
    return PlanDB(plan_db if plan_db else None)


def _flip_bytes(path: str, n: int = 6) -> None:
    """Corrupt ``n`` bytes mid-file (fault model: bit rot — the JSON may
    still parse, the checksum catches it)."""
    size = os.path.getsize(path)
    off = max(size // 2, 2)
    with open(path, "r+b") as f:
        f.seek(off)
        chunk = f.read(n)
        f.seek(off)
        f.write(bytes((b ^ 0xFF) for b in chunk))
