import sys

from repro.tuning.cli import main

sys.exit(main())
