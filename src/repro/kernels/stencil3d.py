"""EBISU-3D Pallas kernel: lazy-batched z-streaming through VMEM queues.

This is the paper's Fig. 5/6 scheme on the TPU memory hierarchy, with the
§6 planner's decisions wired all the way in:

  * Each Pallas grid step is a *device tile*: a chunk of ``zc`` output
    planes.  **Halo-exact fetching**: the chunk's z-context comes from one
    ``halo``-plane sub-block on each side (``HALO = t·rad``) selected by
    halo-granular BlockSpecs — input traffic per grid step is
    ``zc + 2·halo`` planes, not the ``3·zc`` of whole neighbor chunks
    (DESIGN.md §8.4).  ``zc`` is rounded up to a multiple of ``halo`` so
    the rim sub-blocks are block-aligned.
  * Inside the kernel, planes stream through a **multi-queue**: one
    sliding window of ``W = B + 2·rad`` planes per temporal step, held in
    VMEM scratch.  This is the paper's *shifting* addressing mode
    (§4.2.2) batched by ``B = lazy_batch`` planes: per pipeline stage the
    window shifts by ``B`` and one *batched* vectorized tap application
    (``taps.TapEngine.window_step``) advances ``B`` planes of a temporal
    step at once — lazy streaming with honest batch granularity instead
    of a plane-at-a-time ``fori_loop``.
  * When input planes ``[z, z+B)`` (time 0) are enqueued, planes
    ``[z - s·rad, z+B - s·rad)`` of time ``s`` become computable —
    dequeue of step ``s`` overlaps enqueue of step ``s+1`` ("seamless
    time-step transitions").  The whole schedule is statically unrolled
    (``(zc + 2·halo)/B`` stages), so every queue access is a static
    slice — no dynamic ring arithmetic on the hot path.
  * The final time step is written straight to the output block — lazy
    streaming's "one sync per tile": a grid step has a single pipeline
    boundary regardless of depth ``t``.

Boundary semantics: zero outside the domain at every step.  The domain
sits at ``[0, zdim) × [0, ydim) × [0, xdim)`` of the padded array; the
per-batch {0,1} mask (global-z validity × in-plane validity) is applied
as one multiply per batched tap application (DESIGN.md §8.1-2).  Queue
windows are zero-initialized so strip planes below the chunk read as the
tap engine's zero-fill — garbage in the out-of-strip "error zone" decays
before it can reach an output plane (DESIGN.md §8.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multiqueue import stream_schedule
from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import engine_for


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def chunk_geometry(spec: StencilSpec, t: int, zc: int) -> tuple[int, int]:
    """Resolve the (zc, halo) a 3-D launch will actually use.

    ``zc`` is raised to at least one halo and rounded up to a multiple of
    ``halo`` so the rim sub-blocks of the halo-exact fetch are aligned.
    """
    halo = spec.halo(t)
    zc = max(zc, halo)
    return _pad_to(zc, halo), halo


def input_planes_per_chunk(spec: StencilSpec, t: int, zc: int) -> tuple[int, int]:
    """Modeled input traffic: (planes fetched per chunk, chunk body planes)."""
    zc, halo = chunk_geometry(spec, t, zc)
    return zc + 2 * halo, zc


def _stream_kernel(top_ref, mid_ref, bot_ref, out_ref, buf, *,
                   taps, t: int, rad: int, zc: int, halo: int, batch: int,
                   zdim: int, ydim: int, xdim: int):
    i = pl.program_id(0)
    engine = engine_for(taps, 3)
    yp, xp = mid_ref.shape[1], mid_ref.shape[2]
    sz = zc + 2 * halo
    kz = zc // halo
    w = batch + 2 * rad
    z_base = i * zc - halo               # global z of strip plane 0

    def zmask(p0: int, n: int) -> jnp.ndarray:
        """Global-z Dirichlet validity of strip planes [p0, p0+n)."""
        zg = z_base + p0 + jax.lax.broadcasted_iota(jnp.int32, (n, 1, 1), 0)
        return ((zg >= 0) & (zg < zdim)).astype(jnp.float32)

    # The pipeline computes on planes cropped to the true domain extent:
    # the y/x pad lanes exist only for TPU tile alignment, and cropping
    # makes the zero-fill slicing edge coincide with the in-plane Dirichlet
    # boundary — no y/x mask at all (DESIGN.md §8.2).  Only the z boundary
    # stays a per-batch mask (it moves with the grid step).
    def crop(planes: jnp.ndarray) -> jnp.ndarray:
        return planes[:, :ydim, :xdim]

    # Queue windows are per-grid-step state.  Only the tail-source slice
    # [batch, w) must be zeroed: the first shift of each queue copies it to
    # the window head, where it stands in for the planes below the strip —
    # the zero-fill edge (DESIGN.md §8.3); the rest is overwritten before
    # it is ever read.
    buf[:, batch:w] = jnp.zeros((t, w - batch, ydim, xdim), jnp.float32)

    def advance(queue: int, planes: jnp.ndarray) -> None:
        """Shift queue's window by one batch (paper's 'shifting' mode)."""
        tail = buf[queue, batch:w]
        buf[queue, 0:2 * rad] = tail
        buf[queue, 2 * rad:w] = planes

    for n in range(sz // batch):
        z0 = n * batch
        # ---- batched enqueue of input planes [z0, z0+batch) into queue 0.
        # A batch is whole halo-sub-blocks, each living in exactly one of
        # the three halo-exact views.
        chunks = []
        for j in range(z0 // halo, (z0 + batch) // halo):
            if j == 0:
                chunks.append(top_ref[...])
            elif j <= kz:
                chunks.append(mid_ref[(j - 1) * halo:j * halo])
            else:
                chunks.append(bot_ref[...])
        newp = (crop(jnp.concatenate(chunks, axis=0)).astype(jnp.float32)
                * zmask(z0, batch))
        advance(0, newp)

        # ---- cascade: one batched tap application per temporal step -----
        for s in range(1, t + 1):
            p0 = z0 - s * rad            # first plane this step produces
            window = buf[s - 1][...]     # (w, ydim, xdim), already advanced
            planes = engine.window_step(window, batch, mask=zmask(p0, batch))
            if s < t:
                advance(s, planes)
            else:
                lo, hi = max(p0, halo), min(p0 + batch, halo + zc)
                if lo < hi:
                    body = planes[lo - p0:hi - p0]
                    body = jnp.pad(body, ((0, 0), (0, yp - ydim),
                                          (0, xp - xdim)))
                    out_ref[lo - halo:hi - halo] = body.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "t", "zc", "lazy_batch",
                                             "num_buffers", "interpret"))
def ebisu3d(x: jnp.ndarray, spec: StencilSpec, t: int, *, zc: int = 16,
            lazy_batch: int | None = None, num_buffers: int | None = None,
            interpret: bool = True) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked steps of a 3-D ``spec`` via z-streaming."""
    assert spec.ndim == 3
    zdim, ydim, xdim = x.shape
    rad = spec.radius
    zc, halo = chunk_geometry(spec, t, zc)
    kz = zc // halo
    batch, w, _ = stream_schedule(zc, halo, rad,
                                  lazy_batch if lazy_batch else zc)

    zp = _pad_to(zdim, zc)
    yp = _pad_to(ydim, 8)
    xp = _pad_to(xdim, 128)
    xpad = jnp.zeros((zp, yp, xp), jnp.float32).at[
        :zdim, :ydim, :xdim].set(x.astype(jnp.float32))
    grid = zp // zc
    nsub = zp // halo

    def idx_top(i):
        return (jnp.maximum(i * kz - 1, 0), 0, 0)

    def idx_mid(i):
        return (i, 0, 0)

    def idx_bot(i):
        return (jnp.minimum((i + 1) * kz, nsub - 1), 0, 0)

    kern = functools.partial(
        _stream_kernel, taps=spec.taps, t=t, rad=rad, zc=zc, halo=halo,
        batch=batch, zdim=zdim, ydim=ydim, xdim=xdim)

    params = {}
    if not interpret:
        limit = None
        if num_buffers is not None:
            scr = t * w * yp * xp * 4
            io = (zc + 2 * halo + zc) * yp * xp * 4
            limit = min(128 << 20, max(32 << 20,
                                       2 * (scr + num_buffers * io)))
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",), vmem_limit_bytes=limit)

    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((halo, yp, xp), idx_top),
            pl.BlockSpec((zc, yp, xp), idx_mid),
            pl.BlockSpec((halo, yp, xp), idx_bot),
        ],
        out_specs=pl.BlockSpec((zc, yp, xp), idx_mid),
        out_shape=jax.ShapeDtypeStruct((zp, yp, xp), x.dtype),
        scratch_shapes=[pltpu.VMEM((t, w, ydim, xdim), jnp.float32)],
        interpret=interpret,
        **params,
    )(xpad, xpad, xpad)
    return out[:zdim, :ydim, :xdim]
