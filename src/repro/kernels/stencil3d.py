"""EBISU-3D Pallas kernel: lazy-batched z-streaming through VMEM queues.

This is the paper's Fig. 5/6 scheme on the TPU memory hierarchy, with the
§6 planner's decisions wired all the way in:

  * Each Pallas grid step is a *device tile*: a chunk of ``zc`` output
    planes × a ``(ty, tx)`` in-plane tile (``plan.block``).  The grid is
    ``(gz, gy, gx)`` — the planner's §6.4 deeper-or-wider choice is
    executed, not decorative: large domains run at planner-chosen XY
    tiles instead of whatever pads into VMEM.
  * **Halo-exact fetching on every blocked axis**: the tile's context
    comes from one ``halo``-deep sub-block per side, selected by
    halo-granular BlockSpecs (``HALO = t·rad``) — input traffic per grid
    step is ``(zc + 2·halo) × (ty + 2·halo) × (tx + 2·halo)`` cells, not
    whole neighbor blocks.  Each tiled axis is rounded up to a multiple
    of ``halo`` so its rim sub-blocks are block-aligned (DESIGN.md §8.4,
    §9.2).  An axis whose tile covers the whole domain stays *untiled*:
    no rim views, and the zero-fill slicing edge is its Dirichlet
    boundary for free (DESIGN.md §8.2).
  * Inside the kernel, planes stream through a **multi-queue**: one
    sliding window of ``W = B + 2·rad`` planes per temporal step, held in
    VMEM scratch (padded to (8, 128) lane alignment).  This is the
    paper's *shifting* addressing mode (§4.2.2) batched by
    ``B = lazy_batch`` planes: per pipeline stage the window shifts by
    ``B`` and one *batched* vectorized tap application
    (``taps.TapEngine.window_step``) advances ``B`` planes of a temporal
    step at once — lazy streaming with honest batch granularity instead
    of a plane-at-a-time ``fori_loop``.
  * On tiled in-plane axes the cascade is **trapezoid-narrowed**
    (DESIGN.md §9.1): the time-``s`` planes carry only the
    ``tile + 2·(t−s)·rad`` live extent, computed in valid mode from the
    fetched halo — per-step in-plane FLOPs shrink with depth instead of
    recomputing the full haloed tile every step.
  * When input planes ``[z, z+B)`` (time 0) are enqueued, planes
    ``[z - s·rad, z+B - s·rad)`` of time ``s`` become computable —
    dequeue of step ``s`` overlaps enqueue of step ``s+1`` ("seamless
    time-step transitions").  The whole schedule is statically unrolled
    (``(zc + 2·halo)/B`` stages), so every queue access is a static
    slice — no dynamic ring arithmetic on the hot path.
  * The final time step is written straight to the output block — lazy
    streaming's "one sync per tile": a grid step has a single pipeline
    boundary regardless of depth ``t``.

Boundary semantics: zero outside the domain at every step.  The domain
sits at ``[0, zdim) × [0, ydim) × [0, xdim)`` of the padded array; the
per-batch {0,1} validity factors (global-z × global-y × global-x, the
latter two only on tiled axes) are applied as broadcast multiplies per
batched tap application (DESIGN.md §8.1-2, §9.2).  Queue windows are
zero-initialized so strip planes below the chunk read as the tap
engine's zero-fill — garbage in the out-of-strip "error zone" decays
before it can reach an output plane (DESIGN.md §8.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multiqueue import stream_schedule
from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import (check_boundary, engine_for,
                                is_zero_dirichlet, with_boundary)


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def chunk_geometry(spec: StencilSpec, t: int, zc: int) -> tuple[int, int]:
    """Resolve the (zc, halo) a 3-D launch will actually use.

    ``zc`` is raised to at least one halo and rounded up to a multiple of
    ``halo`` so the rim sub-blocks of the halo-exact fetch are aligned.
    """
    halo = spec.halo(t)
    zc = max(zc, halo)
    return _pad_to(zc, halo), halo


def xy_tile(spec: StencilSpec, t: int, dim: int,
            tile: int | None) -> tuple[int, bool]:
    """Resolve a requested in-plane tile: (extent, tiled?).

    ``None`` (or a tile that covers the domain once rounded to a halo
    multiple) means the axis is untiled — full extent, no rim views.
    """
    if tile is None:
        return dim, False
    halo = spec.halo(t)
    tile = _pad_to(max(tile, halo), halo)
    if tile >= dim:
        return dim, False
    return tile, True


def input_planes_per_chunk(spec: StencilSpec, t: int, zc: int) -> tuple[int, int]:
    """Modeled input traffic: (planes fetched per chunk, chunk body planes)."""
    zc, halo = chunk_geometry(spec, t, zc)
    return zc + 2 * halo, zc


def launch_geometry_3d(spec: StencilSpec, t: int, shape: tuple[int, int, int],
                       *, zc: int = 16, ty: int | None = None,
                       tx: int | None = None) -> dict:
    """The geometry a 3-D launch will actually execute (no tracing).

    Returns grid, per-grid-step block, halo, per-axis tiled flags, the
    padded array shape, and the halo-exact fetched/body cell counts per
    grid step — the quantities the bench's traffic model and the
    planner-honoring tests consume.
    """
    zdim, ydim, xdim = shape
    zc, halo = chunk_geometry(spec, t, zc)
    ty_r, tiled_y = xy_tile(spec, t, ydim, ty)
    tx_r, tiled_x = xy_tile(spec, t, xdim, tx)
    zp = _pad_to(zdim, zc)
    yp = _pad_to(ydim, ty_r) if tiled_y else _pad_to(ydim, 8)
    xp = _pad_to(xdim, tx_r) if tiled_x else _pad_to(xdim, 128)
    grid = (zp // zc,
            yp // ty_r if tiled_y else 1,
            xp // tx_r if tiled_x else 1)
    sy = ty_r + 2 * halo if tiled_y else ydim
    sx = tx_r + 2 * halo if tiled_x else xdim
    fetched = (zc + 2 * halo) * sy * sx
    body = zc * ty_r * tx_r
    return dict(grid=grid, block=(zc, ty_r, tx_r), halo=halo,
                tiled=(True, tiled_y, tiled_x), padded=(zp, yp, xp),
                fetched_cells=fetched, body_cells=body)


def _stream_kernel(*args, taps, t: int, rad: int, zc: int, halo: int,
                   batch: int, zdim: int, ydim: int, xdim: int,
                   ty: int, tx: int, nyk: int, nxk: int):
    refs, out_ref, buf = args[:-2], args[-2], args[-1]
    iz, iy, ix = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    engine = engine_for(taps, 3)
    # compute dtype policy: the kernel computes in the dtype of the padded
    # buffer it was handed (the scratch windows are allocated to match)
    cdtype = buf.dtype
    tiled_y, tiled_x = nyk == 3, nxk == 3
    kz = zc // halo
    sz = zc + 2 * halo
    sy = ty + 2 * halo if tiled_y else ydim
    sx = tx + 2 * halo if tiled_x else xdim
    cy = rad if tiled_y else 0          # per-step in-plane narrowing
    cx = rad if tiled_x else 0
    w = batch + 2 * rad
    z_base = iz * zc - halo             # global z of strip plane 0
    y_base = iy * ty - halo if tiled_y else 0
    x_base = ix * tx - halo if tiled_x else 0
    by, bx = out_ref.shape[1], out_ref.shape[2]

    def view(zi: int, yi: int, xi: int):
        return refs[(zi * nyk + yi) * nxk + xi]

    def ey(s: int) -> int:              # live y extent of time-s planes
        return sy - 2 * s * cy

    def ex(s: int) -> int:
        return sx - 2 * s * cx

    def apply_masks(planes: jnp.ndarray, p0: int, s: int) -> jnp.ndarray:
        """Dirichlet validity of time-s strip planes [p0, p0+n): global-z
        always (the z boundary moves with the grid step), global-y/x only
        on tiled axes (untiled axes are domain-cropped — their zero-fill
        edge is the boundary)."""
        n = planes.shape[0]
        zg = z_base + p0 + jax.lax.broadcasted_iota(jnp.int32, (n, 1, 1), 0)
        planes = planes * ((zg >= 0) & (zg < zdim)).astype(cdtype)
        if tiled_y:
            yg = (y_base + s * rad
                  + jax.lax.broadcasted_iota(jnp.int32, (1, ey(s), 1), 1))
            planes = planes * ((yg >= 0) & (yg < ydim)).astype(cdtype)
        if tiled_x:
            xg = (x_base + s * rad
                  + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ex(s)), 2))
            planes = planes * ((xg >= 0) & (xg < xdim)).astype(cdtype)
        return planes

    def slab(j_sub: int) -> jnp.ndarray:
        """Halo sub-block ``j_sub`` of the haloed z extent, assembled
        in-plane from the per-axis rim/body views and cropped to the
        tile's working extent."""
        if j_sub == 0:
            zi, zsl = 0, slice(None)
        elif j_sub <= kz:
            zi, zsl = 1, slice((j_sub - 1) * halo, j_sub * halo)
        else:
            zi, zsl = 2, slice(None)
        rows = []
        for yi in range(nyk):
            cells = [view(zi, yi, xi)[zsl] for xi in range(nxk)]
            rows.append(cells[0] if nxk == 1
                        else jnp.concatenate(cells, axis=2))
        plane = rows[0] if nyk == 1 else jnp.concatenate(rows, axis=1)
        return plane[:, :sy, :sx]

    # Queue windows are per-grid-step state.  Only the tail-source slice
    # [batch, w) must be zeroed: the first shift of each queue copies it to
    # the window head, where it stands in for the planes below the strip —
    # the zero-fill edge (DESIGN.md §8.3); the rest is overwritten before
    # it is ever read.
    buf[:, batch:w] = jnp.zeros((t, w - batch) + buf.shape[2:], cdtype)

    def advance(queue: int, planes: jnp.ndarray) -> None:
        """Shift queue's window by one batch (paper's 'shifting' mode).
        Queue ``q`` holds time-``q`` planes at their narrowed extent, in
        the scratch buffer's aligned corner."""
        ny, nx = ey(queue), ex(queue)
        tail = buf[queue, batch:w, :ny, :nx]
        buf[queue, 0:2 * rad, :ny, :nx] = tail
        buf[queue, 2 * rad:w, :ny, :nx] = planes

    for n in range(sz // batch):
        z0 = n * batch
        # ---- batched enqueue of input planes [z0, z0+batch) into queue 0.
        # A batch is whole halo-sub-blocks, each living in exactly one
        # z-view; in-plane each sub-block is one rim/body/rim concat.
        chunks = [slab(j) for j in range(z0 // halo, (z0 + batch) // halo)]
        newp = (chunks[0] if len(chunks) == 1
                else jnp.concatenate(chunks, axis=0)).astype(cdtype)
        advance(0, apply_masks(newp, z0, 0))

        # ---- cascade: one batched tap application per temporal step -----
        for s in range(1, t + 1):
            p0 = z0 - s * rad            # first plane this step produces
            window = buf[s - 1, :, :ey(s - 1), :ex(s - 1)]
            planes = engine.window_step(window, batch,
                                        inplane_crops=(cy, cx))
            planes = apply_masks(planes, p0, s)
            if s < t:
                advance(s, planes)
            else:
                lo, hi = max(p0, halo), min(p0 + batch, halo + zc)
                if lo < hi:
                    body = planes[lo - p0:hi - p0]
                    body = jnp.pad(body, ((0, 0), (0, by - ey(t)),
                                          (0, bx - ex(t))))
                    out_ref[lo - halo:hi - halo] = body.astype(out_ref.dtype)


def padded_shape_3d(spec: StencilSpec, t: int, shape: tuple[int, int, int],
                    *, zc: int = 16, ty: int | None = None,
                    tx: int | None = None) -> tuple[int, int, int]:
    """Padded layout a 3-D launch uses (see ``launch_geometry_3d``)."""
    return launch_geometry_3d(spec, t, shape, zc=zc, ty=ty, tx=tx)["padded"]


@functools.partial(jax.jit, static_argnames=(
    "spec", "t", "zdim", "ydim", "xdim", "zc", "ty", "tx", "lazy_batch",
    "num_buffers", "interpret"))
def ebisu3d_padded(xpad: jnp.ndarray, spec: StencilSpec, t: int, *,
                   zdim: int, ydim: int, xdim: int, zc: int = 16,
                   ty: int | None = None, tx: int | None = None,
                   lazy_batch: int | None = None,
                   num_buffers: int | None = None,
                   interpret: bool = True) -> jnp.ndarray:
    """Padded-layout sweep: ``xpad`` is the ``padded_shape_3d`` layout with
    zeros outside the domain at the origin; returns the same layout
    (out-of-domain cells again zero — DESIGN.md §9.3)."""
    assert spec.ndim == 3
    rad = spec.radius
    zc, halo = chunk_geometry(spec, t, zc)
    ty_r, tiled_y = xy_tile(spec, t, ydim, ty)
    tx_r, tiled_x = xy_tile(spec, t, xdim, tx)
    kz = zc // halo
    batch, w, _ = stream_schedule(zc, halo, rad,
                                  lazy_batch if lazy_batch else zc)

    zp, yp, xp = xpad.shape
    assert (zp, yp, xp) == padded_shape_3d(spec, t, (zdim, ydim, xdim),
                                           zc=zc, ty=ty, tx=tx), xpad.shape
    grid = (zp // zc,
            yp // ty_r if tiled_y else 1,
            xp // tx_r if tiled_x else 1)
    nsub_z, nsub_y, nsub_x = zp // halo, yp // halo if tiled_y else 1, \
        xp // halo if tiled_x else 1

    # Per-axis view kinds: rim sub-block before the body, the body, rim
    # after.  Clamped rim ids at the domain edges deliver in-array data
    # whose strip-global coordinates are out of domain — zeroed by the
    # validity masks (DESIGN.md §8.4).
    def z_idx(kind):
        return {"top": lambda i: jnp.maximum(i * kz - 1, 0),
                "mid": lambda i: i,
                "bot": lambda i: jnp.minimum((i + 1) * kz, nsub_z - 1)}[kind]

    def plane_idx(kind, k_blocks, nsub):
        return {"top": lambda j: jnp.maximum(j * k_blocks - 1, 0),
                "mid": lambda j: j,
                "bot": lambda j: jnp.minimum((j + 1) * k_blocks,
                                             nsub - 1)}[kind]

    zkinds = ("top", "mid", "bot")
    ykinds = ("top", "mid", "bot") if tiled_y else ("mid",)
    xkinds = ("top", "mid", "bot") if tiled_x else ("mid",)
    zlen = {"top": halo, "mid": zc, "bot": halo}
    ylen = {"top": halo, "mid": ty_r if tiled_y else yp, "bot": halo}
    xlen = {"top": halo, "mid": tx_r if tiled_x else xp, "bot": halo}

    in_specs = []
    for zk in zkinds:
        fz = z_idx(zk)
        for yk in ykinds:
            fy = (plane_idx(yk, ty_r // halo, nsub_y) if tiled_y
                  else (lambda j: 0))
            for xk in xkinds:
                fx = (plane_idx(xk, tx_r // halo, nsub_x) if tiled_x
                      else (lambda k: 0))
                in_specs.append(pl.BlockSpec(
                    (zlen[zk], ylen[yk], xlen[xk]),
                    lambda i, j, k, fz=fz, fy=fy, fx=fx:
                    (fz(i), fy(j), fx(k))))

    out_block = (zc, ty_r if tiled_y else yp, tx_r if tiled_x else xp)
    out_idx = (lambda i, j, k:
               (i, j if tiled_y else 0, k if tiled_x else 0))

    kern = functools.partial(
        _stream_kernel, taps=spec.taps, t=t, rad=rad, zc=zc, halo=halo,
        batch=batch, zdim=zdim, ydim=ydim, xdim=xdim, ty=ty_r, tx=tx_r,
        nyk=len(ykinds), nxk=len(xkinds))

    # VMEM shifting windows, padded to the (8, 128) f32 lane tile when
    # lowering for real TPU — the unaligned (t, w, ydim, xdim) scratch the
    # seed allocated only works because interpret mode hides TPU tiling.
    # The interpreter keeps exact extents: its ref writes are functional
    # whole-buffer copies, so pad lanes would 4x the per-stage copy cost
    # for nothing (DESIGN.md §9.2).
    sy = ty_r + 2 * halo if tiled_y else ydim
    sx = tx_r + 2 * halo if tiled_x else xdim
    scr_y, scr_x = (sy, sx) if interpret else (_pad_to(sy, 8),
                                               _pad_to(sx, 128))
    scratch = pltpu.VMEM((t, w, scr_y, scr_x), xpad.dtype)

    params = {}
    if not interpret:
        limit = None
        if num_buffers is not None:
            scr = t * w * scr_y * scr_x * 4
            io = (zc + 2 * halo) * sy * sx * 4 + zc * out_block[1] * \
                out_block[2] * 4
            limit = min(128 << 20, max(32 << 20,
                                       2 * (scr + num_buffers * io)))
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",) * 3, vmem_limit_bytes=limit)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(out_block, out_idx),
        out_shape=jax.ShapeDtypeStruct((zp, yp, xp), xpad.dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
        **params,
    )(*([xpad] * len(in_specs)))


@functools.partial(jax.jit, static_argnames=("spec", "t", "zc", "ty", "tx",
                                             "lazy_batch", "num_buffers",
                                             "interpret", "boundary",
                                             "compute_dtype"))
def ebisu3d(x: jnp.ndarray, spec: StencilSpec, t: int, *, zc: int = 16,
            ty: int | None = None, tx: int | None = None,
            lazy_batch: int | None = None, num_buffers: int | None = None,
            interpret: bool = True, boundary=None,
            compute_dtype=None) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked steps of a 3-D ``spec`` via z-streaming.

    ``boundary`` (default: zero Dirichlet) is resolved by reduction to
    the zero-Dirichlet core — the affine closure for dirichlet(v),
    per-sweep deep-halo ghost pinning for periodic/reflect
    (``taps.with_boundary``).  ``compute_dtype`` (default float32) is the
    dtype of the padded compute buffer and the VMEM streaming windows.
    """
    assert spec.ndim == 3
    if not is_zero_dirichlet(boundary):
        check_boundary(spec.taps, boundary, t)
        return with_boundary(
            x, 3, spec.halo(t), boundary,
            lambda v: ebisu3d(v, spec, t, zc=zc, ty=ty, tx=tx,
                              lazy_batch=lazy_batch, num_buffers=num_buffers,
                              interpret=interpret,
                              compute_dtype=compute_dtype),
            taps=spec.taps, t=t)
    cdtype = jnp.dtype(compute_dtype) if compute_dtype else jnp.float32
    zdim, ydim, xdim = x.shape
    zp, yp, xp = padded_shape_3d(spec, t, x.shape, zc=zc, ty=ty, tx=tx)
    xpad = jnp.zeros((zp, yp, xp), cdtype).at[
        :zdim, :ydim, :xdim].set(x.astype(cdtype))
    out = ebisu3d_padded(xpad, spec, t, zdim=zdim, ydim=ydim, xdim=xdim,
                         zc=zc, ty=ty, tx=tx, lazy_batch=lazy_batch,
                         num_buffers=num_buffers, interpret=interpret)
    return out[:zdim, :ydim, :xdim].astype(x.dtype)
