"""EBISU-3D Pallas kernel: z-streaming with a circular multi-queue in VMEM.

This is the paper's Fig. 5/6 scheme, verbatim, on the TPU memory hierarchy:

  * Each Pallas grid step is a *device tile*: a chunk of ``zc`` output planes.
    The chunk's z-halo (``HALO = t·rad`` planes each side) comes from three
    shifted BlockSpec views (overlapped tiling in z — the redundancy cost is
    exactly the paper's ``V_SMtile`` term, Eq 9).
  * Inside the kernel, planes stream through a **circular multi-queue**: one
    ring of ``R = next_pow2(2·rad+2)`` planes per temporal step, held in VMEM
    scratch.  Ring addressing is the paper's "computing address" mode:
    ``slot = z & (R-1)`` (§4.2.2).
  * When input plane ``z`` (time 0) is enqueued, planes ``z - s·rad`` of time
    ``s`` become computable — dequeue of step ``s`` overlaps enqueue of step
    ``s+1`` ("seamless time-step transitions").
  * The final time step is written straight to the output block — lazy
    streaming's "one sync per tile": a grid step has a single pipeline
    boundary regardless of depth ``t``.

Boundary semantics: zero outside the domain at every step (planes whose
global z falls outside [0, Z) are zeroed after compute; y/x pads are re-masked
every step, so roll-based tap shifts cannot leak across the boundary).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multiqueue import MultiQueueLayout
from repro.core.stencil_spec import StencilSpec


def _taps_by_dz(taps):
    groups: dict[int, list] = {}
    for (dz, dy, dx), c in taps:
        groups.setdefault(dz, []).append(((dy, dx), c))
    return sorted(groups.items())


def _apply_plane_taps(plane: jnp.ndarray, taps2d) -> jnp.ndarray:
    acc = None
    for (dy, dx), c in taps2d:
        term = plane
        if dy:
            term = jnp.roll(term, -dy, axis=0)
        if dx:
            term = jnp.roll(term, -dx, axis=1)
        term = term * jnp.float32(c)
        acc = term if acc is None else acc + term
    return acc


def _stream_kernel(prev_ref, cur_ref, next_ref, out_ref, buf,
                   *, groups, t: int, rad: int, zc: int, halo: int,
                   ring: int, zdim: int, ydim: int, xdim: int):
    i = pl.program_id(0)
    yp, xp = cur_ref.shape[1], cur_ref.shape[2]
    mask = ring - 1

    ys = jax.lax.broadcasted_iota(jnp.int32, (yp, xp), 0)
    xs = jax.lax.broadcasted_iota(jnp.int32, (yp, xp), 1)
    valid_yx = (ys >= rad) & (ys < rad + ydim) & (xs >= rad) & (xs < rad + xdim)

    def rd(q, z):
        return buf[pl.ds(q * ring + (z & mask), 1)][0]

    def wr(q, z, plane):
        buf[pl.ds(q * ring + (z & mask), 1)] = plane[None]

    def body(zin, _):
        zg = i * zc - halo + zin           # global z of the incoming plane

        # ---- enqueue input plane zin into queue 0 (time 0) -----------------
        def fetch(ref, idx):
            return ref[pl.ds(idx, 1)][0].astype(jnp.float32)

        @pl.when(zin < halo)
        def _():
            plane = fetch(prev_ref, zin + zc - halo)
            ok = valid_yx & (zg >= 0) & (zg < zdim)
            wr(0, zin, jnp.where(ok, plane, 0.0))

        @pl.when((zin >= halo) & (zin < halo + zc))
        def _():
            plane = fetch(cur_ref, zin - halo)
            ok = valid_yx & (zg >= 0) & (zg < zdim)
            wr(0, zin, jnp.where(ok, plane, 0.0))

        @pl.when(zin >= halo + zc)
        def _():
            plane = fetch(next_ref, zin - halo - zc)
            ok = valid_yx & (zg >= 0) & (zg < zdim)
            wr(0, zin, jnp.where(ok, plane, 0.0))

        # ---- advance each deeper queue: plane zin - s·rad of time s --------
        for s in range(1, t + 1):
            z_s = zin - s * rad
            zg_s = i * zc - halo + z_s

            def compute(z_s=z_s, zg_s=zg_s, s=s):
                acc = None
                for dz, taps2d in groups:
                    contrib = _apply_plane_taps(rd(s - 1, z_s + dz), taps2d)
                    acc = contrib if acc is None else acc + contrib
                ok = valid_yx & (zg_s >= 0) & (zg_s < zdim)
                return jnp.where(ok, acc, 0.0)

            if s < t:
                @pl.when(z_s >= 0)
                def _(z_s=z_s, s=s, compute=compute):
                    wr(s, z_s, compute())
            else:
                @pl.when((z_s >= halo) & (z_s < halo + zc))
                def _(z_s=z_s, compute=compute):
                    out_ref[pl.ds(z_s - halo, 1)] = (
                        compute()[None].astype(out_ref.dtype))
        return ()

    jax.lax.fori_loop(0, zc + 2 * halo, body, ())


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("spec", "t", "zc", "interpret"))
def ebisu3d(x: jnp.ndarray, spec: StencilSpec, t: int, *, zc: int = 16,
            interpret: bool = True) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked steps of a 3-D ``spec`` via z-streaming."""
    assert spec.ndim == 3
    zdim, ydim, xdim = x.shape
    rad, halo = spec.radius, spec.halo(t)
    assert halo <= zc, f"neighbor-block halo needs t*rad={halo} <= zc={zc}"
    layout = MultiQueueLayout.make(t, rad, "computing")
    layout.check()
    ring = layout.ring

    zp = _pad_to(zdim, zc)
    yp = _pad_to(rad + ydim + rad, 8)
    xp = _pad_to(rad + xdim + rad, 128)
    xpad = jnp.zeros((zp, yp, xp), jnp.float32).at[
        :zdim, rad:rad + ydim, rad:rad + xdim].set(x.astype(jnp.float32))
    grid = zp // zc

    kern = functools.partial(
        _stream_kernel, groups=_taps_by_dz(spec.taps), t=t, rad=rad, zc=zc,
        halo=halo, ring=ring, zdim=zdim, ydim=ydim, xdim=xdim)

    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((zc, yp, xp), lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
            pl.BlockSpec((zc, yp, xp), lambda i: (i, 0, 0)),
            pl.BlockSpec((zc, yp, xp), lambda i: (jnp.minimum(i + 1, grid - 1), 0, 0)),
        ],
        out_specs=pl.BlockSpec((zc, yp, xp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((zp, yp, xp), x.dtype),
        scratch_shapes=[pltpu.VMEM((t * ring, yp, xp), jnp.float32)],
        interpret=interpret,
    )(xpad, xpad, xpad)
    return out[:zdim, rad:rad + ydim, rad:rad + xdim]
