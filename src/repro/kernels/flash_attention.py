"""Pallas TPU flash attention — the EBISU discipline applied to attention.

The dry-run roofline showed every *_4k/_32k LM cell memory-bound, dominated
by the pure-JAX chunked attention materializing its (qc × kc) score blocks to
HBM between the two dots (~half the step's byte traffic).  This kernel keeps
the query tile + running softmax statistics resident in VMEM while K/V stream
through — "one tile at a time, scale it to the scratchpad, stream the rest",
exactly the paper's §4.1/§4.3 execution model with attention scores playing
the role of the stencil's intermediate time steps:

  * grid = (batch·heads, q-chunks, kv-chunks); the kv axis is the sequential
    ("arbitrary") innermost dimension — a streaming queue;
  * VMEM scratch carries (acc, m, l) across kv steps — the circular-queue
    analogue (depth-1 ring: online softmax needs only the running state);
  * the output block is written once, on the last kv step — lazy streaming's
    one-sync-per-tile;
  * HBM traffic: q, k, v read once, o written once — no S×S materialization.

Supports causal & sliding-window masks and GQA (kv-head index_map h→h//G).
Validated in interpret mode against models/attention.dense_attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *, scale: float,
            causal: bool, window: int | None, qc: int, kc: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    q = q_ref[0].astype(jnp.float32)                 # (qc, hd)
    k = k_ref[0].astype(jnp.float32)                 # (kc, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kpos = ik * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    ok = jnp.ones((qc, kc), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m[:, :1]                                 # (qc, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l[:, :1] * corr + p.sum(axis=1, keepdims=True)
    acc[...] = acc[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m[...] = jnp.broadcast_to(m_new, m.shape)
    l[...] = jnp.broadcast_to(l_new, l.shape)

    @pl.when(ik == nk - 1)
    def _():
        o_ref[0] = (acc[...] / jnp.maximum(l[:, :1], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_chunk",
                                             "kv_chunk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           q_chunk=256, kv_chunk=512,
                           interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, Sk, KV, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qc = min(q_chunk, s)
    kc = min(kv_chunk, sk)
    assert s % qc == 0 and sk % kc == 0, (s, qc, sk, kc)
    nq, nk = s // qc, sk // kc
    scale = 1.0 / math.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, qc=qc, kc=kc, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kc, hd),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, kc, hd),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((qc, hd), jnp.float32),
                        pltpu.VMEM((qc, 128), jnp.float32),
                        pltpu.VMEM((qc, 128), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def attention_hbm_bytes(b, s, sk, h, kv, hd, bytes_per_el=2) -> int:
    """Kernel HBM traffic: q,k,v read once + o written once (per call)."""
    return bytes_per_el * (b * s * h * hd * 2 + 2 * b * sk * kv * hd)


# ------------------------------------------------------------- backward ----
def _fwd_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m, l, *,
                    scale, causal, window, qc, kc, nk):
    """Forward that also emits logsumexp (needed by the backward kernels)."""
    _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, scale=scale,
            causal=causal, window=window, qc=qc, kc=kc, nk=nk)
    ik = pl.program_id(2)

    @pl.when(ik == nk - 1)
    def _():
        lse_ref[0] = (m[:, :1] + jnp.log(jnp.maximum(l[:, :1], 1e-30))
                      ).astype(lse_ref.dtype)


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dq_ref, dk_ref, dv_ref, dq_acc, dk_acc, dv_acc, *,
                scale, causal, window, qc, kc, nq, nk):
    """dq over the kv axis and dk/dv over the q axis, one fused grid.

    Grid: (batch*heads, nq, nk) with BOTH inner axes sequential; dq for a
    q-chunk accumulates across its nk steps (written at ik == nk-1); dk/dv
    for a kv-chunk accumulate across grid wrap-around of iq — realized by
    making the kv axis the middle (parallel-ish) axis would break the acc,
    so we keep (nq outer, nk inner) and accumulate dk/dv in a scratch the
    size of ONE kv chunk, flushing by += into HBM via input_output_aliasing-
    free accumulation: dk/dv refs are indexed by ik, so each (iq, ik) step
    adds its contribution with a read-modify-write under @pl.when(iq == 0)
    initialization.
    """
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0].astype(jnp.float32)          # (qc, 1)
    delta = delta_ref[0].astype(jnp.float32)      # (qc, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kpos = ik * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    ok = jnp.ones((qc, kc), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    p = jnp.where(ok, jnp.exp(s - lse), 0.0)      # (qc, kc)

    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta) * scale                 # (qc, kc)

    # ---- dq: accumulate over ik, flush at the last kv chunk -------------
    @pl.when(ik == 0)
    def _():
        dq_acc[...] = jnp.zeros_like(dq_acc)
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)

    # ---- dk/dv: accumulate over iq into HBM blocks indexed by ik --------
    dk_c = jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dv_c = jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(iq == 0)
    def _():
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])
    dk_ref[0] += dk_c.astype(dk_ref.dtype)
    dv_ref[0] += dv_c.astype(dv_ref.dtype)
    del dk_acc, dv_acc


def flash_attention_pallas_fwd(q, k, v, *, causal, window, q_chunk,
                               kv_chunk, interpret):
    b, s, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qc, kc = min(q_chunk, s), min(kv_chunk, sk)
    nq, nk = s // qc, sk // kc
    scale = 1.0 / math.sqrt(hd)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    kern = functools.partial(_fwd_kernel_lse, scale=scale, causal=causal,
                             window=window, qc=qc, kc=kc, nk=nk)
    out, lse = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=[pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
                   pl.BlockSpec((1, qc, 1), lambda bh, iq, ik: (bh, iq, 0))],
        out_shape=[jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
                   jax.ShapeDtypeStruct((b * h, s, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((qc, hd), jnp.float32),
                        pltpu.VMEM((qc, 128), jnp.float32),
                        pltpu.VMEM((qc, 128), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out, lse


def flash_attention_pallas_bwd(q, k, v, do, out, lse, *, causal, window,
                               q_chunk, kv_chunk, interpret):
    b, s, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    qc, kc = min(q_chunk, s), min(kv_chunk, sk)
    nq, nk = s // qc, sk // kc
    scale = 1.0 / math.sqrt(hd)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, hd)
    dot = do.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    ot = out.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    delta = jnp.sum(dot.astype(jnp.float32) * ot.astype(jnp.float32),
                    axis=-1, keepdims=True)      # (b*h, s, 1)

    kern = functools.partial(_bwd_kernel, scale=scale, causal=causal,
                             window=window, qc=qc, kc=kc, nq=nq, nk=nk)
    dq, dk_h, dv_h = pl.pallas_call(
        kern,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, qc, 1), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, qc, 1), lambda bh, iq, ik: (bh, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qc, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, kc, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, sk, hd), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, sk, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((qc, hd), jnp.float32),
                        pltpu.VMEM((kc, hd), jnp.float32),
                        pltpu.VMEM((kc, hd), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)
    dq = dq.reshape(b, h, s, hd).transpose(0, 2, 1, 3).astype(q.dtype)
    # GQA: sum the per-query-head dk/dv over each kv group
    dk = dk_h.reshape(b, kv, g, sk, hd).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv_h.reshape(b, kv, g, sk, hd).sum(axis=2).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_trainable(q, k, v, causal=True, window=None,
                              q_chunk=256, kv_chunk=512, interpret=True):
    """Differentiable Pallas flash attention (fwd + bwd kernels)."""
    out, _ = flash_attention_pallas_fwd(
        q, k, v, causal=causal, window=window, q_chunk=q_chunk,
        kv_chunk=kv_chunk, interpret=interpret)
    b, s, h, hd = q.shape
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def _fa_fwd(q, k, v, causal, window, q_chunk, kv_chunk, interpret):
    out, lse = flash_attention_pallas_fwd(
        q, k, v, causal=causal, window=window, q_chunk=q_chunk,
        kv_chunk=kv_chunk, interpret=interpret)
    b, s, h, hd = q.shape
    o4 = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return o4, (q, k, v, o4, lse)


def _fa_bwd(causal, window, q_chunk, kv_chunk, interpret, res, do):
    q, k, v, o4, lse = res
    dq, dk, dv = flash_attention_pallas_bwd(
        q, k, v, do, o4, lse, causal=causal, window=window,
        q_chunk=q_chunk, kv_chunk=kv_chunk, interpret=interpret)
    return dq, dk, dv


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)
