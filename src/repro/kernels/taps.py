"""Unified slice-based tap engine — the one stencil-application core.

Every stencil application in the repo (the 2-D strip kernel, the 3-D
streamer, the sharded per-shard trapezoid chain of
``repro.api.sharded`` — DESIGN.md §12.2 — and the pure-jnp oracle) goes
through this module, so the blocked kernels and the reference they are
validated against share one numerical definition of "apply the taps"
(see DESIGN.md §8).

Semantics: *zero-fill* shifts.  ``apply_taps`` treats everything outside
the array extent as 0 — a static slice of a zero-padded buffer, never
``jnp.roll``.  No wrap-around means no per-step wrap remask: the only
masking a kernel still needs is the Dirichlet boundary of the *domain*
(which can sit strictly inside a padded strip), and that collapses to a
single {0,1} mask built once at strip assembly and applied as one
multiply per step (DESIGN.md §8.2).

Three application paths:

  * generic   — pad the tap axes once, then one static slice + FMA per
                tap.  Works for any tap set (box stencils).
  * star      — separable axis-wise accumulation: one 1-axis pad + 2·rad
                slices per axis plus the center term.  Slices stay
                contiguous along the untouched minor axes, which is both
                cheaper to move and what the VPU wants.
  * dz-grouped window — for the 3-D streamer: a *valid*-mode application
                along z over a ``B + 2·rad``-plane window producing ``B``
                planes, with zero-fill only in-plane.  Every z-slice is
                static, so the streamer's batched advance is one
                vectorized call per temporal step.

Every path also supports per-axis **valid-mode** application (``crops``):
a tap axis with ``crops[a] = c > 0`` is not zero-padded — the output
shrinks by ``c`` cells on each side and every tap reads true neighbor
values.  This is the AN5D-style trapezoid: ``chain_trapezoid`` narrows
the live region by one radius per temporal step, so step ``s`` of a
``t``-deep chain computes only the cells that can still influence the
final output (DESIGN.md §9.1) — the FLOP side of temporal blocking
shrinks with depth instead of recomputing the full haloed strip every
step.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax.numpy as jnp

Taps = Sequence[tuple[tuple[int, ...], float]]


def tap_radius(taps: Taps) -> int:
    """Largest |offset| component — the pad the generic path needs."""
    return max((max(abs(o) for o in off) for off, _ in taps), default=0)


def group_by_leading(taps: Taps):
    """Group 3-D taps by dz: ``[(dz, [((dy, dx), c), ...]), ...]`` sorted.

    The dz-grouped form is what z-streaming consumes: each group is an
    in-plane (2-D) tap set contributed by one relative input plane.
    """
    groups: dict[int, list] = {}
    for off, c in taps:
        dz, rest = off[0], tuple(off[1:])
        groups.setdefault(dz, []).append((rest, c))
    return sorted((dz, tuple(ts)) for dz, ts in groups.items())


def split_star(taps: Taps, ndim: int):
    """Split a star tap set into (center_coeff, per-axis arms).

    Returns ``None`` if any tap has more than one nonzero offset component
    (i.e. the set is not a star and the axis-wise path does not apply).
    ``arms[a]`` is a list of ``(offset, coeff)`` with offset != 0 along
    tap-axis ``a``.
    """
    center = 0.0
    arms: list[list[tuple[int, float]]] = [[] for _ in range(ndim)]
    for off, c in taps:
        nz = [i for i, o in enumerate(off) if o]
        if not nz:
            center += c
        elif len(nz) == 1:
            arms[nz[0]].append((off[nz[0]], c))
        else:
            return None
    return center, arms


def apply_taps_generic(x: jnp.ndarray, taps: Taps, ndim: int,
                       crops: Sequence[int] | None = None) -> jnp.ndarray:
    """One stencil application on the last ``ndim`` axes of ``x``.

    Pads the tap axes once by the tap radius, then realizes every tap as
    a single static slice of the padded buffer.  Leading axes of ``x``
    (e.g. a batch of planes) broadcast through untouched.

    ``crops[a] = c > 0`` switches tap-axis ``a`` to *valid* mode: no
    zero-pad, the output shrinks by ``c`` on each side, and every tap
    (``|off| ≤ c``) reads true neighbor values from ``x`` itself.
    """
    rad = tap_radius(taps)
    lead = x.ndim - ndim
    crops = tuple(crops) if crops is not None else (0,) * ndim
    for a, c in enumerate(crops):
        # a valid-mode slice with |off| > crop would wrap via a negative
        # start instead of erroring — refuse it outright
        assert c == 0 or c >= max(abs(off[a]) for off, _ in taps), (a, c)
    pad = [(0, 0)] * lead + [(0, 0) if c else (rad, rad) for c in crops]
    xp = jnp.pad(x, pad) if any(p != (0, 0) for p in pad) else x
    base = [c if c else rad for c in crops]
    out_n = [n - 2 * c for n, c in zip(x.shape[lead:], crops)]
    acc = None
    for off, c in taps:
        idx = (Ellipsis,) + tuple(
            slice(b + o, b + o + n) for b, o, n in zip(base, off, out_n))
        term = xp[idx] * jnp.asarray(c, x.dtype)
        acc = term if acc is None else acc + term
    return acc


def apply_taps_star(x: jnp.ndarray, center: float,
                    arms: Sequence[Sequence[tuple[int, float]]],
                    ndim: int,
                    crops: Sequence[int] | None = None) -> jnp.ndarray:
    """Axis-wise (separable-shape) accumulation for star tap sets.

    ``crops`` has the same valid-mode semantics as in
    ``apply_taps_generic``: cropped axes shrink and read true neighbors.
    """
    lead = x.ndim - ndim
    crops = tuple(crops) if crops is not None else (0,) * ndim

    def crop_axes(exclude: int = -1):
        idx = [slice(None)] * x.ndim
        for b, cp in enumerate(crops):
            if cp and b != exclude:
                idx[lead + b] = slice(cp, x.shape[lead + b] - cp)
        return idx

    acc = x[tuple(crop_axes())] * jnp.asarray(center, x.dtype)
    for a, axis_arms in enumerate(arms):
        if not axis_arms:
            continue
        axis = lead + a
        rad = max(abs(o) for o, _ in axis_arms)
        n = x.shape[axis]
        cp = crops[a]
        assert cp == 0 or cp >= rad, (a, cp, rad)  # see apply_taps_generic
        if cp:
            xp, base, out_a = x, cp, n - 2 * cp
        else:
            pad = [(0, 0)] * x.ndim
            pad[axis] = (rad, rad)
            xp, base, out_a = jnp.pad(x, pad), rad, n
        for off, c in axis_arms:
            idx = crop_axes(exclude=a)
            idx[axis] = slice(base + off, base + off + out_a)
            acc = acc + xp[tuple(idx)] * jnp.asarray(c, x.dtype)
    return acc


class TapEngine:
    """A tap set compiled to its cheapest application path.

    ``step(x, mask)`` applies one stencil step to the last ``ndim`` axes
    of ``x`` with zero-fill shifts, then multiplies by ``mask`` (the
    one-time Dirichlet boundary mask — pass ``None`` only when the array
    edge *is* the domain boundary on every side).
    """

    def __init__(self, taps: Taps, ndim: int):
        self.taps = tuple(taps)
        self.ndim = ndim
        self.radius = tap_radius(taps)
        self._star = split_star(taps, ndim)
        self.groups = group_by_leading(taps) if ndim == 3 else None

    def step(self, x: jnp.ndarray, mask: jnp.ndarray | None = None,
             crops: Sequence[int] | None = None):
        if self._star is not None:
            center, arms = self._star
            out = apply_taps_star(x, center, arms, self.ndim, crops)
        else:
            out = apply_taps_generic(x, self.taps, self.ndim, crops)
        return out if mask is None else out * mask

    def chain(self, x: jnp.ndarray, t: int,
              mask: jnp.ndarray | None = None) -> jnp.ndarray:
        """``t`` fused steps, intermediates carried as pure values."""
        for _ in range(t):
            x = self.step(x, mask)
        return x

    def chain_trapezoid(self, x: jnp.ndarray, t: int,
                        axes: Sequence[int] = (0,),
                        post=None) -> jnp.ndarray:
        """``t`` valid-mode steps, shrinking ``axes`` by one radius each.

        Step ``s`` computes only the ``n − 2·s·rad`` live extent along
        each narrowed tap axis — the cells whose value can still reach
        the final output — using true neighbor context instead of a
        zero-fill edge (DESIGN.md §9.1).  ``post(v, s)`` (optional) is
        applied after each step; kernels use it to re-pin the Dirichlet
        domain boundary where the strip actually meets it.

        Interior equivalence: for cells at distance ≥ ``t·rad`` from the
        narrowed edges, the result equals ``chain(x, t)`` cropped by
        ``t·rad`` along ``axes`` (boundary effects travel one radius per
        step, so those cells never see the edge).
        """
        crops = tuple(self.radius if a in axes else 0
                      for a in range(self.ndim))
        for s in range(1, t + 1):
            x = self.step(x, crops=crops)
            if post is not None:
                x = post(x, s)
        return x

    # ------------------------------------------------- 3-D streaming ----
    def window_step(self, window: jnp.ndarray, batch: int,
                    mask: jnp.ndarray | None = None,
                    inplane_crops: tuple[int, int] = (0, 0)) -> jnp.ndarray:
        """Advance one temporal step over a plane window (3-D only).

        ``window`` is ``(B + 2·rad, Y, X)`` planes of time ``s``; the
        result is the ``B`` planes of time ``s+1`` they determine
        (*valid* along z — no zero-fill; the caller's shifting buffers
        provide the z context).  In-plane shifts are zero-filled, unless
        ``inplane_crops = (cy, cx)`` requests valid-mode narrowing there
        too (XY-tiled streaming: the tile's fetched y/x halo provides
        true context and the live region shrinks one radius per step —
        DESIGN.md §9.1).  Every z-slice offset is static, so each dz
        group is one vectorized 2-D application over a ``(B, Y, X)``
        block.
        """
        assert self.groups is not None, "window_step is for 3-D tap sets"
        rad = self.radius
        assert window.shape[0] == batch + 2 * rad
        cy, cx = inplane_crops
        acc = None
        for dz, taps2d in self.groups:
            block = window[rad + dz:rad + dz + batch]
            if len(taps2d) == 1 and taps2d[0][0] == (0, 0):
                iy = slice(cy, block.shape[1] - cy) if cy else slice(None)
                ix = slice(cx, block.shape[2] - cx) if cx else slice(None)
                contrib = (block[:, iy, ix]
                           * jnp.asarray(taps2d[0][1], window.dtype))
            else:
                star = split_star(taps2d, 2)
                if star is not None:
                    contrib = apply_taps_star(block, star[0], star[1], 2,
                                              crops=(cy, cx))
                else:
                    contrib = apply_taps_generic(block, taps2d, 2,
                                                 crops=(cy, cx))
            acc = contrib if acc is None else acc + contrib
        return acc if mask is None else acc * mask


@functools.lru_cache(maxsize=None)
def engine_for(taps: Taps, ndim: int) -> TapEngine:
    """Memoized engine per (taps, ndim) — specs are hashable frozen tuples."""
    return TapEngine(taps, ndim)


# ------------------------------------------------------------ boundaries ----
# The engine's zero-fill slicing realizes exactly one boundary condition:
# zero Dirichlet.  Everything else is reduced to it here, shared by the
# Pallas kernels and the oracle (the ``Boundary`` objects handed in are
# duck-typed: anything with ``.kind``/``.value`` — see repro.api.boundary).

def is_zero_dirichlet(boundary) -> bool:
    return (boundary is None
            or (boundary.kind == "dirichlet" and boundary.value == 0.0))


def tap_sum(taps: Taps) -> float:
    """Sum of tap coefficients ``s`` — the contraction factor of the affine
    closure: one true Dirichlet(v) step satisfies ``u_1 = Z(u_0 − v) + v·s``
    exactly for ANY ``s`` (DESIGN.md §11.3)."""
    return sum(c for _, c in taps)


def check_boundary(taps: Taps, boundary, t: int | None = None) -> None:
    """Raise ``ValueError`` when a ``t``-step fused chain of ``taps``
    cannot run under ``boundary`` through the zero-Dirichlet reductions
    below (``t=None``: depth unknown — require the depth-independent
    closure).

    * dirichlet(v≠0) runs through the affine closure
      ``u_t = Z_t(u_0 − v) + v·s^t`` (``s`` = tap sum), which is exact
      iff ``s == 1`` (the classic constant shift, any depth) or ``t == 1``
      (a single step — chains of depth-1 sweeps re-apply the shift every
      sweep).  For ``s ≠ 1`` at ``t ≥ 2`` the correction term
      ``v·Σ_k s^{t-1-k}(s·Z^k(1) − Z^{k+1}(1))`` is a *field* supported on
      the ``t·rad`` boundary band, not a constant — no pre/post shift of a
      fused chain can absorb it, so we refuse with the fixes spelled out.
    * reflect needs per-axis mirror symmetry of the tap set: only then is
      the mirror extension preserved by evolution, making the one-time
      deep-halo ghost fill equivalent to re-mirroring every step.
    * neumann(flux) fills ghosts by the face-mirror ``ghost(-k) = u(k-1)
      + k·flux`` (zero normal derivative for flux = 0).  A depth-1 chain
      refills the ghosts every step — exact for ANY taps and any flux.
      Deeper fused chains fill once per sweep, which is exact only when
      the tap set is mirror-symmetric per axis (so the symmetric
      extension evolves as the mirror of the interior) AND ``flux == 0``
      (one step moves a kinked flux ramp off the ``ghost(-k) = u(k-1) +
      k·flux`` relation by ``-a·flux`` at the face for arm weight ``a``
      — no tap sum fixes it), so other combinations are refused with the
      fixes spelled out.
    """
    if is_zero_dirichlet(boundary) or boundary.kind == "periodic":
        return
    if boundary.kind == "neumann":
        if t == 1:
            return                    # ghosts refilled per step: exact
        mirror = _mirror_defect(taps)
        if mirror is not None:
            off, c, a = mirror
            raise ValueError(
                f"neumann boundary at chain depth "
                f"t={'unknown' if t is None else t} needs a "
                f"mirror-symmetric tap set (the one-fill-per-sweep "
                f"symmetric extension must evolve as the mirror of the "
                f"interior); tap {off} (coeff {c:g}) has no axis-{a} "
                "mirror.  Fix: compile with t=1 (ghosts re-pinned every "
                "step, exact for any taps), or symmetrize the taps")
        if boundary.value != 0.0:
            raise ValueError(
                f"neumann(flux={boundary.value:g}) with a fused chain "
                f"t={'unknown' if t is None else t} steps deep: the "
                "constant-flux ghost ramp is only consistent under "
                "per-step refills (one stencil application bends the "
                "ramp at the face).  Fix: compile with t=1, or use "
                "neumann() with zero flux, which is exact at any depth "
                "for mirror-symmetric taps")
        return
    if boundary.kind == "dirichlet":
        s = tap_sum(taps)
        if abs(s - 1.0) > 1e-6 and t != 1:
            raise ValueError(
                f"dirichlet({boundary.value:g}) with taps summing to "
                f"s={s:.6g}: the affine closure u_t = Z_t(u - v) + v*s^t "
                f"is exact only for s == 1 or single-step sweeps, and this "
                f"chain is t={'unknown' if t is None else t} steps deep. "
                "Fix: compile with t=1 (exact, chained per sweep), "
                "normalize the taps to sum 1 "
                "(define_stencil(..., normalize=True)), or use "
                "dirichlet(0)/periodic, which are exact for any tap sum")
        return
    if boundary.kind == "reflect":
        mirror = _mirror_defect(taps)
        if mirror is not None:
            off, c, a = mirror
            raise ValueError(
                f"reflect boundary needs a mirror-symmetric tap set; "
                f"tap {off} (coeff {c:g}) has no axis-{a} mirror")
        return
    raise ValueError(f"unknown boundary kind {boundary.kind!r}")


def _mirror_defect(taps: Taps):
    """First tap breaking per-axis mirror symmetry as ``(off, coeff,
    axis)``, or ``None`` for a symmetric set (reflect/neumann need this
    symmetry for one-fill-per-sweep ghost pinning)."""
    coeff = dict(taps)
    for off, c in taps:
        for a in range(len(off)):
            m = tuple(-o if i == a else o for i, o in enumerate(off))
            if abs(coeff.get(m, 0.0) - c) > 1e-9:
                return off, c, a
    return None


def ghost_extend(x: jnp.ndarray, ndim: int, halo: int,
                 boundary) -> jnp.ndarray:
    """Extend the last ``ndim`` axes of ``x`` by ``halo`` ghost cells per
    side, filled by the boundary rule (constant / wrap / mirror /
    flux-mirror).  Leading axes (e.g. a batch) pass through unpadded.

    neumann(flux): the face-mirror ``ghost(-k) = u(k-1) + k·flux`` per
    axis — ``jnp.pad mode='symmetric'`` plus a linear ramp of slope
    ``flux`` over the ghost distance, so the outward normal derivative
    at every domain face is ``flux`` (zero-flux insulation for the
    default 0).  Corners add the per-axis ramps (the separable
    convention the oracle tests pin down)."""
    pad = [(0, 0)] * (x.ndim - ndim) + [(halo, halo)] * ndim
    if boundary.kind == "dirichlet":
        return jnp.pad(x, pad, constant_values=boundary.value)
    if boundary.kind == "neumann":
        xe = jnp.pad(x, pad, mode="symmetric")
        if boundary.value != 0.0:
            for a in range(ndim):
                axis = x.ndim - ndim + a
                i = jnp.arange(xe.shape[axis])
                n = x.shape[axis]
                dist = jnp.maximum(jnp.maximum(halo - i, i - (halo + n - 1)),
                                   0)
                shape = [1] * xe.ndim
                shape[axis] = xe.shape[axis]
                xe = xe + (dist.astype(xe.dtype)
                           * jnp.asarray(boundary.value, xe.dtype)
                           ).reshape(shape)
        return xe
    mode = {"periodic": "wrap", "reflect": "reflect"}[boundary.kind]
    return jnp.pad(x, pad, mode=mode)


def with_boundary(x: jnp.ndarray, ndim: int, halo: int, boundary, core,
                  *, taps: Taps | None = None, t: int = 1):
    """Run ``core`` — a zero-Dirichlet ``t``-step map over the last
    ``ndim`` axes — under ``boundary``, where ``halo`` is the ``t·rad``
    reach of the chain ``core`` applies.

    dirichlet(v): the affine closure ``core(x − v) + v·s^t`` (``s`` = tap
    sum; no extra traffic at all) — the constant shift when ``s = 1``,
    exact for any ``s`` when ``t = 1`` (``check_boundary`` enforces one of
    the two; pass ``taps`` so ``s`` is known — omitting them assumes a
    normalized set).
    periodic/reflect: deep-halo ghost pinning — extend by ``halo``
    boundary-true cells, run ``core`` on the extended domain (its
    zero-fill corruption stays inside the ghost ring for ``t`` steps),
    crop the domain back out.  Caller is responsible for
    ``check_boundary`` having passed.
    """
    if is_zero_dirichlet(boundary):
        return core(x)
    if boundary.kind == "dirichlet":
        v = jnp.asarray(boundary.value, x.dtype)
        scale = tap_sum(taps) ** t if taps is not None else 1.0
        return core(x - v) + v * jnp.asarray(scale, x.dtype)
    xe = ghost_extend(x, ndim, halo, boundary)
    ye = core(xe)
    crop = (Ellipsis,) + tuple(slice(halo, halo + n)
                               for n in x.shape[x.ndim - ndim:])
    return ye[crop]
