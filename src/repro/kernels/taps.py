"""Unified slice-based tap engine — the one stencil-application core.

Every stencil application in the repo (the 2-D strip kernel, the 3-D
streamer, and the pure-jnp oracle) goes through this module, so the
blocked kernels and the reference they are validated against share one
numerical definition of "apply the taps" (see DESIGN.md §8).

Semantics: *zero-fill* shifts.  ``apply_taps`` treats everything outside
the array extent as 0 — a static slice of a zero-padded buffer, never
``jnp.roll``.  No wrap-around means no per-step wrap remask: the only
masking a kernel still needs is the Dirichlet boundary of the *domain*
(which can sit strictly inside a padded strip), and that collapses to a
single {0,1} mask built once at strip assembly and applied as one
multiply per step (DESIGN.md §8.2).

Three application paths:

  * generic   — pad the tap axes once, then one static slice + FMA per
                tap.  Works for any tap set (box stencils).
  * star      — separable axis-wise accumulation: one 1-axis pad + 2·rad
                slices per axis plus the center term.  Slices stay
                contiguous along the untouched minor axes, which is both
                cheaper to move and what the VPU wants.
  * dz-grouped window — for the 3-D streamer: a *valid*-mode application
                along z over a ``B + 2·rad``-plane window producing ``B``
                planes, with zero-fill only in-plane.  Every z-slice is
                static, so the streamer's batched advance is one
                vectorized call per temporal step.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax.numpy as jnp

Taps = Sequence[tuple[tuple[int, ...], float]]


def tap_radius(taps: Taps) -> int:
    """Largest |offset| component — the pad the generic path needs."""
    return max((max(abs(o) for o in off) for off, _ in taps), default=0)


def group_by_leading(taps: Taps):
    """Group 3-D taps by dz: ``[(dz, [((dy, dx), c), ...]), ...]`` sorted.

    The dz-grouped form is what z-streaming consumes: each group is an
    in-plane (2-D) tap set contributed by one relative input plane.
    """
    groups: dict[int, list] = {}
    for off, c in taps:
        dz, rest = off[0], tuple(off[1:])
        groups.setdefault(dz, []).append((rest, c))
    return sorted((dz, tuple(ts)) for dz, ts in groups.items())


def split_star(taps: Taps, ndim: int):
    """Split a star tap set into (center_coeff, per-axis arms).

    Returns ``None`` if any tap has more than one nonzero offset component
    (i.e. the set is not a star and the axis-wise path does not apply).
    ``arms[a]`` is a list of ``(offset, coeff)`` with offset != 0 along
    tap-axis ``a``.
    """
    center = 0.0
    arms: list[list[tuple[int, float]]] = [[] for _ in range(ndim)]
    for off, c in taps:
        nz = [i for i, o in enumerate(off) if o]
        if not nz:
            center += c
        elif len(nz) == 1:
            arms[nz[0]].append((off[nz[0]], c))
        else:
            return None
    return center, arms


def apply_taps_generic(x: jnp.ndarray, taps: Taps, ndim: int) -> jnp.ndarray:
    """One stencil application on the last ``ndim`` axes of ``x``.

    Pads the tap axes once by the tap radius, then realizes every tap as
    a single static slice of the padded buffer.  Leading axes of ``x``
    (e.g. a batch of planes) broadcast through untouched.
    """
    rad = tap_radius(taps)
    lead = x.ndim - ndim
    pad = [(0, 0)] * lead + [(rad, rad)] * ndim
    xp = jnp.pad(x, pad)
    shape = x.shape[lead:]
    acc = None
    for off, c in taps:
        idx = (Ellipsis,) + tuple(
            slice(rad + o, rad + o + n) for o, n in zip(off, shape))
        term = xp[idx] * jnp.asarray(c, x.dtype)
        acc = term if acc is None else acc + term
    return acc


def apply_taps_star(x: jnp.ndarray, center: float,
                    arms: Sequence[Sequence[tuple[int, float]]],
                    ndim: int) -> jnp.ndarray:
    """Axis-wise (separable-shape) accumulation for star tap sets."""
    acc = x * jnp.asarray(center, x.dtype)
    lead = x.ndim - ndim
    for a, axis_arms in enumerate(arms):
        if not axis_arms:
            continue
        axis = lead + a
        rad = max(abs(o) for o, _ in axis_arms)
        n = x.shape[axis]
        pad = [(0, 0)] * x.ndim
        pad[axis] = (rad, rad)
        xp = jnp.pad(x, pad)
        for off, c in axis_arms:
            idx = [slice(None)] * x.ndim
            idx[axis] = slice(rad + off, rad + off + n)
            acc = acc + xp[tuple(idx)] * jnp.asarray(c, x.dtype)
    return acc


class TapEngine:
    """A tap set compiled to its cheapest application path.

    ``step(x, mask)`` applies one stencil step to the last ``ndim`` axes
    of ``x`` with zero-fill shifts, then multiplies by ``mask`` (the
    one-time Dirichlet boundary mask — pass ``None`` only when the array
    edge *is* the domain boundary on every side).
    """

    def __init__(self, taps: Taps, ndim: int):
        self.taps = tuple(taps)
        self.ndim = ndim
        self.radius = tap_radius(taps)
        self._star = split_star(taps, ndim)
        self.groups = group_by_leading(taps) if ndim == 3 else None

    def step(self, x: jnp.ndarray, mask: jnp.ndarray | None = None):
        if self._star is not None:
            center, arms = self._star
            out = apply_taps_star(x, center, arms, self.ndim)
        else:
            out = apply_taps_generic(x, self.taps, self.ndim)
        return out if mask is None else out * mask

    def chain(self, x: jnp.ndarray, t: int,
              mask: jnp.ndarray | None = None) -> jnp.ndarray:
        """``t`` fused steps, intermediates carried as pure values."""
        for _ in range(t):
            x = self.step(x, mask)
        return x

    # ------------------------------------------------- 3-D streaming ----
    def window_step(self, window: jnp.ndarray, batch: int,
                    mask: jnp.ndarray | None = None) -> jnp.ndarray:
        """Advance one temporal step over a plane window (3-D only).

        ``window`` is ``(B + 2·rad, Y, X)`` planes of time ``s``; the
        result is the ``B`` planes of time ``s+1`` they determine
        (*valid* along z — no zero-fill; the caller's shifting buffers
        provide the z context).  In-plane shifts are zero-filled.  Every
        z-slice offset is static, so each dz group is one vectorized 2-D
        application over a ``(B, Y, X)`` block.
        """
        assert self.groups is not None, "window_step is for 3-D tap sets"
        rad = self.radius
        assert window.shape[0] == batch + 2 * rad
        acc = None
        for dz, taps2d in self.groups:
            block = window[rad + dz:rad + dz + batch]
            if len(taps2d) == 1 and taps2d[0][0] == (0, 0):
                contrib = block * jnp.asarray(taps2d[0][1], window.dtype)
            else:
                star = split_star(taps2d, 2)
                if star is not None:
                    contrib = apply_taps_star(block, star[0], star[1], 2)
                else:
                    contrib = apply_taps_generic(block, taps2d, 2)
            acc = contrib if acc is None else acc + contrib
        return acc if mask is None else acc * mask


@functools.lru_cache(maxsize=None)
def engine_for(taps: Taps, ndim: int) -> TapEngine:
    """Memoized engine per (taps, ndim) — specs are hashable frozen tuples."""
    return TapEngine(taps, ndim)
