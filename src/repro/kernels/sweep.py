"""Zero-copy multi-sweep executor: a ``T``-step simulation as one launch.

A long simulation is ``T/t`` temporally-blocked sweeps.  Driving it by
calling ``ebisu_stencil`` per sweep pays the full-domain pad, the
full-domain crop, and a jit dispatch *every* ``t`` steps — repeated
traffic the paper's whole scheme exists to avoid.  This module keeps the
field in **padded layout** across sweeps and chains all of them under
one jit:

  * pad once, crop once, dispatch once (DESIGN.md §9.3): the padded
    layout is closed under a sweep — every kernel re-zeroes its
    out-of-domain cells — so consecutive same-depth sweeps compose with
    no re-layout at all.  Only a trailing remainder sweep (``T % t ≠ 0``,
    whose smaller halo changes the strip geometry) re-lays out, once.
  * **shape-bucketed plan cache + launch cache**: §6 planning is
    memoized per (spec, 64-rounded domain, hardware) bucket, so a
    simulation loop over many near-identical domains plans once per
    bucket; the compiled runner is memoized per exact launch signature
    (shape, T, depth, …), mirroring jit's own cache.
  * **planner-true launch geometry**: each sweep runs at the widest
    device tile the §6 VMEM model says fits — the §6.4 deeper-or-wider
    rule taken to its limit (tile = whole padded domain when on-chip
    capacity allows, i.e. the Pallas grid collapses toward one step per
    sweep) — falling back to the plan's tile when it does not.
  * **buffer donation** where the backend supports it: the padded carry
    of ``run_sweeps_padded`` is donated, so XLA ping-pongs two buffers
    (`input_output_aliasing`-style) instead of allocating per sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import roofline as rl
from repro.core.planner import (EbisuPlan, fit_streaming_batch,
                                plan as make_plan, vmem_required_2d)
from repro.core.stencil_spec import StencilSpec
from repro.kernels.stencil2d import (ebisu2d_padded, padded_shape_2d,
                                     strip_geometry)
from repro.kernels.stencil3d import (_pad_to, ebisu3d_padded,
                                     padded_shape_3d, xy_tile)

_PLAN_CACHE: dict[tuple, EbisuPlan] = {}
_LAUNCH_CACHE: dict[tuple, object] = {}
_BUCKET = 64


def sweep_schedule(total_t: int, t: int) -> tuple[int, ...]:
    """Per-sweep depths covering ``total_t`` steps: full-depth sweeps plus
    one shallower remainder sweep when ``t`` does not divide ``total_t``."""
    assert total_t >= 0 and t >= 1
    q, r = divmod(total_t, t)
    return (t,) * q + ((r,) if r else ())


def _grouped(schedule: tuple[int, ...]) -> list[tuple[int, int]]:
    """Runs of equal depth: [(depth, count), ...] — one layout per run."""
    out: list[list[int]] = []
    for d in schedule:
        if out and out[-1][0] == d:
            out[-1][1] += 1
        else:
            out.append([d, 1])
    return [(d, c) for d, c in out]


def plan_bucketed(spec: StencilSpec, shape: tuple[int, ...],
                  hw: rl.HardwareModel = rl.TPU_V5E) -> EbisuPlan:
    """§6 plan memoized per (spec, 64-rounded domain, hardware)."""
    bucket = tuple(_pad_to(d, _BUCKET) for d in shape)
    key = (spec.name, bucket, hw.name)
    if key not in _PLAN_CACHE:
        _PLAN_CACHE[key] = make_plan(spec, hw, domain=bucket)
    return _PLAN_CACHE[key]


def _budget(hw: rl.HardwareModel) -> float:
    return hw.onchip_device_bytes or hw.onchip_bytes


def _sweep_tile_2d(spec: StencilSpec, t: int, shape: tuple[int, int],
                   hw: rl.HardwareModel, plan: EbisuPlan) -> int:
    """Widest strip the §6 VMEM model affords (§6.4: wider before deeper),
    halving toward the plan's tile when the whole domain does not fit."""
    height, width = shape
    halo = spec.halo(t)
    nbuf = plan.parallelism.num_buffers
    bh, _ = strip_geometry(spec, t, max(height, halo))
    floor = max(min(plan.block[0], height), halo)
    while (vmem_required_2d(spec, t, bh, width, hw.s_cell, nbuf)
           > _budget(hw) and bh // 2 >= floor):
        bh, _ = strip_geometry(spec, t, bh // 2)
    return bh


def _sweep_tile_3d(spec: StencilSpec, t: int, shape: tuple[int, int, int],
                   hw: rl.HardwareModel, plan: EbisuPlan
                   ) -> tuple[int, int | None, int | None, int]:
    """Deepest z chunk — and the streaming batch — the §6 VMEM model
    affords at the plan's xy tile.  The batch is fitted with the
    planner's own ``fit_streaming_batch``, so the executor never
    launches a configuration the shared model says does not fit: at the
    plan's own (zc, depth) the planner already proved one exists, and an
    off-plan depth too deep for the budget raises instead of silently
    over-committing on-chip memory."""
    zdim, ydim, xdim = shape
    halo = spec.halo(t)
    nbuf = plan.parallelism.num_buffers
    ty, tx = plan.block[1], plan.block[2]
    ty_r, tiled_y = xy_tile(spec, t, ydim, ty)
    tx_r, tiled_x = xy_tile(spec, t, xdim, tx)
    ny = ty_r + 2 * halo if tiled_y else ydim
    nx = tx_r + 2 * halo if tiled_x else xdim

    def fit_batch(zc_c: int) -> int | None:
        return fit_streaming_batch(spec, t, zc_c, ny, nx, hw.s_cell,
                                   nbuf, _budget(hw))

    zc = _pad_to(max(zdim, halo), halo)
    floor = min(zc, _pad_to(max(min(plan.block[0], zdim), halo), halo))
    batch = fit_batch(zc)
    while batch is None and zc > floor:
        zc = max(floor, _pad_to(zc // 2, halo))
        batch = fit_batch(zc)
    if batch is None:
        raise ValueError(
            f"{spec.name}: depth t={t} at xy tile ({ny}, {nx}) does not fit "
            f"the {hw.name} on-chip budget even at zc={zc} with a one-halo "
            f"batch — lower t toward the plan's depth ({plan.t})")
    return zc, (ty if tiled_y else None), (tx if tiled_x else None), batch


def _supports_donation() -> bool:
    return jax.default_backend() in ("tpu", "gpu")


def _build_runner(spec: StencilSpec, shape: tuple[int, ...], dtype,
                  total_t: int, depth: int, plan: EbisuPlan,
                  hw: rl.HardwareModel, mode: str, interpret: bool):
    """Compile one jitted callable running the whole sweep schedule."""
    groups = _grouped(sweep_schedule(total_t, depth))
    nbuf = plan.parallelism.num_buffers

    if spec.ndim == 2:
        height, width = shape
        cfg = {d: (_sweep_tile_2d(spec, d, shape, hw, plan),) for d, _ in groups}

        def run(x):
            v = x.astype(jnp.float32)
            for d, count in groups:
                (bh,) = cfg[d]
                hp, wp = padded_shape_2d(spec, d, bh, height, width)
                xp = jnp.zeros((hp, wp), jnp.float32
                               ).at[:height, :width].set(v)
                for _ in range(count):
                    xp = ebisu2d_padded(xp, spec, d, height=height,
                                        width=width, bh=bh, mode=mode,
                                        num_buffers=nbuf,
                                        interpret=interpret)
                v = xp[:height, :width]
            return v.astype(dtype)
    else:
        zdim, ydim, xdim = shape
        cfg = {d: _sweep_tile_3d(spec, d, shape, hw, plan)
               for d, _ in groups}

        def run(x):
            v = x.astype(jnp.float32)
            for d, count in groups:
                zc, ty, tx, batch = cfg[d]
                zp, yp, xp_ = padded_shape_3d(spec, d, shape, zc=zc,
                                              ty=ty, tx=tx)
                xp = jnp.zeros((zp, yp, xp_), jnp.float32
                               ).at[:zdim, :ydim, :xdim].set(v)
                for _ in range(count):
                    xp = ebisu3d_padded(xp, spec, d, zdim=zdim, ydim=ydim,
                                        xdim=xdim, zc=zc, ty=ty, tx=tx,
                                        lazy_batch=batch,
                                        num_buffers=nbuf,
                                        interpret=interpret)
                v = xp[:zdim, :ydim, :xdim]
            return v.astype(dtype)

    return jax.jit(run)


def run_sweeps(x: jnp.ndarray, spec: StencilSpec, total_t: int, *,
               t: int | None = None, plan: EbisuPlan | None = None,
               hw: rl.HardwareModel = rl.TPU_V5E, mode: str = "fused",
               interpret: bool | None = None) -> jnp.ndarray:
    """Apply ``total_t`` stencil steps as chained temporally-blocked sweeps.

    Per-sweep depth is ``t`` (default: the §6 plan's depth).  The whole
    schedule — including a shallower remainder sweep when ``t`` does not
    divide ``total_t`` — runs under a single cached jit in padded layout.
    """
    if spec.ndim == 2 and mode not in ("fused", "scratch"):
        raise ValueError(
            f"run_sweeps supports 2-D modes 'fused'/'scratch', got {mode!r} "
            "(use ops.ebisu_stencil for the lifted 'stream' path)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if total_t == 0:
        return x
    if plan is None:
        plan = plan_bucketed(spec, x.shape, hw)
    depth = max(1, min(t if t is not None else plan.t, total_t))
    key = (spec, x.shape, jnp.dtype(x.dtype).name, total_t, depth,
           plan.block, plan.parallelism.num_buffers, hw.name, mode,
           interpret)
    runner = _LAUNCH_CACHE.get(key)
    if runner is None:
        runner = _build_runner(spec, x.shape, x.dtype, total_t, depth,
                               plan, hw, mode, interpret)
        _LAUNCH_CACHE[key] = runner
    return runner(x)


def _padded_chain_2d(xp, spec, total_t, *, t, height, width, bh, mode,
                     num_buffers, interpret):
    assert total_t % t == 0, "padded chaining needs a uniform sweep depth"
    for _ in range(total_t // t):
        xp = ebisu2d_padded(xp, spec, t, height=height, width=width, bh=bh,
                            mode=mode, num_buffers=num_buffers,
                            interpret=interpret)
    return xp


@functools.lru_cache(maxsize=None)
def _padded_runner_2d(donate: bool):
    return jax.jit(_padded_chain_2d,
                   static_argnames=("spec", "total_t", "t", "height",
                                    "width", "bh", "mode", "num_buffers",
                                    "interpret"),
                   donate_argnums=(0,) if donate else ())


def run_sweeps_padded(xp: jnp.ndarray, spec: StencilSpec, total_t: int, *,
                      t: int, height: int, width: int, bh: int,
                      mode: str = "fused", num_buffers: int | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """Padded-layout sweep chain (2-D), ``t | total_t`` (uniform layout).

    The caller owns the padded buffer and the layout never changes, so
    the carry is donated where the backend supports it — XLA ping-pongs
    two buffers across sweeps instead of allocating per sweep
    (DESIGN.md §9.3).  The donation choice is made at call time so
    importing this module never initializes a JAX backend."""
    return _padded_runner_2d(_supports_donation())(
        xp, spec, total_t, t=t, height=height, width=width, bh=bh,
        mode=mode, num_buffers=num_buffers, interpret=interpret)
