"""Multi-sweep executor entry points — DEPRECATED shims over ``repro.api``.

The zero-copy executor itself (padded-layout chaining, §6.4
widest-tile-that-fits selection, shape-bucketed plan memoization, the
donated padded carry) lives in ``repro.api.program`` now, owned by
:class:`~repro.api.program.StencilProgram` — ``prog.run(x, T)`` is the
executor, ``prog.run_padded`` the donated uniform-depth chain.  This
module keeps the seed call surface working:

  * ``run_sweeps(x, spec, T, ...)``  →  ``compile_stencil(...).run(x, T)``
  * ``run_sweeps_padded`` / ``sweep_schedule`` / ``plan_bucketed`` —
    re-exported from ``repro.api.program``.
  * The module-global ``_PLAN_CACHE`` / ``_LAUNCH_CACHE`` dicts are gone:
    both now alias the bounded LRU :class:`ProgramCache` instances
    (hit/miss counters, ``clear()``) the front door owns.

Deprecation policy in README.md; the ``DeprecationWarning`` fires at
*call* time only (importing this module is silent), and ``benchmarks/``
drives ``repro.api`` directly rather than these shims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.program import (PLAN_CACHE, RUNNER_CACHE,  # noqa: F401
                               _grouped, _sweep_tile_2d, _sweep_tile_3d,
                               compile_stencil, deprecated_entry,
                               plan_bucketed, run_sweeps_padded,
                               sweep_schedule)
from repro.core import roofline as rl
from repro.core.planner import EbisuPlan
from repro.core.stencil_spec import StencilSpec

# Legacy aliases: the unbounded module dicts became bounded LRU caches.
_PLAN_CACHE = PLAN_CACHE
_LAUNCH_CACHE = RUNNER_CACHE


def run_sweeps(x: jnp.ndarray, spec: StencilSpec, total_t: int, *,
               t: int | None = None, plan: EbisuPlan | None = None,
               hw: rl.HardwareModel = rl.TPU_V5E, mode: str = "fused",
               interpret: bool | None = None,
               boundary=None) -> jnp.ndarray:
    """Apply ``total_t`` stencil steps as chained temporally-blocked sweeps.

    DEPRECATED shim: compile a program and call ``.run`` —

        prog = compile_stencil(spec, x.shape, t=t, hw=hw)
        y = prog.run(x, total_t)

    Per-sweep depth is ``t`` (default: the §6 plan's depth).  The whole
    schedule — including a shallower remainder sweep when ``t`` does not
    divide ``total_t`` — runs under a single cached jit.
    """
    deprecated_entry("sweep.run_sweeps", "compile_stencil(...).run")
    if spec.ndim == 2 and mode not in ("fused", "scratch"):
        raise ValueError(
            f"run_sweeps supports 2-D modes 'fused'/'scratch', got {mode!r} "
            "(use the program's apply for the lifted 'stream' path)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if total_t == 0:
        return x
    if plan is None:
        plan = plan_bucketed(spec, x.shape, hw)
    depth = max(1, min(t if t is not None else plan.t, total_t))
    prog = compile_stencil(spec, x.shape, dtype=x.dtype, t=depth, hw=hw,
                           plan=plan, mode=mode, interpret=interpret,
                           boundary=boundary)
    return prog.run(x, total_t)
