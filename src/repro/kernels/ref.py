"""Pure-jnp oracles for the stencil kernels.

Semantics: zero (Dirichlet) boundary — cells outside the domain read as 0 at
*every* time step.  ``reference(x, spec, t)`` applies ``t`` plain steps; every
temporally-blocked implementation in this repo must match it exactly (up to
dtype rounding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil_spec import StencilSpec


def _shift_zero(xp: jnp.ndarray, off, rad: int, shape) -> jnp.ndarray:
    """Slice a zero-padded array to realize a tap shift with zero fill."""
    idx = tuple(
        slice(rad + o, rad + o + n) for o, n in zip(off, shape)
    )
    return xp[idx]


def stencil_step(x: jnp.ndarray, spec: StencilSpec) -> jnp.ndarray:
    """One Jacobi step of ``spec`` with zero boundaries. Works for 2-D / 3-D."""
    rad = spec.radius
    pad = [(rad, rad)] * x.ndim
    xp = jnp.pad(x, pad)
    acc = None
    for off, c in spec.taps:
        term = jnp.asarray(c, x.dtype) * _shift_zero(xp, off, rad, x.shape)
        acc = term if acc is None else acc + term
    return acc


def reference(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """``t`` un-blocked steps — the ground truth for temporal blocking."""
    def body(_, v):
        return stencil_step(v, spec)
    return jax.lax.fori_loop(0, t, body, x) if t > 0 else x


def reference_unrolled(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """Python-loop variant (differentiable / easier to inspect)."""
    for _ in range(t):
        x = stencil_step(x, spec)
    return x
