"""Pure-jnp oracles for the stencil kernels.

Default semantics: zero (Dirichlet) boundary — cells outside the domain read
as 0 at *every* time step.  ``reference(x, spec, t)`` applies ``t`` plain
steps; every temporally-blocked implementation in this repo must match it
exactly (up to dtype rounding).

``boundary`` (a ``repro.api.boundary.Boundary``) switches the condition:
each oracle step ghost-extends the field by one stencil radius with the
boundary rule (constant / wrap / mirror) and applies the taps in valid
mode over the extension — the textbook per-step ghost-cell discretization.
The blocked kernels implement the same condition by per-*sweep* deep-halo
pinning (``taps.with_boundary``); the equivalence of the two is exactly
what the boundary tests assert.

One step is one call into the shared slice-based tap engine
(``repro.kernels.taps``) — the same engine the Pallas kernels run, so the
oracle and the blocked implementations cannot drift apart numerically
(DESIGN.md §8.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import (check_boundary, engine_for, ghost_extend,
                                is_zero_dirichlet)


def stencil_step(x: jnp.ndarray, spec: StencilSpec,
                 boundary=None) -> jnp.ndarray:
    """One Jacobi step of ``spec``. Works for 2-D / 3-D.

    Zero Dirichlet (default): the whole array is treated as domain — the
    zero-fill shifts of the tap engine realize the boundary exactly at
    the array edges.  Other boundaries: per-step ghost fill of one
    radius, taps applied in valid mode over it.
    """
    engine = engine_for(spec.taps, spec.ndim)
    if is_zero_dirichlet(boundary):
        return engine.step(x)
    # per-step ghost pinning is a depth-1 chain: exact for ANY tap sum
    # (the oracle is ground truth for unnormalized Dirichlet too)
    check_boundary(spec.taps, boundary, t=1)
    rad = spec.radius
    xe = ghost_extend(x, spec.ndim, rad, boundary)
    return engine.step(xe, crops=(rad,) * spec.ndim)


def reference(x: jnp.ndarray, spec: StencilSpec, t: int,
              boundary=None) -> jnp.ndarray:
    """``t`` un-blocked steps — the ground truth for temporal blocking."""
    def body(_, v):
        return stencil_step(v, spec, boundary)
    return jax.lax.fori_loop(0, t, body, x) if t > 0 else x


def reference_unrolled(x: jnp.ndarray, spec: StencilSpec, t: int,
                       boundary=None) -> jnp.ndarray:
    """Python-loop variant (differentiable / easier to inspect)."""
    for _ in range(t):
        x = stencil_step(x, spec, boundary)
    return x
