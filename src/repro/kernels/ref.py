"""Pure-jnp oracles for the stencil kernels.

Semantics: zero (Dirichlet) boundary — cells outside the domain read as 0 at
*every* time step.  ``reference(x, spec, t)`` applies ``t`` plain steps; every
temporally-blocked implementation in this repo must match it exactly (up to
dtype rounding).

One step is one call into the shared slice-based tap engine
(``repro.kernels.taps``) — the same engine the Pallas kernels run, so the
oracle and the blocked implementations cannot drift apart numerically
(DESIGN.md §8.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import engine_for


def stencil_step(x: jnp.ndarray, spec: StencilSpec) -> jnp.ndarray:
    """One Jacobi step of ``spec`` with zero boundaries. Works for 2-D / 3-D.

    The whole array is treated as domain: the zero-fill shifts of the tap
    engine realize the Dirichlet boundary exactly at the array edges.
    """
    return engine_for(spec.taps, spec.ndim).step(x)


def reference(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """``t`` un-blocked steps — the ground truth for temporal blocking."""
    def body(_, v):
        return stencil_step(v, spec)
    return jax.lax.fori_loop(0, t, body, x) if t > 0 else x


def reference_unrolled(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """Python-loop variant (differentiable / easier to inspect)."""
    for _ in range(t):
        x = stencil_step(x, spec)
    return x
