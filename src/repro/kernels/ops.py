"""Legacy entry points for the stencil kernels — DEPRECATED shims.

Every function here delegates to ``repro.api`` (the compile-once
``StencilProgram`` front door), which owns the single geometry/dispatch
resolution path; nothing in this module re-derives tile, grid, or pad
geometry.  New code should compile a program instead:

    from repro.api import compile_stencil
    prog = compile_stencil(spec, x.shape, t=t)
    y = prog.apply(x)            # was: ops.ebisu_stencil(x, spec, t)

Deprecation policy (README.md): these shims keep the seed signatures
working, emit a ``DeprecationWarning`` once per call site — strictly at
*call* time, never at import, so transiting this module (test
collection, introspection) stays silent — and will be removed two PR
cycles after the ``repro.api`` introduction.  ``benchmarks/`` drives
``repro.api`` directly and no longer calls these.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.api.program import (DEFAULT_BH_2D, DEFAULT_ZC_3D,  # noqa: F401
                               DEFAULT_ZC_STREAM_2D, compile_stencil,
                               deprecated_entry, resolve_geometry)
from repro.core.planner import EbisuPlan
from repro.core.roofline import TPU_V5E
from repro.core.stencil_spec import StencilSpec
from repro.kernels import ref as ref_ops


def ebisu_stencil(x: jnp.ndarray, spec: StencilSpec, t: int, *,
                  plan: EbisuPlan | None = None,
                  mode: str = "fused",
                  interpret: bool | None = None,
                  boundary=None) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked stencil steps (EBISU execution).

    DEPRECATED: compile a :class:`repro.api.StencilProgram` and call
    ``.apply``.  ``plan=None`` keeps the seed's request-default tiles
    (programs compiled through the front door resolve a §6 plan).
    """
    deprecated_entry("ops.ebisu_stencil", "compile_stencil(...).apply")
    prog = compile_stencil(spec, x.shape, dtype=x.dtype, t=t, plan=plan,
                           mode=mode, interpret=interpret,
                           boundary=boundary)
    return prog.apply(x)


def launch_geometry(spec: StencilSpec, t: int, shape: tuple[int, ...], *,
                    plan: EbisuPlan | None = None,
                    mode: str = "fused") -> dict:
    """The geometry an ``ebisu_stencil`` call with these args will launch.

    Shim over :func:`repro.api.resolve_geometry` — the sole tile/grid/pad
    resolution path.
    """
    return resolve_geometry(spec, t, tuple(shape), plan=plan, mode=mode)


def ebisu_stencil_planned(x: jnp.ndarray, spec: StencilSpec, *,
                          hw=TPU_V5E, t: int | None = None,
                          mode: str = "fused",
                          interpret: bool | None = None,
                          boundary=None):
    """Plan (t, tiles) with the §6 planner, then run. Returns (out, plan).

    DEPRECATED shim over ``compile_stencil`` — which is also where the
    seed's silent drop of ``mode`` (always-fused) and of domain-
    independent ``hw`` tweaks is fixed: both now thread through to the
    compiled program.
    """
    deprecated_entry("ops.ebisu_stencil_planned", "compile_stencil")
    prog = compile_stencil(spec, x.shape, dtype=x.dtype, t=t, hw=hw,
                           mode=mode, interpret=interpret,
                           boundary=boundary)
    return prog.apply(x), prog.plan


def naive_stencil(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """Un-blocked baseline (one global-memory round trip per step)."""
    return ref_ops.reference(x, spec, t)
