"""Public entry points for the stencil kernels.

``ebisu_stencil`` dispatches on dimensionality and picks interpret mode
automatically (Pallas-TPU lowering on TPU backends, interpreter on CPU — the
kernels are *written* for TPU BlockSpec/VMEM tiling and *validated* on CPU).

When a §6 plan is supplied, its decisions are wired all the way into the
kernels: tile height/chunk depth (``plan.block``), streaming batch
(``plan.lazy_batch``) and DMA pipeline depth (``plan.parallelism.
num_buffers``) — none of the planner's outputs are decorative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.planner import EbisuPlan, plan as make_plan
from repro.core.roofline import TPU_V5E
from repro.core.stencil_spec import StencilSpec, lift_2d_to_3d
from repro.kernels import ref as ref_ops
from repro.kernels.stencil2d import (ebisu2d, padded_shape_2d,
                                     strip_geometry)
from repro.kernels.stencil3d import ebisu3d, launch_geometry_3d


# plan-less fallback tiles (bench traffic modeling resolves the launched
# tile via launch_geometry below — these are only the request defaults)
DEFAULT_BH_2D = 128
DEFAULT_ZC_3D = 16
DEFAULT_ZC_STREAM_2D = 64


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ebisu_stencil(x: jnp.ndarray, spec: StencilSpec, t: int, *,
                  plan: EbisuPlan | None = None,
                  mode: str = "fused",
                  interpret: bool | None = None) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked stencil steps (EBISU execution)."""
    interpret = _default_interpret() if interpret is None else interpret
    lazy = plan.lazy_batch if plan is not None else None
    nbuf = plan.parallelism.num_buffers if plan is not None else None
    if spec.ndim == 2:
        if mode == "stream":
            # the paper's 2-D scheme: stream y through the multi-queue
            # (no overlapped halo along the streamed dim); the planner's
            # §6.4 tile width (plan.block[1]) tiles x with overlapped halo
            zc = (plan.block[0] if plan is not None
                  else max(DEFAULT_ZC_STREAM_2D, spec.halo(t)))
            zc = max(zc, spec.halo(t))
            tx = plan.block[1] if plan is not None else None
            y = ebisu3d(x[:, None, :], lift_2d_to_3d(spec), t, zc=zc,
                        tx=tx, lazy_batch=lazy, num_buffers=nbuf,
                        interpret=interpret)
            return y[:, 0, :]
        bh = (plan.block[0] if plan is not None
              else max(DEFAULT_BH_2D, spec.halo(t)))
        bh = max(bh, spec.halo(t))
        return ebisu2d(x, spec, t, bh=bh, mode=mode, num_buffers=nbuf,
                       interpret=interpret)
    zc = (plan.block[0] if plan is not None
          else max(DEFAULT_ZC_3D, spec.halo(t)))
    zc = max(zc, spec.halo(t))
    ty = plan.block[1] if plan is not None else None
    tx = plan.block[2] if plan is not None else None
    return ebisu3d(x, spec, t, zc=zc, ty=ty, tx=tx, lazy_batch=lazy,
                   num_buffers=nbuf, interpret=interpret)


def launch_geometry(spec: StencilSpec, t: int, shape: tuple[int, ...], *,
                    plan: EbisuPlan | None = None,
                    mode: str = "fused") -> dict:
    """The geometry an ``ebisu_stencil`` call with these args will launch.

    Resolves the same tile/grid the kernels resolve (rounding included),
    so modeled traffic is derived from the launch that actually runs —
    not from the plan-less default tile (``fetched_cells``/``body_cells``
    are the halo-exact input cells and output cells per grid step).
    """
    halo = spec.halo(t)
    if spec.ndim == 2 and mode != "stream":
        bh = plan.block[0] if plan is not None else max(DEFAULT_BH_2D, halo)
        bh, halo = strip_geometry(spec, t, max(bh, halo))
        hp, wp = padded_shape_2d(spec, t, bh, *shape)
        return dict(grid=(hp // bh,), block=(bh, shape[1]), halo=halo,
                    padded=(hp, wp),
                    fetched_cells=(bh + 2 * halo) * wp,
                    body_cells=bh * wp)
    if spec.ndim == 2:                   # stream mode: lifted 3-D geometry
        zc = plan.block[0] if plan is not None else \
            max(DEFAULT_ZC_STREAM_2D, halo)
        tx = plan.block[1] if plan is not None else None
        return launch_geometry_3d(lift_2d_to_3d(spec), t,
                                  (shape[0], 1, shape[1]),
                                  zc=max(zc, halo), tx=tx)
    zc = plan.block[0] if plan is not None else max(DEFAULT_ZC_3D, halo)
    return launch_geometry_3d(
        spec, t, shape, zc=max(zc, halo),
        ty=plan.block[1] if plan is not None else None,
        tx=plan.block[2] if plan is not None else None)


def ebisu_stencil_planned(x: jnp.ndarray, spec: StencilSpec, *,
                          hw=TPU_V5E, t: int | None = None,
                          interpret: bool | None = None):
    """Plan (t, tiles) with the §6 planner, then run. Returns (out, plan)."""
    p = make_plan(spec, hw, domain=x.shape)
    depth = t if t is not None else p.t
    return ebisu_stencil(x, spec, depth, plan=p, interpret=interpret), p


def naive_stencil(x: jnp.ndarray, spec: StencilSpec, t: int) -> jnp.ndarray:
    """Un-blocked baseline (one global-memory round trip per step)."""
    return ref_ops.reference(x, spec, t)
