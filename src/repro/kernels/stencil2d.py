"""EBISU-2D Pallas kernel: temporally-blocked strip device-tiles.

TPU mapping of the paper's 2-D scheme (§4.1, §6.3.1, §6.4.1):

  * Each Pallas grid step is a *device tile*: one full-width strip of
    ``bh`` output rows, resident in VMEM while ``t`` time steps are applied
    ("one tile at a time" — the TPU grid is sequential, so low occupancy is
    the native execution model).
  * **Halo-exact fetching**: the input is re-blocked at halo granularity.
    A grid step reads its ``bh`` body rows plus one ``halo``-row sub-block
    above and below (``HALO = t·rad``), so input traffic per strip is
    ``bh + 2·halo`` rows — not the ``3·bh`` of fetching whole neighbor
    blocks to use only their rims.  ``bh`` is rounded up to a multiple of
    ``halo`` so the rim sub-blocks are block-aligned (Pallas blocks cannot
    overlap; DESIGN.md §8.4).
  * Taps are applied by the shared slice-based engine
    (``repro.kernels.taps``): zero-fill static slices, no ``jnp.roll`` —
    no wrap-around, so the only masking left is the Dirichlet domain
    boundary, built **once** per strip and applied as a single multiply
    per step (DESIGN.md §8.1-2).
  * ``mode='fused'`` chains the ``t`` steps as pure jnp values — Mosaic
    keeps intermediates in VREGs/VMEM without explicit round-trips: the
    TPU realization of *redundant register streaming* (§4.3.3).  The
    chain is **trapezoid-narrowed** (AN5D-style): step ``s`` computes
    only the ``sh − 2·s·rad`` rows that can still influence the strip's
    output, using true neighbor context (valid-mode rows), and the
    Dirichlet row mask is re-pinned per step only when the strip
    actually meets the domain boundary — interior strips run mask-free
    (DESIGN.md §9.1).
  * ``mode='scratch'`` ping-pongs two explicit VMEM scratch buffers — the
    paper's double-buffering, i.e. lazy streaming with a single queue
    (§4.3.2); kept for the Fig-9-style ablation.

Boundary semantics: zero outside the domain at every step (the oracle's
contract).  The domain sits at rows ``[0, height)`` × cols ``[0, width)``
of the padded compute array, so the top/left Dirichlet boundaries coincide
with the zero-fill slicing edge for free; bottom/right (and the strip's
clamped rim sub-blocks at the domain edges) are zeroed by the strip mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stencil_spec import StencilSpec
from repro.kernels.taps import (check_boundary, engine_for,
                                is_zero_dirichlet, with_boundary)


def _strip_kernel(top_ref, mid_ref, bot_ref, out_ref, *scratch,
                  taps, t: int, bh: int, halo: int,
                  height: int, width: int, mode: str):
    i = pl.program_id(0)
    sh = bh + 2 * halo
    wp = mid_ref.shape[1]
    engine = engine_for(taps, 2)
    rad = engine.radius
    # compute dtype policy: the kernel computes in the dtype of the padded
    # buffer it was handed — the program layer decides that dtype
    cdtype = mid_ref.dtype

    # --- one-time Dirichlet boundary mask (DESIGN.md §8.2).  Columns need no
    # mask: the strip is cropped to the true domain width, so the zero-fill
    # slicing edge *is* the left/right Dirichlet boundary.  Rows keep a
    # (sh, 1) mask — the top/bottom domain boundary moves with the strip.
    row0 = i * bh - halo
    rows = jax.lax.broadcasted_iota(jnp.int32, (sh, 1), 0) + row0
    mask = ((rows >= 0) & (rows < height)).astype(cdtype)

    # --- assemble the haloed strip from the halo-exact views ----------------
    vals = jnp.concatenate(
        [top_ref[...], mid_ref[...], bot_ref[...]], axis=0
    )[:, :width] * mask

    def emit(body: jnp.ndarray) -> None:
        out_ref[...] = jnp.pad(body, ((0, 0), (0, wp - width))
                               ).astype(out_ref.dtype)

    if mode == "fused":
        # Trapezoid narrowing (DESIGN.md §9.1): step s computes only rows
        # [s·rad, sh − s·rad) in valid mode; after t steps exactly the bh
        # body rows remain.  The Dirichlet row boundary is re-pinned per
        # step only on strips that meet it — interior strips (the whole
        # haloed extent inside [0, height)) run mask-free.
        interior = (row0 >= 0) & (row0 + sh <= height)

        def repin(v: jnp.ndarray, s: int) -> jnp.ndarray:
            n = sh - 2 * s * rad

            def masked(u):
                rr = (jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)
                      + row0 + s * rad)
                return u * ((rr >= 0) & (rr < height)).astype(u.dtype)

            return jax.lax.cond(interior, lambda u: u, masked, v)

        emit(engine.chain_trapezoid(vals, t, axes=(0,), post=repin))
        return

    # --- 'scratch': explicit VMEM double-buffering (paper's lazy streaming /
    # double-buffer special case) --------------------------------------------
    buf0, buf1 = scratch
    buf0[...] = vals
    for s in range(t):
        src, dst = (buf0, buf1) if s % 2 == 0 else (buf1, buf0)
        dst[...] = engine.step(src[...], mask)
    final = buf1[...] if t % 2 == 1 else buf0[...]
    emit(final[halo:halo + bh, :])


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def strip_geometry(spec: StencilSpec, t: int, bh: int) -> tuple[int, int]:
    """Resolve the (bh, halo) a 2-D launch will actually use.

    ``bh`` is raised to at least one halo and rounded up to a multiple of
    ``halo`` so the rim sub-blocks of the halo-exact fetch are aligned.
    """
    halo = spec.halo(t)
    bh = max(bh, halo)
    return _pad_to(bh, halo), halo


def input_rows_per_strip(spec: StencilSpec, t: int, bh: int) -> tuple[int, int]:
    """Modeled input traffic: (rows fetched per strip, strip body rows).

    The halo-exact BlockSpecs fetch exactly ``bh + 2·halo`` rows per
    ``bh``-row strip, i.e. each input element is read at most
    ``1 + 2·halo/bh`` times per sweep of ``t`` steps.
    """
    bh, halo = strip_geometry(spec, t, bh)
    return bh + 2 * halo, bh


def padded_shape_2d(spec: StencilSpec, t: int, bh: int,
                    height: int, width: int) -> tuple[int, int]:
    """Padded layout a 2-D launch uses: rows to a strip multiple, cols to 128."""
    bh, _ = strip_geometry(spec, t, bh)
    return _pad_to(height, bh), _pad_to(width, 128)


@functools.partial(jax.jit, static_argnames=("spec", "t", "height", "width",
                                             "bh", "mode", "num_buffers",
                                             "interpret"))
def ebisu2d_padded(xp: jnp.ndarray, spec: StencilSpec, t: int, *,
                   height: int, width: int, bh: int = 128,
                   mode: str = "fused", num_buffers: int | None = None,
                   interpret: bool = True) -> jnp.ndarray:
    """Padded-layout sweep: ``xp`` is ``(hp, wp)`` with zeros outside the
    ``height × width`` domain at the origin; returns the same layout
    (out-of-domain cells again zero — DESIGN.md §9.3).  This is the
    multi-sweep executor's hot path: chaining sweeps through it pays no
    per-sweep pad/crop."""
    assert spec.ndim == 2
    bh, halo = strip_geometry(spec, t, bh)
    sh = bh + 2 * halo
    k = bh // halo                      # halo sub-blocks per strip body

    hp, wp = xp.shape
    assert hp % bh == 0 and wp % 128 == 0, (xp.shape, bh)
    grid = hp // bh
    nsub = hp // halo

    # Halo-exact index maps: the rim views are (halo, wp) sub-blocks — the
    # last sub-block of strip i-1 and the first of strip i+1.  Clamped ids at
    # the domain edges deliver garbage rows that the strip mask zeroes.
    def idx_top(i):
        return (jnp.maximum(i * k - 1, 0), 0)

    def idx_mid(i):
        return (i, 0)

    def idx_bot(i):
        return (jnp.minimum((i + 1) * k, nsub - 1), 0)

    kern = functools.partial(
        _strip_kernel, taps=spec.taps, t=t, bh=bh, halo=halo,
        height=height, width=width, mode=mode)

    scratch_shapes = []
    if mode == "scratch":
        scratch_shapes = [pltpu.VMEM((sh, width), xp.dtype),
                          pltpu.VMEM((sh, width), xp.dtype)]

    # §6.1 wiring: grid steps are independent ⇒ 'parallel' semantics; the
    # planner's num_buffers (DMA pipeline depth) sizes the VMEM budget hint.
    params = {}
    if not interpret:
        io_bytes = (sh + bh) * wp * 4
        limit = None
        if num_buffers is not None:
            scr = 2 * sh * wp * 4 if mode == "scratch" else 0
            limit = min(128 << 20, max(32 << 20,
                                       2 * (scr + num_buffers * io_bytes)))
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",), vmem_limit_bytes=limit)

    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((halo, wp), idx_top),
                  pl.BlockSpec((bh, wp), idx_mid),
                  pl.BlockSpec((halo, wp), idx_bot)],
        out_specs=pl.BlockSpec((bh, wp), idx_mid),
        out_shape=jax.ShapeDtypeStruct((hp, wp), xp.dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        **params,
    )(xp, xp, xp)


@functools.partial(jax.jit, static_argnames=("spec", "t", "bh", "mode",
                                             "num_buffers", "interpret",
                                             "boundary", "compute_dtype"))
def ebisu2d(x: jnp.ndarray, spec: StencilSpec, t: int, *, bh: int = 128,
            mode: str = "fused", num_buffers: int | None = None,
            interpret: bool = True, boundary=None,
            compute_dtype=None) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked steps of ``spec`` to a 2-D field.

    ``boundary`` (default: zero Dirichlet) is resolved by reduction to
    the zero-Dirichlet core: the affine closure for dirichlet(v),
    deep-halo ghost pinning (extend by ``t·rad`` boundary-true cells,
    sweep, crop) for periodic/reflect — see ``taps.with_boundary``.
    ``compute_dtype`` (default float32) is the dtype of the padded
    compute buffer — the result is cast back to ``x.dtype``.
    """
    assert spec.ndim == 2
    if not is_zero_dirichlet(boundary):
        check_boundary(spec.taps, boundary, t)
        return with_boundary(
            x, 2, spec.halo(t), boundary,
            lambda v: ebisu2d(v, spec, t, bh=bh, mode=mode,
                              num_buffers=num_buffers, interpret=interpret,
                              compute_dtype=compute_dtype),
            taps=spec.taps, t=t)
    cdtype = jnp.dtype(compute_dtype) if compute_dtype else jnp.float32
    height, width = x.shape
    hp, wp = padded_shape_2d(spec, t, bh, height, width)
    xp = jnp.zeros((hp, wp), cdtype).at[:height, :width].set(
        x.astype(cdtype))
    out = ebisu2d_padded(xp, spec, t, height=height, width=width, bh=bh,
                         mode=mode, num_buffers=num_buffers,
                         interpret=interpret)
    return out[:height, :width].astype(x.dtype)
