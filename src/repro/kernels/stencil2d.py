"""EBISU-2D Pallas kernel: temporally-blocked strip device-tiles.

TPU mapping of the paper's 2-D scheme (§4.1, §6.3.1, §6.4.1):

  * Each Pallas grid step is a *device tile*: one full-width strip of
    ``bh`` output rows, resident in VMEM while ``t`` time steps are applied
    ("one tile at a time" — the TPU grid is sequential, so low occupancy is
    the native execution model).
  * The strip's y-halo (``HALO = t·rad`` rows on each side) is assembled from
    three shifted BlockSpec views of the input (blocks i-1, i, i+1) — Pallas
    blocks cannot overlap, so neighbor views stand in for overlapped tiling.
  * ``mode='fused'`` chains the ``t`` steps as pure jnp values — Mosaic keeps
    intermediates in VREGs/VMEM without explicit round-trips: the TPU
    realization of *redundant register streaming* (§4.3.3).
  * ``mode='scratch'`` ping-pongs two explicit VMEM scratch buffers — the
    paper's double-buffering, i.e. lazy streaming with a single queue
    (§4.3.2); kept for the Fig-9-style ablation.

Boundary semantics: zero outside the domain at every step.  The kernel
re-applies an iota mask (global row/col ids) after assembly and after every
fused step, so wrap-around garbage from the roll-based tap shifts stays
confined to rows that can never reach the output (see DESIGN.md §8.1-2).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stencil_spec import StencilSpec


def _apply_taps_2d(vals: jnp.ndarray, taps) -> jnp.ndarray:
    """One stencil step on a (SH, Wp) strip using roll-based shifts."""
    acc = None
    for (dy, dx), c in taps:
        term = vals
        if dy:
            term = jnp.roll(term, -dy, axis=0)
        if dx:
            term = jnp.roll(term, -dx, axis=1)
        term = term * jnp.float32(c)
        acc = term if acc is None else acc + term
    return acc


def _strip_kernel(prev_ref, cur_ref, next_ref, out_ref, *scratch,
                  taps: Sequence, t: int, rad: int, bh: int, halo: int,
                  height: int, width: int, mode: str):
    i = pl.program_id(0)
    sh = bh + 2 * halo

    row0 = i * bh - halo
    rows = jax.lax.broadcasted_iota(jnp.int32, (sh, prev_ref.shape[1]), 0) + row0
    cols = jax.lax.broadcasted_iota(jnp.int32, (sh, prev_ref.shape[1]), 1)
    valid = (rows >= 0) & (rows < height) & (cols >= rad) & (cols < rad + width)

    # --- assemble the haloed strip from the three neighbor views ------------
    top = prev_ref[bh - halo:, :] if halo else None
    mid = cur_ref[...]
    bot = next_ref[:halo, :] if halo else None
    parts = [p for p in (top, mid, bot) if p is not None]
    vals = jnp.concatenate(parts, axis=0) if len(parts) > 1 else mid
    vals = jnp.where(valid, vals.astype(jnp.float32), 0.0)

    if mode == "fused":
        for _ in range(t):
            vals = jnp.where(valid, _apply_taps_2d(vals, taps), 0.0)
        out_ref[...] = vals[halo:halo + bh, :].astype(out_ref.dtype)
        return

    # --- 'scratch': explicit VMEM double-buffering (paper's lazy streaming /
    # double-buffer special case) --------------------------------------------
    buf0, buf1 = scratch
    buf0[...] = vals
    for s in range(t):
        src, dst = (buf0, buf1) if s % 2 == 0 else (buf1, buf0)
        dst[...] = jnp.where(valid, _apply_taps_2d(src[...], taps), 0.0)
    final = buf1 if t % 2 == 1 else buf0
    out_ref[...] = final[halo:halo + bh, :].astype(out_ref.dtype)


def _pad_to(n: int, m: int) -> int:
    return (n + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("spec", "t", "bh", "mode",
                                             "interpret"))
def ebisu2d(x: jnp.ndarray, spec: StencilSpec, t: int, *, bh: int = 128,
            mode: str = "fused", interpret: bool = True) -> jnp.ndarray:
    """Apply ``t`` temporally-blocked steps of ``spec`` to a 2-D field."""
    assert spec.ndim == 2
    height, width = x.shape
    rad, halo = spec.radius, spec.halo(t)
    assert halo <= bh, f"neighbor-block halo needs t*rad={halo} <= bh={bh}"

    hp = _pad_to(height, bh)
    wp = _pad_to(rad + width + rad, 128)
    xp = jnp.zeros((hp, wp), jnp.float32).at[:height, rad:rad + width].set(
        x.astype(jnp.float32))
    grid = hp // bh
    sh = bh + 2 * halo

    def idx_prev(i):
        return (jnp.maximum(i - 1, 0), 0)

    def idx_cur(i):
        return (i, 0)

    def idx_next(i):
        return (jnp.minimum(i + 1, grid - 1), 0)

    kern = functools.partial(
        _strip_kernel, taps=spec.taps, t=t, rad=rad, bh=bh, halo=halo,
        height=height, width=width, mode=mode)

    scratch_shapes = []
    if mode == "scratch":
        scratch_shapes = [pltpu.VMEM((sh, wp), jnp.float32),
                          pltpu.VMEM((sh, wp), jnp.float32)]

    out = pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[pl.BlockSpec((bh, wp), idx_prev),
                  pl.BlockSpec((bh, wp), idx_cur),
                  pl.BlockSpec((bh, wp), idx_next)],
        out_specs=pl.BlockSpec((bh, wp), idx_cur),
        out_shape=jax.ShapeDtypeStruct((hp, wp), x.dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(xp, xp, xp)
    return out[:height, rad:rad + width]
