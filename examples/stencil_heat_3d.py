"""3-D heat-equation (j3d7pt) with the EBISU streaming kernel + the
distributed deep-halo schedule — the paper's flagship 3-D case end-to-end.

Run:  PYTHONPATH=src python examples/stencil_heat_3d.py
"""
import jax.numpy as jnp

from repro.api import compile_stencil
from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import get
from repro.kernels import ref
from repro.stencils.data import init_domain

spec = get("j3d7pt")
p_tpu = plan(spec, rl.TPU_V5E)
p_a100 = plan(spec, rl.A100_FP64)
print(f"A100 plan: t={p_a100.t} tile={p_a100.block}   "
      f"TPU plan: t={p_tpu.t} tile={p_tpu.block}")
print(f"-> the paper's thesis on TPU: {p_tpu.vmem_bytes/2**20:.0f} MiB VMEM "
      f"affords t={p_tpu.t} vs the A100's t={p_a100.t}")

x = init_domain(spec, (40, 24, 32))
t = 4
y = compile_stencil(spec, x.shape, t=t, interpret=True).apply(x)
err = float(jnp.abs(y - ref.reference(x, spec, t)).max())
print(f"streaming multi-queue kernel, t={t}: maxerr={err:.2e}")
assert err < 1e-4

# total heat is conserved up to boundary outflow (sanity physics check)
assert float(y.sum()) <= float(x.sum()) + 1e-3
print("OK — 3-D heat stencil with circular multi-queue streaming.")
