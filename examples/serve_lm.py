"""Serve a small model with batched requests: prefill + greedy decode.

Exercises the full serving path (KV caches / SSM state caches, rolling SWA
windows, batched decode) for three different architecture families.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import run

for arch in ["h2o-danube-1.8b",      # dense + sliding-window cache
             "mamba2-130m",          # SSM state cache (O(1) decode)
             "granite-moe-3b-a800m"]:  # MoE routing in decode
    run(arch, batch=4, prompt_len=32, max_new=12, reduced=True)
print("OK — batched serving works across attention/SSM/MoE families.")
