"""Quickstart: the paper's technique in 30 lines.

Applies EBISU temporal blocking to the 2-D 5-point Jacobi stencil and checks
it against the step-by-step reference, then shows the §6 planner deciding
depth/tiling from the performance model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.api import compile_stencil
from repro.core.stencil_spec import get
from repro.kernels import ref
from repro.stencils.data import init_domain

spec = get("j2d5pt")

# 1. compile: the §5/§6 model decides depth + tiling for TPU v5e, once
prog = compile_stencil(spec, (512, 512))
p = prog.plan
print(f"planner: t={p.t}, tile={p.block}, ring={p.ring} "
      f"({p.addressing}), predicted {p.pp.pp_cells_per_s/1e9:.0f} GCells/s, "
      f"bottleneck={p.pp.bottleneck}")

# 2. run: t temporally-blocked steps in ONE pass over memory
x = init_domain(spec, (512, 512))
y = prog.apply(x)

# 3. trust: blocked == unblocked, exactly
want = ref.reference(x, spec, p.t)
err = float(jnp.abs(y - want).max())
print(f"EBISU t={p.t} vs {p.t} plain steps: max err = {err:.2e}")
assert err < 1e-4
print("OK — temporal blocking is semantics-preserving.")
