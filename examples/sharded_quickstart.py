"""Sharded quickstart: deep-halo temporal blocking over a device mesh.

Mirrors examples/quickstart.py on a faked 4-device CPU mesh: compile the
2-D 5-point Jacobi stencil onto a 2x2 mesh, run 24 steps with ONE ghost
exchange per 4-step temporal block, and check the result against the
single-device executor.  See docs/sharding.md for the model.

Run:  PYTHONPATH=src python examples/sharded_quickstart.py
"""
from repro.launch.mesh import ensure_fake_devices

ensure_fake_devices(4)          # must precede the first backend touch

import jax.numpy as jnp

from repro.api import (compile_stencil, count_ppermutes,
                       planned_exchange_rounds)
from repro.api.sharded import build_sharded_runner
from repro.core.stencil_spec import get
from repro.stencils.data import init_domain

spec = get("j2d5pt")
shape, t, total = (128, 128), 4, 24

# 1. compile onto a 2x2 mesh: dim 0 and dim 1 each split across 2 devices;
#    the §6 planner plans for ONE SHARD (64x64 plus its t*rad block halo)
prog = compile_stencil(spec, shape, t=t, mesh=(2, 2))
print(f"program: {prog!r}")

# 2. run: 24 steps = 6 temporal blocks = 6 deep-halo exchange rounds
#    (the per-step scheme would exchange 24 times for the same bytes)
x = init_domain(spec, shape)
y = prog.run_sharded(x, total)
rounds = planned_exchange_rounds(total, prog.t)
print(f"T={total} at t={prog.t}: {rounds} exchange rounds "
      f"(vs {total} per-step)")

# 3. the count is real, not aspirational: count ppermutes in the trace
n = count_ppermutes(build_sharded_runner(prog, total), x)
assert n == rounds * 2 * 2, n          # 2 directions x 2 sharded axes
print(f"traced collectives: {n} ppermutes == {rounds} rounds x 2 dirs "
      f"x 2 axes")

# 4. trust: sharded == the single-device zero-copy executor, exactly
single = compile_stencil(spec, shape, t=t)
err = float(jnp.abs(y - single.run(x, total)).max())
print(f"sharded vs single-device run: max err = {err:.2e}")
assert err < 1e-5
print("OK — deep-halo sharding is semantics-preserving.")
