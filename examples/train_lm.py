"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the real framework path: config -> mesh -> sharded params/optimizer ->
prefetching data pipeline -> jitted train_step -> async checkpoints ->
resume.  On CPU this runs a genuinely ~100M model (mamba2-130m at full size
but short sequences) — pass --tiny for a seconds-long smoke.

Run:  PYTHONPATH=src python examples/train_lm.py [--tiny]
"""
import argparse
import dataclasses
import tempfile

import repro.configs as C
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config, 40 steps (CI-speed)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        if args.tiny:
            params, state, losses = train(
                "mamba2-130m", steps=args.steps or 40, batch=8, seq=64,
                reduced=True, ckpt_dir=d, ckpt_every=20, lr=1e-2)
        else:
            # full mamba2-130m (130M params) — a few hundred steps
            params, state, losses = train(
                "mamba2-130m", steps=args.steps or 200, batch=4, seq=256,
                reduced=False, ckpt_dir=d, ckpt_every=100, lr=3e-4)
        drop = losses[0] - losses[-1]
        print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} (drop {drop:.3f})")
        assert drop > 0.05, "training did not reduce loss"
        print("OK — end-to-end training works (with async checkpoints).")


if __name__ == "__main__":
    main()
