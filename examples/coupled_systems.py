"""Coupled multi-field systems in 40 lines.

Defines a Gray–Scott reaction-diffusion system, compiles it to ONE
fused cross-field trapezoid chain, runs it under an insulating
(zero-flux neumann) boundary, and checks the fused chain against the
per-field-per-step lockstep reference.  Guide: docs/systems.md.

Run:  PYTHONPATH=src python examples/coupled_systems.py
"""
import numpy as np
import jax.numpy as jnp

from repro.api import Boundary
from repro.systems import compile_system, get_system, system_names

print(f"shipped systems: {system_names()}")

# 1. the spec: two fields, per-field diffusion couplings, a registered
#    pointwise reaction — same open definition layer, lifted
spec = get_system("gray-scott", F=0.035, k=0.065)
print(f"spec: {spec!r}")
print(f"cost: {spec.flops_per_cell:.0f} flops/cell "
      f"({spec.per_field_flops()}), a_gm={spec.a_gm}")

# 2. compile once: all fields advance inside one fused jitted program,
#    4 temporal steps per sweep; the zero-flux neumann ring is
#    re-pinned every step inside the same jit (exact at any depth)
prog = compile_system(spec, (96, 96), t=4, boundary=Boundary.neumann())
print(f"program: {prog!r}")

# 3. seed: uniform u with a square v perturbation (the classic setup)
rng = np.random.default_rng(0)
u0 = jnp.asarray(np.full((96, 96), 0.9, np.float32))
v0 = np.zeros((96, 96), np.float32)
v0[40:56, 40:56] = 0.25 + 0.05 * rng.random((16, 16), np.float32)
fields = {"u": u0, "v": jnp.asarray(v0)}

# 4. run 24 steps = 6 fused sweeps (vs 48 lockstep dispatches)
out = prog.run(fields, 24)

# 5. trust: fused chain == per-field-per-step lockstep, exactly
ref = prog.run_lockstep(fields, 24)
err = max(float(jnp.abs(out[f] - ref[f]).max()) for f in spec.fields)
print(f"fused chain vs lockstep after 24 steps: max err = {err:.2e}")
assert err < 2e-5
assert all(bool(jnp.isfinite(out[f]).all()) for f in spec.fields)
print(f"u in [{float(out['u'].min()):.3f}, {float(out['u'].max()):.3f}], "
      f"v in [{float(out['v'].min()):.3f}, {float(out['v'].max()):.3f}]")
print("OK — temporal blocking spans the coupling, not just one field.")
