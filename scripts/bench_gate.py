#!/usr/bin/env python
"""Bench regression gate: newest BENCH_kernels.json entry vs the previous.

Fails (exit 1) when any row present in both entries regressed by more
than ``--max-regress`` (default 15%) in wall time.  New rows (no
predecessor) and removed rows are reported but never fail the gate —
the trajectory may legitimately add or drop rows across PRs.

Machine-load normalization: kernel rows carry ``naive_us=`` in their
derived column — the wall time of the UNTOUCHED naive reference on the
same run.  Nobody optimizes the naive loop, so when its time moves
between two entries the machine moved, not the code.  The gate divides
each new row's wall time by the median ``new naive / old naive`` ratio
before applying the threshold (and prints the factor it used), so a
slow CI box doesn't fail healthy kernels and a fast one doesn't hide a
real regression.  Entries without ``naive_us=`` rows gate unnormalized.

A second, load-IMMUNE gate runs alongside: rows carrying
``analytic_bytes=`` (HBM bytes per step counted from the lowered HLO by
``repro.tuning.analytic``) are compared raw with ``--max-traffic-regress``
(default 10%) — byte counts are deterministic, so this gate catches a
traffic regression even when wall time is hopelessly load-contaminated
(the PR 5 +17% false flag could not have confused it).

Opt-in from the tier-1 gate:  ``bash scripts/tier1.sh --bench-gate``
(run ``PYTHONPATH=src python -m benchmarks.run --only kernels`` first to
append a fresh entry; CPU-interpret wall times are noisy, so the gate is
advisory rather than part of the default tier-1 bar).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _derived_field(row: dict, field: str) -> float | None:
    """Pull a numeric ``field=`` out of a row's pipe-separated derived
    column (``naive_us=123|analytic_bytes=456|...``)."""
    for part in str(row.get("derived", "")).split("|"):
        if part.startswith(field + "="):
            try:
                return float(part.split("=", 1)[1])
            except ValueError:
                return None
    return None


def _naive_us(row: dict) -> float | None:
    """The naive-reference control time (machine-load normalization)."""
    return _derived_field(row, "naive_us")


def _analytic_bytes(row: dict) -> float | None:
    """The lowered-HLO bytes-per-step column (``repro.tuning.analytic``)
    — deterministic, so it gates UNNORMALIZED: any growth is the code,
    never the machine."""
    return _derived_field(row, "analytic_bytes")


def load_factor(prev_rows: dict, new_rows: dict) -> tuple[float, int]:
    """Median new/old ratio of the naive-reference control across rows
    present in both entries; ``(1.0, 0)`` when no row carries one."""
    ratios = sorted(
        _naive_us(new_rows[name]) / _naive_us(prev_rows[name])
        for name in prev_rows
        if name in new_rows
        and _naive_us(prev_rows[name]) and _naive_us(new_rows[name]))
    if not ratios:
        return 1.0, 0
    mid = len(ratios) // 2
    med = (ratios[mid] if len(ratios) % 2
           else 0.5 * (ratios[mid - 1] + ratios[mid]))
    return med, len(ratios)


def traffic_gate(prev_rows: dict, new_rows: dict,
                 max_regress: float) -> int:
    """The load-immune half of the gate: per-row ``analytic_bytes=``
    (lowered-HLO HBM bytes per step, ``repro.tuning.analytic``) compared
    raw — byte counts are deterministic, so no normalization applies and
    a slow CI box can neither fail a healthy kernel nor hide a real
    traffic regression.  Rows without the field are skipped."""
    pairs = [(name, _analytic_bytes(prev_rows[name]),
              _analytic_bytes(new_rows[name]))
             for name in sorted(prev_rows) if name in new_rows]
    pairs = [(n, o, w) for n, o, w in pairs if o and w is not None]
    if not pairs:
        print("bench-gate: no analytic_bytes= rows in both entries — "
              "traffic gate skipped")
        return 0
    print(f"bench-gate: analytic-traffic gate over {len(pairs)} row"
          f"{'s' if len(pairs) != 1 else ''}, max growth "
          f"{max_regress:.0%} (unnormalized — bytes are deterministic)")
    status = 0
    for name, old_b, new_b in pairs:
        rel = new_b / old_b - 1.0
        verdict = "OK"
        if rel > max_regress:
            verdict = "FAIL"
            status = 1
        print(f"  {name:24s} {old_b:14.0f}B -> {new_b:14.0f}B "
              f"({rel:+.1%})  {verdict}")
    return status


def gate(path: str, max_regress: float,
         max_traffic_regress: float = 0.10) -> int:
    try:
        with open(path) as f:
            entries = json.load(f).get("entries", [])
    except (OSError, ValueError) as e:
        print(f"bench-gate: cannot read {path}: {e}")
        return 1
    if len(entries) < 2:
        print(f"bench-gate: {len(entries)} entr{'y' if len(entries) == 1 else 'ies'} "
              f"in {os.path.basename(path)} — nothing to compare, OK")
        return 0
    prev, new = entries[-2], entries[-1]
    print(f"bench-gate: {prev.get('rev', '?')} "
          f"({prev.get('timestamp', '?')}) -> "
          f"{new.get('rev', '?')} ({new.get('timestamp', '?')}), "
          f"max regression {max_regress:.0%}")
    prev_rows, new_rows = prev.get("rows"), new.get("rows")
    if not isinstance(prev_rows, dict) or not isinstance(new_rows, dict):
        # a hand-edited or truncated baseline entry: warn, don't crash —
        # an advisory gate that dies on its own input is worse than no gate
        print("bench-gate: WARNING — entry without a 'rows' table "
              f"({'previous' if not isinstance(prev_rows, dict) else 'new'}); "
              "nothing to compare, OK")
        return 0
    load, n_controls = load_factor(prev_rows, new_rows)
    if n_controls:
        print(f"bench-gate: machine-load factor {load:.3f} from "
              f"{n_controls} naive-reference control row"
              f"{'s' if n_controls != 1 else ''} — new wall times are "
              "divided by it before the threshold")
    else:
        print("bench-gate: no naive_us= control rows in both entries — "
              "gating on raw wall time")
    status = 0
    for name, row in sorted(prev_rows.items()):
        if name not in new_rows:
            was = row.get("us_per_call")
            print(f"  {name:24s} removed"
                  + (f" (was {float(was):.1f}us)" if was is not None else ""))
            continue
        old_us, new_raw = (row.get("us_per_call"),
                           new_rows[name].get("us_per_call"))
        if old_us is None or new_raw is None:
            print(f"  {name:24s} WARNING — row missing us_per_call in "
                  f"{'baseline' if old_us is None else 'new'} entry; "
                  "skipped")
            continue
        old_us = float(old_us)
        new_us = float(new_raw) / load
        rel = new_us / old_us - 1.0 if old_us else 0.0
        verdict = "OK"
        if rel > max_regress:
            verdict = "FAIL"
            status = 1
        print(f"  {name:24s} {old_us:9.1f}us -> {new_us:9.1f}us "
              f"({rel:+.1%})  {verdict}")
    for name in sorted(set(new_rows) - set(prev_rows)):
        us = new_rows[name].get("us_per_call")
        print(f"  {name:24s} new row"
              + (f" ({float(us):.1f}us)" if us is not None else ""))
    status |= traffic_gate(prev_rows, new_rows, max_traffic_regress)
    print("bench-gate: " + ("FAIL — regression beyond threshold"
                            if status else "OK"))
    return status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default=os.path.join(_ROOT, "BENCH_kernels.json"))
    ap.add_argument("--max-regress", type=float, default=0.15,
                    help="allowed fractional wall-time growth per row")
    ap.add_argument("--max-traffic-regress", type=float, default=0.10,
                    help="allowed fractional growth of a row's "
                         "analytic_bytes= (lowered-HLO traffic; "
                         "deterministic, gated unnormalized)")
    args = ap.parse_args(argv)
    return gate(args.file, args.max_regress, args.max_traffic_regress)


if __name__ == "__main__":
    sys.exit(main())
