#!/usr/bin/env python
"""Bench regression gate: newest BENCH_kernels.json entry vs the previous.

Fails (exit 1) when any row present in both entries regressed by more
than ``--max-regress`` (default 15%) in wall time.  New rows (no
predecessor) and removed rows are reported but never fail the gate —
the trajectory may legitimately add or drop rows across PRs.

Opt-in from the tier-1 gate:  ``bash scripts/tier1.sh --bench-gate``
(run ``PYTHONPATH=src python -m benchmarks.run --only kernels`` first to
append a fresh entry; CPU-interpret wall times are noisy, so the gate is
advisory rather than part of the default tier-1 bar).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def gate(path: str, max_regress: float) -> int:
    try:
        with open(path) as f:
            entries = json.load(f).get("entries", [])
    except (OSError, ValueError) as e:
        print(f"bench-gate: cannot read {path}: {e}")
        return 1
    if len(entries) < 2:
        print(f"bench-gate: {len(entries)} entr{'y' if len(entries) == 1 else 'ies'} "
              f"in {os.path.basename(path)} — nothing to compare, OK")
        return 0
    prev, new = entries[-2], entries[-1]
    print(f"bench-gate: {prev['rev']} ({prev['timestamp']}) -> "
          f"{new['rev']} ({new['timestamp']}), "
          f"max regression {max_regress:.0%}")
    status = 0
    for name, row in sorted(prev["rows"].items()):
        if name not in new["rows"]:
            print(f"  {name:24s} removed (was {row['us_per_call']:.1f}us)")
            continue
        old_us = float(row["us_per_call"])
        new_us = float(new["rows"][name]["us_per_call"])
        rel = new_us / old_us - 1.0 if old_us else 0.0
        verdict = "OK"
        if rel > max_regress:
            verdict = "FAIL"
            status = 1
        print(f"  {name:24s} {old_us:9.1f}us -> {new_us:9.1f}us "
              f"({rel:+.1%})  {verdict}")
    for name in sorted(set(new["rows"]) - set(prev["rows"])):
        print(f"  {name:24s} new row "
              f"({float(new['rows'][name]['us_per_call']):.1f}us)")
    print("bench-gate: " + ("FAIL — wall-time regression beyond threshold"
                            if status else "OK"))
    return status


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default=os.path.join(_ROOT, "BENCH_kernels.json"))
    ap.add_argument("--max-regress", type=float, default=0.15,
                    help="allowed fractional wall-time growth per row")
    args = ap.parse_args(argv)
    return gate(args.file, args.max_regress)


if __name__ == "__main__":
    sys.exit(main())
