#!/usr/bin/env bash
# Tier-1 gate: run the ROADMAP tier-1 suite.  The gate is zero-tolerance:
# ANY test failure or collection error fails the gate (the seed-failure
# allowance was retired once the LM half went green — the suite is now
# fully green, and any regression blocks merge).
#
#   bash scripts/tier1.sh [extra pytest args]
#
# The ROADMAP command is `pytest -x -q`; we drop -x and add
# --continue-on-collection-errors so one run reports the complete failure
# set instead of halting at the first.
set -uo pipefail
cd "$(dirname "$0")/.."

# Opt-in bench regression gate: `bash scripts/tier1.sh --bench-gate [...]`
# compares the newest two BENCH_kernels.json entries after the test run.
BENCH_GATE=0
if [ "${1:-}" = "--bench-gate" ]; then
    BENCH_GATE=1
    shift
fi

# Floor on passes: catches a gate that "passes" because collection
# silently lost most of the suite.
MIN_PASSED=700

# Import hygiene: the compile-once front door answers backend questions at
# compile time — `import repro.api` must never initialize a JAX backend.
if PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import sys
import repro.api                              # must not touch a backend
try:
    from jax._src import xla_bridge           # private: probe defensively
    backends = getattr(xla_bridge, "_backends", {})
except Exception as e:                        # jax moved the internals —
    print(f"tier1: backend probe unavailable ({e!r}); check skipped")
    sys.exit(0)                               # don't misreport as a leak
if backends:
    print(f"tier1: FAIL — import repro.api initialized: {list(backends)}")
    sys.exit(1)
EOF
then
    echo "tier1: repro.api import is backend-free"
else
    echo "tier1: FAIL — import repro.api initialized a JAX backend"
    exit 1
fi

log=$(mktemp)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
    --continue-on-collection-errors "$@" 2>&1 | tee "$log" | tail -3

summary=$(grep -E '[0-9]+ (failed|passed|error)' "$log" | tail -1)
count() { echo "$summary" | grep -oE "[0-9]+ $1" | grep -oE '[0-9]+' || echo 0; }
failed=$(count failed)
passed=$(count passed)
errors=$(count "errors?")
rm -f "$log"

echo
echo "tier1: failed=$failed  passed=$passed  collection-errors=$errors (gate: 0 failed, 0 errors, >= $MIN_PASSED passed)"

status=0
[ "$failed" -gt 0 ] && { echo "tier1: FAIL — $failed test failure(s)"; status=1; }
[ "$errors" -gt 0 ] && { echo "tier1: FAIL — $errors collection error(s)"; status=1; }
[ "$passed" -lt "$MIN_PASSED" ] && { echo "tier1: FAIL — only $passed passes (< $MIN_PASSED: suite truncated?)"; status=1; }
[ "$status" -eq 0 ] && echo "tier1: OK — fully green"

if [ "$BENCH_GATE" -eq 1 ]; then
    echo
    python scripts/bench_gate.py || status=1
fi
exit "$status"
