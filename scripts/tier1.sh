#!/usr/bin/env bash
# Tier-1 gate: run the ROADMAP tier-1 suite and print the pass/fail delta
# vs the seed baseline, so "no worse than seed" is checked mechanically.
#
#   bash scripts/tier1.sh [extra pytest args]
#
# Seed baseline (PR 0): 25 failed, 165 passed, 3 collection errors.
# The ROADMAP command is `pytest -x -q`; we drop -x and add
# --continue-on-collection-errors so the counts are comparable to the
# seed numbers (with -x the run halts at the first failure and no totals
# exist to diff).
set -uo pipefail
cd "$(dirname "$0")/.."

# Opt-in bench regression gate: `bash scripts/tier1.sh --bench-gate [...]`
# compares the newest two BENCH_kernels.json entries after the test run.
BENCH_GATE=0
if [ "${1:-}" = "--bench-gate" ]; then
    BENCH_GATE=1
    shift
fi

SEED_FAILED=25
SEED_PASSED=165
SEED_ERRORS=3

# Import hygiene: the compile-once front door answers backend questions at
# compile time — `import repro.api` must never initialize a JAX backend.
if PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import sys
import repro.api                              # must not touch a backend
try:
    from jax._src import xla_bridge           # private: probe defensively
    backends = getattr(xla_bridge, "_backends", {})
except Exception as e:                        # jax moved the internals —
    print(f"tier1: backend probe unavailable ({e!r}); check skipped")
    sys.exit(0)                               # don't misreport as a leak
if backends:
    print(f"tier1: FAIL — import repro.api initialized: {list(backends)}")
    sys.exit(1)
EOF
then
    echo "tier1: repro.api import is backend-free"
else
    echo "tier1: FAIL — import repro.api initialized a JAX backend"
    exit 1
fi

log=$(mktemp)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
    --continue-on-collection-errors "$@" 2>&1 | tee "$log" | tail -3

summary=$(grep -E '[0-9]+ (failed|passed|error)' "$log" | tail -1)
count() { echo "$summary" | grep -oE "[0-9]+ $1" | grep -oE '[0-9]+' || echo 0; }
failed=$(count failed)
passed=$(count passed)
errors=$(count "errors?")
rm -f "$log"

echo
echo "tier1: failed=$failed (seed $SEED_FAILED)  passed=$passed (seed $SEED_PASSED)  collection-errors=$errors (seed $SEED_ERRORS)"

status=0
[ "$failed" -gt "$SEED_FAILED" ] && { echo "tier1: FAIL — more failures than seed"; status=1; }
[ "$errors" -gt "$SEED_ERRORS" ] && { echo "tier1: FAIL — more collection errors than seed"; status=1; }
[ "$passed" -lt "$SEED_PASSED" ] && { echo "tier1: FAIL — fewer passes than seed"; status=1; }
[ "$status" -eq 0 ] && echo "tier1: OK — no worse than seed"

if [ "$BENCH_GATE" -eq 1 ]; then
    echo
    python scripts/bench_gate.py || status=1
fi
exit "$status"
