"""Append the optimized-variant table to EXPERIMENTS.md §Perf."""
import glob, json, os

rows = []
for f in sorted(glob.glob("results/optimized/*.json")):
    r = json.load(open(f))
    if r["status"] != "ok":
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR {r.get('error','')[:60]} |||||")
        continue
    base_f = f"results/dryrun/{r['arch']}__{r['shape']}__single.json"
    b = json.load(open(base_f))
    # adjusted terms: memory from the stub program, compute/coll from baseline
    # program when only the attention stub differs; for fsdp variants the
    # whole program changed, so take all terms from the variant.
    fsdp = "fsdp" in r["mesh"]
    terms = dict(r["terms"])
    if not fsdp:
        terms["compute_s"] = b["terms"]["compute_s"]
        terms["collective_s"] = b["terms"]["collective_s"]
    step = max(terms.values())
    mf = r["model_flops"] / r["n_chips"] / 197e12
    rf = mf / step
    gain = rf / b["roofline_fraction"] if b["roofline_fraction"] else float("inf")
    scheme = ("FSDP" if fsdp else "TP") + "+flash" +         ("+SSD" if "ssmstub" in r["mesh"] else "")
    rows.append(
        f"| {r['arch']} | {r['shape']} | {scheme} | {terms['compute_s']:.2f} | "
        f"{terms['memory_s']:.2f} | {terms['collective_s']:.2f} | "
        f"**{rf:.4f}** | {b['roofline_fraction']:.4f} | {gain:.1f}× |")

table = "\n".join([
    "",
    "### Optimized-variant sweep (beyond the three scoring cells)",
    "",
    "Kernel-adjusted terms (attention boundary-stub; FSDP rows re-lowered",
    "whole-program). `gain` = optimized / baseline roofline fraction.",
    "",
    "| arch | shape | scheme | cmp s | mem s | coll s | roofline | baseline | gain |",
    "|---|---|---|---|---|---|---|---|---|",
    *rows, ""])
src = open("EXPERIMENTS.md").read()
marker = "### Stopping rule"
src = src.replace(marker, table + "\n" + marker)
open("EXPERIMENTS.md", "w").write(src)
print(f"appended {len(rows)} optimized rows")
