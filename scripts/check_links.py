#!/usr/bin/env python
"""Markdown link check for the docs tree (CI: tier1.yml docs job).

Validates every ``[text](target)`` in docs/*.md plus the root markdown
files:

  * relative file targets must exist (resolved from the linking file);
  * ``#anchor`` fragments must match a heading in the target file,
    GitHub-slugged (lowercase, spaces->dashes, punctuation dropped);
  * http(s) links are NOT fetched (CI must not depend on the network) —
    they are only counted.

Exit 1 with a per-link report when anything is broken.

    python scripts/check_links.py          # from the repo root
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "PAPER.md", "PAPERS.md"]
DOCS = os.path.join(ROOT, "docs")

LINK_RE = re.compile(r"(?<!!)\[([^\]]+)\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown, lowercase, spaces to dashes,
    drop everything that is not a word char, dash, or space."""
    text = re.sub(r"[`*_]", "", heading).strip()
    text = re.sub(r"[^\w\- §.]", "", text, flags=re.UNICODE)
    text = re.sub(r"[ §.]+", " ", text).strip()
    return text.lower().replace(" ", "-")


def anchors_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    return {github_slug(h) for h in HEADING_RE.findall(body)}


def check_file(path: str) -> list[str]:
    errors = []
    with open(path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    rel = os.path.relpath(path, ROOT)
    for text, target in LINK_RE.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, frag = target.partition("#")
        dest = path if not base else os.path.normpath(
            os.path.join(os.path.dirname(path), base))
        if not os.path.exists(dest):
            errors.append(f"{rel}: [{text}]({target}) — missing file "
                          f"{os.path.relpath(dest, ROOT)}")
            continue
        if frag and dest.endswith(".md"):
            got = anchors_of(dest)
            if frag not in got:
                close = [a for a in got if frag.split("-")[0] in a][:3]
                errors.append(
                    f"{rel}: [{text}]({target}) — no heading for "
                    f"#{frag}" + (f" (near: {close})" if close else ""))
    return errors


def main() -> int:
    files = [os.path.join(ROOT, f) for f in FILES
             if os.path.exists(os.path.join(ROOT, f))]
    if os.path.isdir(DOCS):
        files += sorted(os.path.join(DOCS, f) for f in os.listdir(DOCS)
                        if f.endswith(".md"))
    errors = []
    n_links = 0
    for path in files:
        with open(path, encoding="utf-8") as f:
            n_links += len(LINK_RE.findall(CODE_FENCE_RE.sub("", f.read())))
        errors += check_file(path)
    if errors:
        print(f"check_links: {len(errors)} broken of {n_links} links:")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"check_links: OK — {n_links} links across {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
