"""Autotune sweep over (t, tile, mode) per Table-2 spec, vs the §6 planner.

The paper's auto-tuning competitors (ARTEMIS, DRSTENCIL) search the
configuration space empirically; EBISU's planner derives it analytically.
This script runs both on reduced CPU domains: a wall-time sweep over
``(t, bh|zc, mode)`` in interpret mode, then a cross-check of the
planner's analytic pick against the sweep's best.

Usage:
    PYTHONPATH=src python scripts/autotune_stencil.py \
        [--stencil j2d5pt,j3d7pt] [--scale 64] [--depths 1,2,4,6] \
        [--json autotune.json]
    # user-defined stencils tune through the same pipeline (no registry):
    PYTHONPATH=src python scripts/autotune_stencil.py \
        --taps '[[[0,0],0.6],[[0,1],0.1],[[0,-1],0.1],[[1,0],0.1],[[-1,0],0.1]]'
    PYTHONPATH=src python scripts/autotune_stencil.py --spec-json my.json

The cross-check is advisory on CPU (interpret-mode wall time is a proxy,
not v5e time): the planner optimizes the §5 model, the sweep measures the
interpreter — agreement on *shape* (deeper-better-than-shallow, fused over
scratch) is the signal, exact tile agreement is not expected.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import dataclasses  # noqa: E402

from benchmarks.common import time_fn  # noqa: E402
from repro.api import (compile_stencil, define_stencil, parse_taps,
                       spec_from_json)
from repro.core import roofline as rl
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2, get
from repro.kernels import ref
from repro.stencils.data import init_domain, reduced_domain


def _pinned(p, spec, t: int, tile: int):
    """The §6 plan with (t, leading tile) pinned to a sweep point — the
    program front door honors an explicit plan verbatim, which is how the
    empirical search drives the same dispatch path the planner does."""
    return dataclasses.replace(
        p, t=t, halo=spec.halo(t), block=(tile,) + p.block[1:],
        lazy_batch=min(p.lazy_batch, tile))


def sweep_one(spec_or_name, scale: int, depths: list[int]):
    spec = (get(spec_or_name) if isinstance(spec_or_name, str)
            else spec_or_name)
    name = spec.name
    shape = reduced_domain(spec, scale)
    x = init_domain(spec, shape)
    p = plan(spec, rl.TPU_V5E)
    rows = []
    tiles = (64, 128, 256) if spec.ndim == 2 else (16, 32)
    modes = ("fused", "scratch") if spec.ndim == 2 else ("fused",)
    for t in sorted(set(depths) | {min(p.t, max(depths))}):
        want = ref.reference(x, spec, t)
        for tile in tiles:
            for mode in modes:
                prog = compile_stencil(spec, shape, t=t, mode=mode,
                                       interpret=True,
                                       plan=_pinned(p, spec, t, tile))
                fn = lambda: prog.apply(x)  # noqa: E731
                out = fn()
                err = float(abs(out - want).max())
                us = time_fn(fn, warmup=1, iters=3)
                rows.append({"stencil": name, "t": t, "tile": tile,
                             "mode": mode, "us": round(us, 1),
                             "us_per_step": round(us / t, 1),
                             "maxerr": err})
                assert err < 1e-4, rows[-1]
    best = min(rows, key=lambda r: r["us_per_step"])
    return {
        "stencil": name, "domain": list(shape), "sweep": rows, "best": best,
        "planner": {"t": p.t, "tile": p.block[0],
                    "lazy_batch": p.lazy_batch,
                    "pp_gcells": round(p.pp.pp_cells_per_s / 1e9, 1)},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stencil", default="all")
    ap.add_argument("--taps", default=None,
                    help="autotune a custom stencil from a JSON tap list")
    ap.add_argument("--spec-json", default=None,
                    help="autotune a custom stencil from a JSON spec file")
    ap.add_argument("--normalize", action="store_true",
                    help="rescale --taps coefficients to sum to 1")
    ap.add_argument("--scale", type=int, default=64)
    ap.add_argument("--depths", default="1,2,4")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    if args.taps or args.spec_json:
        specs = [define_stencil(parse_taps(args.taps),
                                normalize=args.normalize)
                 if args.taps else spec_from_json(args.spec_json)]
    else:
        names = (list(TABLE2) if args.stencil == "all"
                 else args.stencil.split(","))
        unknown = [n for n in names if n not in TABLE2]
        if unknown:
            ap.error(f"unknown stencil(s) {unknown}; choose from "
                     f"{list(TABLE2)} — or pass --taps/--spec-json for a "
                     "custom stencil")
        specs = [get(n) for n in names]
    depths = [int(d) for d in args.depths.split(",")]

    results = []
    for spec in specs:
        res = sweep_one(spec, args.scale, depths)
        results.append(res)
        b, p = res["best"], res["planner"]
        agree_depth = b["t"] >= max(1, p["t"] // 2) or b["t"] == max(
            r["t"] for r in res["sweep"])
        print(f"[autotune] {res['stencil']:11s} best: t={b['t']} tile={b['tile']} "
              f"mode={b['mode']} {b['us_per_step']:.0f}us/step | "
              f"planner: t={p['t']} tile={p['tile']} "
              f"lazy_batch={p['lazy_batch']} "
              f"({'depth-consistent' if agree_depth else 'DEPTH MISMATCH'})",
              flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
            f.write("\n")
        print(f"[autotune] wrote {args.json}")


if __name__ == "__main__":
    main()
