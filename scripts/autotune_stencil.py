"""DEPRECATED shim -> ``python -m repro.tuning sweep`` (docs/tuning.md).

The one-off (t, tile, mode) sweep this script used to run grew into the
``repro.tuning`` subsystem: a budgeted successive-halving search seeded
by the §6 plan's neighborhood, normalized by a naive-reference control,
pruned analytically from the lowered HLO, and persisted to a plan DB so
``compile_stencil(..., mode="tuned")`` replays winners with zero search.

Per the PR 3 shim policy (README.md), this wrapper stays for two PR
cycles: it warns once, translates the legacy flags, and delegates.

  * ``--stencil/--scale/--json/--taps/--spec-json/--normalize`` map 1:1;
  * ``--depths`` is ignored (the search derives depths from the plan's
    neighborhood instead of a user-supplied grid) — a warning says so;
  * everything else (``--db``, ``--budget``, ``--candidates``, ...)
    passes straight through to the ``sweep`` subcommand.
"""
from __future__ import annotations

import os
import sys
import warnings

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))


def main(argv=None) -> int:
    from repro.tuning.cli import main as cli_main

    warnings.warn(
        "scripts/autotune_stencil.py is deprecated; use "
        "`python -m repro.tuning sweep` (see docs/tuning.md)",
        DeprecationWarning, stacklevel=2)
    argv = list(sys.argv[1:] if argv is None else argv)
    out, i = [], 0
    while i < len(argv):
        a = argv[i]
        if a == "--depths" or a.startswith("--depths="):
            warnings.warn(
                "--depths is ignored: the measured search derives its "
                "depth candidates from the §6 plan's neighborhood",
                stacklevel=2)
            if a == "--depths":
                i += 1                      # skip the flag's value too
        else:
            out.append(a)
        i += 1
    return cli_main(["sweep", *out])


if __name__ == "__main__":
    sys.exit(main())
