"""Render the §Roofline markdown table from results/dryrun into EXPERIMENTS.md."""
import glob
import json

rows = []
for f in sorted(glob.glob("results/dryrun/*__single.json")):
    r = json.load(open(f))
    arch, shape = r["arch"], r["shape"]
    if r["status"] == "skipped":
        rows.append(f"| {arch} | {shape} | — | — | — | skip | — | — | {r['reason']} |")
        continue
    t = r["terms"]
    u = r.get("useful_flops_ratio")
    rf = r.get("roofline_fraction")
    multi = f.replace("__single", "__multi")
    try:
        mok = json.load(open(multi))["status"]
    except Exception:
        mok = "?"
    rows.append(
        f"| {arch} | {shape} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
        f"{t['collective_s']:.3f} | {r['dominant'].replace('_s','')} | "
        f"{u and round(u,2) or '—'} | **{rf:.4f}** | "
        f"{'✓' if r['hbm_ok'] else '✗ (see §Dry-run)'} /{mok[0]} |")

table = "\n".join([
    "| arch | shape | compute s | memory s | collective s | dom | useful | roofline | hbm / multi-pod |",
    "|---|---|---|---|---|---|---|---|---|",
    *rows,
])
src = open("EXPERIMENTS.md").read()
src = src.replace("<!-- ROOFLINE_TABLE -->", table)
open("EXPERIMENTS.md", "w").write(src)
print(f"inserted {len(rows)} rows")
