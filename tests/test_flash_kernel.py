"""Pallas flash-attention kernel vs the dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import dense_attention

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,h,kv,hd", [
    (2, 128, 4, 2, 64), (1, 256, 8, 8, 32), (2, 64, 4, 1, 128),
    (1, 128, 6, 2, 64),
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 48)])
def test_flash_pallas_matches_dense(b, s, h, kv, hd, causal, window):
    q = jax.random.normal(KEY, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd), jnp.float32)
    want = dense_attention(q, k, v, causal=causal, window=window)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_chunk=32, kv_chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("qc,kc", [(16, 16), (64, 128), (128, 32)])
def test_flash_pallas_chunk_invariance(qc, kc):
    q = jax.random.normal(KEY, (1, 128, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 128, 2, 32), jnp.float32)
    want = dense_attention(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, q_chunk=qc,
                                 kv_chunk=kc, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_pallas_bf16():
    q = jax.random.normal(KEY, (2, 128, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (2, 128, 2, 64), jnp.float32)
    want = dense_attention(q, k, v, causal=True)
    got = flash_attention_pallas(q.astype(jnp.bfloat16),
                                 k.astype(jnp.bfloat16),
                                 v.astype(jnp.bfloat16),
                                 q_chunk=32, kv_chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=0.06, rtol=0.06)


def test_hbm_traffic_model():
    from repro.kernels.flash_attention import attention_hbm_bytes
    # kernel traffic is linear in S; the jnp path's score traffic is S²-ish
    lin = attention_hbm_bytes(1, 4096, 4096, 32, 8, 128)
    assert lin == 2 * (4096 * 32 * 128 * 2 + 2 * 4096 * 8 * 128)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_backward_kernel_matches_autodiff(causal, window):
    """The Pallas backward kernels (dq/dk/dv) vs jax.grad of the dense
    oracle — removes the 'flash backward assumed' caveat for train cells."""
    from repro.kernels.flash_attention import flash_attention_trainable
    b, s, h, kv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(KEY, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(11), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(12), (b, s, kv, hd))
    tgt = jax.random.normal(jax.random.PRNGKey(13), (b, s, h, hd))

    def loss_ref(q, k, v):
        return jnp.sum((dense_attention(q, k, v, causal=causal,
                                        window=window) - tgt) ** 2)

    def loss_pal(q, k, v):
        return jnp.sum((flash_attention_trainable(
            q, k, v, causal, window, 16, 32, True) - tgt) ** 2)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_pal = jax.grad(loss_pal, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a),
                                   atol=1e-4, rtol=1e-4)


def test_flash_trainable_forward_matches():
    from repro.kernels.flash_attention import flash_attention_trainable
    q = jax.random.normal(KEY, (2, 64, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(21), (2, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(22), (2, 64, 2, 32))
    want = dense_attention(q, k, v, causal=True)
    got = flash_attention_trainable(q, k, v, True, None, 32, 32, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
