"""Direct unit tests of ``analysis/hlo_cost.analyze`` (ISSUE 8 satellite).

Until now the loop-aware HLO cost model was exercised only through
``launch/dryrun.py``; these tests pin its numbers on LOWERED stencil
programs against hand-derived expectations.

The naive reference is the clean yardstick: XLA lowers it to ONE fused
stencil update inside ``while(known_trip_count=t)``, so

  * elementwise flops are EXACT: a tap chain of N multiplies and N-1
    adds per cell per step -> ``(2N-1) * D * t`` (the while-trip
    multiplier must count the fused body t times — XLA's own
    ``cost_analysis()`` counts it once, the bug this module exists to
    fix);
  * byte traffic uses the same per-op approximation ``cost_analysis``
    uses (result + operands per non-trivial top-level op), so it
    overcounts the minimal load+store by a small factor (pad/select
    machinery): bounded hand-derivation, ``2*D*s*t <= bytes <=
    8*D*s*t``.

Blocked (temporally-blocked, interpret-lowered) programs get the
inequalities that are stable by construction: redundant halo compute
means ew_flops >= the naive count for the same (D, t); counts are
deterministic across repeated lowerings (the property the bench gate's
traffic column relies on).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import HloCost, analyze
from repro.core.stencil_spec import get
from repro.kernels.ref import reference

CASES = (("j2d5pt", (64, 64), 4),
         ("j3d7pt", (16, 16, 16), 2))


def _naive_text(spec, shape, t):
    fn = jax.jit(lambda a: reference(a, spec, t))
    return fn.lower(jax.ShapeDtypeStruct(shape, jnp.float32)) \
             .compile().as_text()


@pytest.mark.parametrize("name,shape,t", CASES)
def test_naive_ew_flops_exact(name, shape, t):
    """(2N-1) flops per cell per step, times D cells, times t steps —
    the while-loop trip multiplier makes it exact, not 1/t of it."""
    spec = get(name)
    cost = analyze(_naive_text(spec, shape, t))
    want = (2 * len(spec.taps) - 1) * math.prod(shape) * t
    assert cost.ew_flops == want
    assert cost.dot_flops == 0.0            # stencils are dot-free
    assert cost.total_flops == want


@pytest.mark.parametrize("name,shape,t", CASES)
def test_naive_bytes_bounded(name, shape, t):
    """Per step the field is read and written at least once (2*D*s) and
    the per-op approximation charges the pad/select machinery a small
    constant factor on top — measured 4.1x (2-D) / 5.9x (3-D)."""
    spec = get(name)
    cost = analyze(_naive_text(spec, shape, t))
    floor = 2 * math.prod(shape) * 4 * t    # one f32 load + store per step
    assert floor <= cost.bytes_accessed <= 8 * floor


def test_blocked_program_flops_and_determinism():
    """The temporally-blocked chain recomputes halo cells, so its flop
    count can only exceed the naive minimum; repeated lowerings count
    identically (the load-immune property the bench gate relies on)."""
    from repro.api import compile_stencil
    from repro.tuning.analytic import lowered_text

    spec = get("j2d5pt")
    shape, t = (64, 64), 2
    prog = compile_stencil(spec, shape, t=t, interpret=True)
    cost = analyze(lowered_text(prog, t))
    naive_flops = (2 * len(spec.taps) - 1) * math.prod(shape) * t
    assert cost.ew_flops >= naive_flops
    assert cost.bytes_accessed > 0
    again = analyze(lowered_text(prog, t))
    assert again.ew_flops == cost.ew_flops
    assert again.bytes_accessed == cost.bytes_accessed


SYNTH = """\
HloModule synth

ENTRY %main (p0: f32[4,4], p1: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %p1 = f32[4,4]{1,0} parameter(1)
  %add.1 = f32[4,4]{1,0} add(%p0, %p1)
  %iot = s32[4]{0} iota(), iota_dimension=0
  %iadd = s32[4]{0} add(%iot, %iot)
  %cmp = pred[4,4]{1,0} compare(%p0, %p1), direction=LT
  ROOT %mul = f32[4,4]{1,0} multiply(%add.1, %p1)
}
"""


def test_ew_counting_gates_on_float_arithmetic():
    """One add + one multiply on f32[4,4] = 32 flops; the s32 add, the
    iota, and the compare are bookkeeping, not flops."""
    cost = analyze(SYNTH)
    assert cost.ew_flops == 32.0


def test_hlocost_backward_compatible_construction():
    """``ew_flops`` was appended with a default so every existing
    positional construction (``HloCost(0, 0, {}, {}, {})`` included)
    still works, and ``as_dict`` carries the new keys."""
    c = HloCost(6.0, 100.0, {}, {}, {})
    assert c.ew_flops == 0.0
    assert c.total_flops == 6.0
    d = HloCost(6.0, 100.0, {}, {}, {}, ew_flops=4.0).as_dict()
    assert d["ew_flops"] == 4.0
    assert d["total_flops"] == 10.0
    assert d["dot_flops"] == 6.0
