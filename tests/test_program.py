"""The compile-once front door: ``StencilProgram`` semantics, first-class
boundary conditions vs an independent jnp.roll/pad oracle, batched
execution, the bounded ``ProgramCache``, and the deprecation shims."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Boundary, ProgramCache, cache_stats, compile_stencil,
                       resolve_geometry)
from repro.core.stencil_spec import TABLE2, get
from repro.kernels import ops, ref, sweep
from repro.stencils.data import init_domain

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ALL_SPECS = list(TABLE2.values())
BOUNDARIES = [Boundary.periodic(), Boundary.reflect(),
              Boundary.dirichlet(0.7), Boundary.neumann()]


def small_shape(spec):
    return (27, 22) if spec.ndim == 2 else (12, 9, 11)


# ------------------------------------------------ independent oracle -------
# Deliberately NOT the tap engine: periodic via jnp.roll, the rest via a
# jnp.pad ghost ring and hand-written tap slices (neumann = per-step
# symmetric fill ghost(-k) = u(k-1) + k·flux, the flux ramp added by hand).

def neumann_pad(x, rad, flux):
    xe = np.pad(np.asarray(x), rad, mode="symmetric")
    if flux:
        for a in range(x.ndim):
            n = x.shape[a]
            i = np.arange(xe.shape[a])
            dist = np.maximum(np.maximum(rad - i, i - (rad + n - 1)), 0)
            sh = [1] * x.ndim
            sh[a] = -1
            xe = xe + (dist * flux).reshape(sh).astype(xe.dtype)
    return jnp.asarray(xe)


def oracle_step(x, spec, b):
    nd = spec.ndim
    if b.kind == "periodic":
        acc = jnp.zeros_like(x)
        for off, c in spec.taps:
            acc = acc + c * jnp.roll(x, tuple(-o for o in off),
                                     axis=tuple(range(nd)))
        return acc
    rad = spec.radius
    if b.kind == "dirichlet":
        xe = jnp.pad(x, rad, constant_values=b.value)
    elif b.kind == "neumann":
        xe = neumann_pad(x, rad, b.value)
    else:
        xe = jnp.pad(x, rad, mode="reflect")
    acc = jnp.zeros_like(x)
    for off, c in spec.taps:
        sl = tuple(slice(rad + o, rad + o + n)
                   for o, n in zip(off, x.shape))
        acc = acc + c * xe[sl]
    return acc


def oracle(x, spec, t, b):
    for _ in range(t):
        x = oracle_step(x, spec, b)
    return x


# ===================================================== boundary programs ==
@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
@pytest.mark.parametrize("t", [1, 2, 4])
@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
def test_boundary_program_matches_oracle(spec, t, boundary):
    """All nine Table-2 specs under periodic / reflect / Dirichlet(0.7)
    match the independent roll/pad oracle through the compiled program."""
    x = init_domain(spec, small_shape(spec))
    prog = compile_stencil(spec, x.shape, t=t, boundary=boundary,
                           interpret=True)
    got = prog.apply(x)
    want = oracle(x, spec, t, boundary)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, (spec.name, t, boundary, err)


@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
def test_boundary_executor_matches_oracle(boundary):
    """The multi-sweep executor (remainder sweep included) re-pins the
    boundary correctly — T steps == T oracle steps."""
    for name in ("j2d9pt", "j3d7pt"):
        spec = get(name)
        x = init_domain(spec, small_shape(spec))
        prog = compile_stencil(spec, x.shape, t=3, boundary=boundary,
                               interpret=True)
        got = prog.run(x, 7)                 # 3 + 3 + 1 remainder
        want = oracle(x, spec, 7, boundary)
        err = float(jnp.abs(got - want).max())
        assert err < 1e-4, (name, boundary, err)


def test_boundary_reference_oracle_agrees():
    """ref.reference(boundary=...) (the in-repo oracle the kernels share
    machinery with) agrees with the independent roll/pad oracle."""
    for b in BOUNDARIES:
        for name in ("j2d25pt", "j3d27pt"):
            spec = get(name)
            x = init_domain(spec, small_shape(spec))
            got = ref.reference_unrolled(x, spec, 3, boundary=b)
            want = oracle(x, spec, 3, b)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-5, rtol=1e-5)


def test_boundary_validation_errors():
    spec2 = get("j2d5pt")
    with pytest.raises(ValueError, match="kind"):
        Boundary("torus")
    with pytest.raises(ValueError, match="no value"):
        Boundary("periodic", 1.0)
    # non-normalized taps run non-zero Dirichlet only through the affine
    # closure: exact for depth-1 sweeps, refused (actionably) for deeper
    # fused chains (DESIGN.md §11.3)
    import dataclasses
    bad = dataclasses.replace(spec2, name="unnorm",
                              taps=tuple((o, 2 * c) for o, c in spec2.taps))
    with pytest.raises(ValueError, match="affine closure"):
        compile_stencil(bad, (16, 16), t=2,
                        boundary=Boundary.dirichlet(0.5))
    x2 = init_domain(spec2, (16, 16))
    p1 = compile_stencil(bad, (16, 16), t=1,
                         boundary=Boundary.dirichlet(0.5), interpret=True)
    err = float(jnp.abs(p1.apply(x2)
                        - oracle(x2, bad, 1, Boundary.dirichlet(0.5))).max())
    assert err < 1e-4          # u_1 = Z(u - v) + v*s, exact for any s
    # mirror-asymmetric taps cannot run reflect exactly
    asym = dataclasses.replace(
        spec2, name="asym",
        taps=(((0, 0), 0.5), ((0, 1), 0.3), ((0, -1), 0.2)))
    with pytest.raises(ValueError, match="mirror"):
        compile_stencil(asym, (16, 16), t=1, boundary=Boundary.reflect())
    # ...but they run fine under zero Dirichlet and periodic
    x = init_domain(spec2, (16, 16))
    for b in (None, Boundary.periodic()):
        compile_stencil(asym, (16, 16), t=2, boundary=b,
                        interpret=True).apply(x)


def test_neumann_flux_and_refusals():
    """Constant-flux neumann is exact for t=1 sweeps (ghosts re-pinned
    every step, any taps); deeper fused chains are refused unless the
    taps are mirror-symmetric AND the flux is zero — with the fixes
    spelled out (taps.check_boundary)."""
    import dataclasses

    spec = get("j2d5pt")
    x = init_domain(spec, (22, 19))
    b = Boundary.neumann(0.5)
    prog = compile_stencil(spec, x.shape, t=1, boundary=b, interpret=True)
    got = prog.run(x, 3)
    want = oracle(x, spec, 3, b)
    assert float(jnp.abs(got - want).max()) < 1e-4
    # flux != 0 at depth >= 2: one application bends the ghost ramp
    with pytest.raises(ValueError, match="per-step refills"):
        compile_stencil(spec, x.shape, t=2, boundary=b)
    # mirror-asymmetric taps at depth >= 2: symmetric extension does not
    # evolve as the mirror of the interior
    asym = dataclasses.replace(
        spec, name="asym",
        taps=(((0, 0), 0.5), ((0, 1), 0.3), ((0, -1), 0.2)))
    with pytest.raises(ValueError, match="mirror-symmetric"):
        compile_stencil(asym, x.shape, t=2, boundary=Boundary.neumann())
    # ...but the same taps are exact at t=1 (per-step refill)
    p1 = compile_stencil(asym, x.shape, t=1, boundary=Boundary.neumann(),
                         interpret=True)
    err = float(jnp.abs(p1.run(x, 3)
                        - oracle(x, asym, 3, Boundary.neumann())).max())
    assert err < 1e-4
    # zero-flux neumann conserves the mean for normalized symmetric taps
    # (insulated domain): the fused deep chain must too
    deep = compile_stencil(spec, x.shape, t=4,
                           boundary=Boundary.neumann(), interpret=True)
    y = deep.run(x, 8)
    assert abs(float(y.mean()) - float(x.mean())) < 1e-5


# ========================================================== program API ==
def test_program_apply_and_run_match_reference():
    spec = get("j2d5pt")
    x = init_domain(spec, (97, 83))
    prog = compile_stencil(spec, x.shape, t=6, interpret=True)
    np.testing.assert_allclose(
        np.asarray(prog.apply(x)),
        np.asarray(ref.reference_unrolled(x, spec, 6)),
        atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(                  # 25 = 6+6+6+6+1 remainder
        np.asarray(prog.run(x, 25)),
        np.asarray(ref.reference_unrolled(x, spec, 25)),
        atol=1e-4, rtol=1e-4)
    assert prog.run(x, 0) is x


def test_program_apply_depth_override():
    spec = get("j3d7pt")
    x = init_domain(spec, (14, 9, 11))
    prog = compile_stencil(spec, x.shape, t=4, interpret=True)
    got = prog.apply(x, t=2)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.reference_unrolled(x, spec, 2)),
        atol=1e-4, rtol=1e-4)


def test_run_batched_equals_loop_over_run():
    """The one-vmapped-runner batched path == a Python loop of .run —
    2-D and 3-D, including a boundary that needs per-sweep re-pinning."""
    cases = [("j2d5pt", (33, 29), None), ("j3d7pt", (12, 9, 11), None),
             ("j2d9pt", (24, 21), Boundary.periodic())]
    for name, shape, boundary in cases:
        spec = get(name)
        xs = jnp.stack([init_domain(spec, shape, seed=i) for i in range(3)])
        prog = compile_stencil(spec, shape, t=3, boundary=boundary,
                               interpret=True)
        got = prog.run_batched(xs, 7)
        assert got.shape == xs.shape
        for i in range(xs.shape[0]):
            np.testing.assert_allclose(
                np.asarray(got[i]), np.asarray(prog.run(xs[i], 7)),
                atol=1e-5, rtol=1e-5, err_msg=f"{name} batch elem {i}")


def test_run_padded_donated_carry_matches_run():
    from repro.kernels.stencil2d import padded_shape_2d

    spec = get("j2d5pt")
    shape = (45, 70)
    x = init_domain(spec, shape)
    prog = compile_stencil(spec, shape, t=3, interpret=True)
    bh = prog.geometry()["block"][0]
    hp, wp = padded_shape_2d(spec, 3, bh, *shape)
    xp = jnp.zeros((hp, wp), jnp.float32).at[:shape[0], :shape[1]].set(x)
    out = prog.run_padded(xp, 9)
    np.testing.assert_allclose(
        np.asarray(out)[:shape[0], :shape[1]],
        np.asarray(prog.run(x, 9)), atol=1e-5, rtol=1e-5)
    # not available off the 2-D zero-Dirichlet fast path
    p3 = compile_stencil(get("j3d7pt"), (12, 9, 11), t=2, interpret=True)
    with pytest.raises(ValueError, match="padded-carry"):
        p3.run_padded(xp, 4)


def test_program_shape_mismatch_raises():
    spec = get("j2d5pt")
    prog = compile_stencil(spec, (32, 32), t=2, interpret=True)
    with pytest.raises(ValueError, match="compiled for shape"):
        prog.apply(init_domain(spec, (16, 16)))
    with pytest.raises(ValueError, match="compiled for shape"):
        prog.run_batched(init_domain(spec, (32, 32)))   # missing batch axis
    with pytest.raises(ValueError):
        compile_stencil(spec, (32, 32, 32))             # 3-D shape, 2-D spec


def test_compile_validates_mode_and_depth():
    """A typo'd mode or a degenerate depth fails loudly at compile/call
    time with a clear message, not deep inside kernel geometry."""
    spec = get("j2d5pt")
    with pytest.raises(ValueError, match="unknown mode"):
        compile_stencil(spec, (32, 32), t=2, mode="scrtch")
    with pytest.raises(ValueError, match="unknown mode"):
        compile_stencil(get("j3d7pt"), (12, 9, 11), t=2, mode="stream")
    with pytest.raises(ValueError, match="depth must be >= 1"):
        compile_stencil(spec, (32, 32), t=0)
    prog = compile_stencil(spec, (32, 32), t=2, interpret=True)
    with pytest.raises(ValueError, match="depth must be >= 1"):
        prog.apply(init_domain(spec, (32, 32)), t=0)
    stream = compile_stencil(spec, (32, 32), t=2, mode="stream",
                             interpret=True)
    with pytest.raises(ValueError, match="padded-carry"):
        stream.run_padded(jnp.zeros((64, 128)), 4)


def test_program_memoized_and_distinct():
    spec = get("j2d5pt")
    a = compile_stencil(spec, (48, 40), t=4, interpret=True)
    b = compile_stencil(spec, (48, 40), t=4, interpret=True)
    assert a is b
    c = compile_stencil(spec, (48, 40), t=4, interpret=True,
                        boundary=Boundary.periodic())
    assert c is not a


def test_program_geometry_and_cost():
    spec = get("j3d7pt")
    prog = compile_stencil(spec, (32, 24, 32), t=4, interpret=True)
    g = prog.geometry()
    assert g["block"][0] >= spec.halo(4)
    assert g["fetched_cells"] > g["body_cells"] > 0
    # the sole geometry path: the legacy shim resolves identical geometry
    assert g == ops.launch_geometry(spec, 4, (32, 24, 32), plan=prog.plan)
    assert prog.cost(prog.plan.t).pp_cells_per_s == prog.plan.pp.pp_cells_per_s
    assert prog.cost(1).pp_cells_per_s > 0
    # re-pinning boundaries compute a ghost-extended domain
    pb = compile_stencil(spec, (32, 24, 32), t=4, interpret=True,
                         boundary=Boundary.periodic())
    assert pb.compute_shape() == tuple(n + 2 * spec.halo(4)
                                       for n in (32, 24, 32))
    stats = prog.cache_stats()
    assert {"programs", "plans", "runners"} <= set(stats)


# ========================================================= ProgramCache ==
def test_program_cache_lru_and_counters():
    c = ProgramCache(maxsize=2, name="t")
    assert c.get("a") is None and c.misses == 1
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                 # refreshes a
    c.put("d", 4)                          # evicts b (LRU)
    assert "b" not in c and "a" in c and len(c) == 2
    assert c.get("b", "gone") == "gone"
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 2 and s["size"] == 2
    assert c.get_or_build("e", lambda: 5) == 5
    assert c.get_or_build("e", lambda: 99) == 5
    c.clear()
    assert len(c) == 0

    with pytest.raises(ValueError):
        ProgramCache(maxsize=0)


def test_global_caches_exposed_and_bounded():
    stats = cache_stats()
    for name in ("programs", "plans", "runners"):
        assert stats[name]["size"] <= stats[name]["maxsize"]
    # the legacy sweep module aliases the bounded caches, not dicts
    assert isinstance(sweep._LAUNCH_CACHE, ProgramCache)
    assert isinstance(sweep._PLAN_CACHE, ProgramCache)


def test_plan_bucketed_delegates_to_cache():
    spec = get("j2d9pt")
    before = sweep._PLAN_CACHE.stats()["misses"]
    p1 = sweep.plan_bucketed(spec, (130, 70))
    p2 = sweep.plan_bucketed(spec, (150, 90))   # same 64-bucket: (192, 128)
    assert p1 is p2
    assert sweep._PLAN_CACHE.stats()["misses"] <= before + 1


# ================================================================ shims ==
def test_legacy_shims_warn_and_match():
    spec = get("j2d5pt")
    x = init_domain(spec, (40, 36))
    prog = compile_stencil(spec, x.shape, t=3, plan=None, interpret=True)
    with pytest.warns(DeprecationWarning, match="ebisu_stencil"):
        legacy = ops.ebisu_stencil(x, spec, 3, interpret=True)
    np.testing.assert_allclose(np.asarray(legacy),
                               np.asarray(prog.apply(x)), atol=0, rtol=0)
    with pytest.warns(DeprecationWarning, match="run_sweeps"):
        legacy = sweep.run_sweeps(x, spec, 7, t=3, interpret=True)
    np.testing.assert_allclose(np.asarray(legacy),
                               np.asarray(prog.run(x, 7)),
                               atol=1e-6, rtol=1e-6)


def test_planned_shim_threads_mode_and_hw():
    """The seed's ebisu_stencil_planned silently dropped mode= (always
    fused); it now routes through the program front door."""
    from repro.core import roofline as rl

    spec = get("j2d9pt")
    x = init_domain(spec, (40, 36))
    with pytest.warns(DeprecationWarning):
        y_scratch, p = ops.ebisu_stencil_planned(
            x, spec, t=2, mode="scratch", interpret=True)
    assert p is not None
    np.testing.assert_allclose(
        np.asarray(y_scratch),
        np.asarray(ref.reference_unrolled(x, spec, 2)),
        atol=1e-4, rtol=1e-4)
    with pytest.warns(DeprecationWarning):
        _, p_a100 = ops.ebisu_stencil_planned(
            x, spec, t=2, hw=rl.A100_FP64, interpret=True)
    assert p_a100.hw_name == rl.A100_FP64.name


def test_resolve_geometry_is_sole_path():
    """ops.launch_geometry is a pure delegate of api.resolve_geometry."""
    spec = get("j2d5pt")
    for mode in ("fused", "stream"):
        assert (ops.launch_geometry(spec, 4, (96, 80), mode=mode)
                == resolve_geometry(spec, 4, (96, 80), mode=mode))


def test_bench_min_merge():
    """--passes N keeps each row's minimum with that pass's derived
    column, preserving row order of first appearance."""
    sys.path.insert(0, _ROOT)
    try:
        from benchmarks.run import min_merge
    finally:
        sys.path.remove(_ROOT)
    merged = min_merge([[("a", 10.0, "d1"), ("b", 5.0, "x")],
                        [("a", 7.0, "d2"), ("c", 1.0, "y")],
                        [("a", 9.0, "d3")]])
    assert merged == [("a", 7.0, "d2"), ("b", 5.0, "x"), ("c", 1.0, "y")]


# ======================================================= import hygiene ==
def test_api_import_initializes_no_backend():
    """`import repro.api` must stay backend-free: programs answer backend
    questions at compile time, never at import time (tier1.sh gate)."""
    code = (
        "import repro.api\n"
        "from jax._src import xla_bridge\n"
        "assert not getattr(xla_bridge, '_backends', {}), "
        "'repro.api import initialized a JAX backend'\n"
        "print('clean')\n"
    )
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode == 0 and "clean" in r.stdout, r.stderr
