"""Tap-engine unit tests + the halo-exact input-traffic model assertions.

The engine is validated against an independent numpy realization of the
tap semantics (zero-fill shifts), *not* against `ref` — `ref` itself runs
on the engine, so that comparison would be circular.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import roofline as rl
from repro.core.multiqueue import choose_batch, stream_schedule
from repro.core.planner import plan
from repro.core.stencil_spec import TABLE2, get
from repro.kernels import taps as tp
from repro.kernels.stencil2d import input_rows_per_strip, strip_geometry
from repro.kernels.stencil3d import chunk_geometry, input_planes_per_chunk

ALL = list(TABLE2.values())


def numpy_step(x: np.ndarray, taps) -> np.ndarray:
    """Independent oracle: out[i] = sum c * x[i+off], zero outside."""
    rad = tp.tap_radius(taps)
    xp = np.pad(x, [(rad, rad)] * x.ndim)
    acc = np.zeros_like(x)
    for off, c in taps:
        idx = tuple(slice(rad + o, rad + o + n) for o, n in zip(off, x.shape))
        acc += c * xp[idx]
    return acc


@pytest.mark.parametrize("spec", ALL, ids=lambda s: s.name)
def test_engine_step_matches_numpy(spec):
    rng = np.random.default_rng(0)
    shape = (13, 9, 17)[:spec.ndim] if spec.ndim == 3 else (13, 17)
    x = rng.standard_normal(shape).astype(np.float32)
    got = tp.engine_for(spec.taps, spec.ndim).step(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), numpy_step(x, spec.taps),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("spec", ALL, ids=lambda s: s.name)
def test_star_and_generic_paths_agree(spec):
    """The separable star path is an algebraic regrouping of the generic."""
    star = tp.split_star(spec.taps, spec.ndim)
    if star is None:
        assert spec.shape_kind != "star"
        return
    assert spec.shape_kind == "star"
    rng = np.random.default_rng(1)
    shape = (8, 11, 15)[:spec.ndim] if spec.ndim == 3 else (11, 15)
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    a = tp.apply_taps_generic(x, spec.taps, spec.ndim)
    b = tp.apply_taps_star(x, star[0], star[1], spec.ndim)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("spec", [s for s in ALL if s.ndim == 3],
                         ids=lambda s: s.name)
@pytest.mark.parametrize("batch", [1, 3, 4])
def test_window_step_is_valid_mode_of_full_step(spec, batch):
    """window_step == the interior planes of a full 3-D application."""
    rad = spec.radius
    w = batch + 2 * rad
    rng = np.random.default_rng(2)
    window = jnp.asarray(rng.standard_normal((w, 7, 9)).astype(np.float32))
    eng = tp.engine_for(spec.taps, 3)
    got = eng.window_step(window, batch)
    full = eng.step(window)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(full[rad:rad + batch]),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------- trapezoid narrowing ---
@pytest.mark.parametrize("spec", ALL, ids=lambda s: s.name)
@pytest.mark.parametrize("t", [1, 2, 4])
def test_trapezoid_chain_matches_full_chain_and_oracle(spec, t):
    """Narrowed chain == full zero-fill chain == independent numpy oracle
    on the interior (cells ≥ t·rad from the narrowed edges): boundary
    effects travel one radius per step, so the trapezoid's valid-mode
    context reproduces them exactly (DESIGN.md §9.1)."""
    eng = tp.engine_for(spec.taps, spec.ndim)
    rad = eng.radius
    if spec.ndim == 2:
        shape, axes = (2 * t * rad + 7, 15), (0,)
    else:
        # the 3-D streamer narrows the in-plane axes (z is streamed)
        shape, axes = (2 * t * rad + 5, 2 * t * rad + 6, 9), (1, 2)
    rng = np.random.default_rng(4)
    x = rng.standard_normal(shape).astype(np.float32)
    oracle = x.copy()
    for _ in range(t):
        oracle = numpy_step(oracle, spec.taps)
    crop = tuple(slice(t * rad, n - t * rad) if a in axes else slice(None)
                 for a, n in enumerate(shape))
    got = eng.chain_trapezoid(jnp.asarray(x), t, axes=axes)
    np.testing.assert_allclose(np.asarray(got), oracle[crop],
                               atol=1e-4, rtol=1e-4)
    full = eng.chain(jnp.asarray(x), t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[crop]),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("spec", [s for s in ALL if s.ndim == 3],
                         ids=lambda s: s.name)
def test_window_step_inplane_valid_mode(spec):
    """In-plane valid-mode narrowing == interior of the zero-fill result."""
    rad = spec.radius
    rng = np.random.default_rng(5)
    window = jnp.asarray(rng.standard_normal(
        (3 + 2 * rad, 9 + 2 * rad, 11 + 2 * rad)).astype(np.float32))
    eng = tp.engine_for(spec.taps, 3)
    got = eng.window_step(window, 3, inplane_crops=(rad, rad))
    full = eng.step(window)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(full[rad:rad + 3, rad:-rad, rad:-rad]),
        atol=1e-5, rtol=1e-5)


def test_leading_axes_broadcast():
    """Batched (leading-axis) application == per-slice application."""
    spec = get("j2d25pt")
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((4, 10, 12)).astype(np.float32))
    eng = tp.engine_for(spec.taps, 2)
    got = eng.step(x)
    for b in range(4):
        np.testing.assert_allclose(np.asarray(got[b]),
                                   np.asarray(eng.step(x[b])),
                                   atol=1e-6, rtol=1e-6)


# --------------------------------------------------- traffic model ---------
@pytest.mark.parametrize("spec", ALL, ids=lambda s: s.name)
@pytest.mark.parametrize("t", [1, 3, 6])
def test_halo_exact_traffic_bound(spec, t):
    """Each input element is read at most 1 + 2·halo/tile times per sweep —
    the halo-exact fetch replaces the seed's implicit 3x."""
    tile = 128 if spec.ndim == 2 else 16
    if spec.ndim == 2:
        fetched, body = input_rows_per_strip(spec, t, tile)
        resolved, halo = strip_geometry(spec, t, tile)
    else:
        fetched, body = input_planes_per_chunk(spec, t, tile)
        resolved, halo = chunk_geometry(spec, t, tile)
    assert body == resolved and fetched == body + 2 * halo
    reads = fetched / body
    # the resolved tile only ever grows, so the bound vs the *requested*
    # tile still holds
    assert reads <= 1 + 2 * halo / max(tile, halo) + 1e-9
    assert reads < 3.0  # strictly better than whole-neighbor-block fetching


@pytest.mark.parametrize("name,t,shape", [("j2d5pt", 6, (256, 256)),
                                          ("j3d7pt", 4, (32, 24, 32))])
def test_traffic_ratio_consistent_with_roofline(name, t, shape):
    """bench_kernels' modeled ratio == the same quantity expressed through
    roofline.component_times (Eq 2 with halo-inflated D_gm).  The ratio is
    derived from the tile the launch actually resolves."""
    from benchmarks.bench_kernels import modeled_traffic_ratio, reads_per_elem

    spec = get(name)
    hw = rl.TPU_V5E
    d = 1e6  # any domain size — the ratio is size-free
    t_gm_naive = sum(
        rl.component_times(spec, 1, hw, d_all=d)[0] for _ in range(t))
    d_eff = d * (reads_per_elem(spec, t, shape) + 1) / 2
    t_gm_blocked = rl.component_times(spec, t, hw, d_gm=d_eff, d_all=d)[0]
    assert modeled_traffic_ratio(spec, t, shape) == pytest.approx(
        t_gm_naive / t_gm_blocked)
    # j2d5pt t=6 @ bh=128: ~2.7x less input HBM traffic than whole-block
    if name == "j2d5pt":
        fetched, body = input_rows_per_strip(spec, t, 128)
        assert 3 * body / fetched == pytest.approx(2.75, abs=0.1)


def test_reads_per_elem_tracks_launched_tile():
    """The bench's traffic model follows the resolved launch, not the
    default tile constants: a plan with a different tile changes it."""
    from benchmarks.bench_kernels import reads_per_elem
    from repro.core.planner import plan
    from repro.kernels.ops import launch_geometry

    spec = get("j3d7pt")
    p = plan(spec, rl.TPU_V5E)
    shape = (256, 64, 64)
    default = reads_per_elem(spec, p.t, shape)
    planned = reads_per_elem(spec, p.t, shape, plan=p)
    g = launch_geometry(spec, p.t, shape, plan=p)
    assert planned == pytest.approx(g["fetched_cells"] / g["body_cells"])
    assert planned != default  # plan.zc differs from the default chunk


# --------------------------------------------------- batch algebra ---------
@pytest.mark.parametrize("halo", [1, 2, 3, 6, 8])
@pytest.mark.parametrize("kz", [1, 2, 4, 6])
@pytest.mark.parametrize("target_mult", [0, 1, 2, 10])
def test_choose_batch_invariants(halo, kz, target_mult):
    span = halo * (kz + 2)
    target = halo * target_mult
    b = choose_batch(span, halo, target)
    assert b % halo == 0 and span % b == 0
    assert b <= max(target, halo)


def test_stream_schedule_matches_planner_pick():
    """The kernel-side schedule honors the plan's lazy_batch exactly."""
    for name in ("j3d7pt", "j3d13pt", "poisson"):
        spec = get(name)
        p = plan(spec, rl.TPU_V5E)
        zc, halo = chunk_geometry(spec, p.t, p.block[0])
        batch, window, stages = stream_schedule(zc, halo, spec.radius,
                                                p.lazy_batch)
        assert batch == p.lazy_batch  # planner chose a feasible batch
        assert window == batch + 2 * spec.radius
        assert stages * batch == zc + 2 * halo
