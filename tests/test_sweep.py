"""Multi-sweep executor: schedule algebra, reference equivalence, the
padded-layout contract, launch caching, and the bench regression gate."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil_spec import get
from repro.kernels import ref, sweep
from repro.kernels.stencil2d import padded_shape_2d
from repro.stencils.data import init_domain

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_sweep_schedule():
    assert sweep.sweep_schedule(24, 6) == (6, 6, 6, 6)
    assert sweep.sweep_schedule(25, 6) == (6, 6, 6, 6, 1)
    assert sweep.sweep_schedule(5, 8) == (5,)
    assert sweep.sweep_schedule(0, 4) == ()
    assert sum(sweep.sweep_schedule(37, 5)) == 37


@pytest.mark.parametrize("name,shape,total,t", [
    ("j2d5pt", (97, 83), 25, 6),     # remainder sweep (25 % 6 != 0)
    ("j2d9pt", (64, 60), 10, 4),
    ("j3d7pt", (20, 9, 13), 10, 4),
    ("j3d27pt", (14, 10, 12), 7, 3),
])
def test_run_sweeps_matches_reference(name, shape, total, t):
    spec = get(name)
    x = init_domain(spec, shape)
    got = sweep.run_sweeps(x, spec, total, t=t, interpret=True)
    want = ref.reference_unrolled(x, spec, total)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_run_sweeps_plan_depth_default():
    """t=None: per-sweep depth comes from the shape-bucketed §6 plan."""
    spec = get("j2d5pt")
    x = init_domain(spec, (48, 40))
    p = sweep.plan_bucketed(spec, x.shape)
    total = p.t + 2                       # forces a remainder sweep too
    got = sweep.run_sweeps(x, spec, total, interpret=True)
    want = ref.reference_unrolled(x, spec, total)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_run_sweeps_zero_steps_identity():
    spec = get("j2d5pt")
    x = init_domain(spec, (16, 16))
    assert sweep.run_sweeps(x, spec, 0, t=4, interpret=True) is x


def test_padded_layout_contract():
    """DESIGN.md §9.3: padded layout is closed under chained sweeps —
    out-of-domain cells are zero after every sweep, and the uniform-depth
    padded chain equals the reference on the domain."""
    spec = get("j2d5pt")
    t, total = 3, 9
    height, width = 45, 70
    x = init_domain(spec, (height, width))
    bh = 64
    hp, wp = padded_shape_2d(spec, t, bh, height, width)
    xp = jnp.zeros((hp, wp), jnp.float32).at[:height, :width].set(x)
    out = sweep.run_sweeps_padded(xp, spec, total, t=t, height=height,
                                  width=width, bh=bh, interpret=True)
    assert out.shape == (hp, wp)
    body = np.asarray(out)[:height, :width]
    want = np.asarray(ref.reference_unrolled(x, spec, total))
    np.testing.assert_allclose(body, want, atol=1e-4, rtol=1e-4)
    pad = np.asarray(out).copy()
    pad[:height, :width] = 0.0
    assert np.all(pad == 0.0)


def test_sweep_tile_3d_fits_vmem_model():
    """The executor never launches a 3-D config its own §6 model rejects:
    the (zc, batch) it picks stays within the hardware budget at the
    haloed working extents (at the plan's own depth a fit is guaranteed)."""
    from repro.core import roofline as rl
    from repro.core.planner import vmem_required_3d_batched
    from repro.core.stencil_spec import TABLE2
    from repro.kernels.stencil3d import xy_tile

    for hw in (rl.TPU_V5E, rl.A100_FP64):
        for spec in (s for s in TABLE2.values() if s.ndim == 3):
            shape = spec.domain
            p = sweep.plan_bucketed(spec, shape, hw)
            zc, ty, tx, batch = sweep._sweep_tile_3d(spec, p.t, shape, hw, p)
            halo = spec.halo(p.t)
            ty_r, tiled_y = xy_tile(spec, p.t, shape[1], ty)
            tx_r, tiled_x = xy_tile(spec, p.t, shape[2], tx)
            ny = ty_r + 2 * halo if tiled_y else shape[1]
            nx = tx_r + 2 * halo if tiled_x else shape[2]
            need = vmem_required_3d_batched(spec, p.t, zc, batch, ny, nx,
                                            hw.s_cell,
                                            p.parallelism.num_buffers)
            budget = hw.onchip_device_bytes or hw.onchip_bytes
            assert need <= budget, (hw.name, spec.name, zc, batch,
                                    need / budget)


def test_sweep_tile_3d_rejects_over_budget_depth():
    """An off-plan depth too deep for the hardware budget raises instead
    of silently launching a config the §6 model says does not fit."""
    from repro.core import roofline as rl

    spec = get("j3d7pt")
    shape = spec.domain
    p = sweep.plan_bucketed(spec, shape, rl.A100_FP64)
    with pytest.raises(ValueError, match="does not fit"):
        sweep._sweep_tile_3d(spec, p.t + 8, shape, rl.A100_FP64, p)


def test_run_sweeps_rejects_stream_mode():
    spec = get("j2d5pt")
    x = init_domain(spec, (16, 16))
    with pytest.raises(ValueError, match="stream"):
        sweep.run_sweeps(x, spec, 4, t=2, mode="stream", interpret=True)


def test_launch_cache_reuse():
    spec = get("j3d7pt")
    x = init_domain(spec, (12, 8, 10))
    a = sweep.run_sweeps(x, spec, 8, t=4, interpret=True)
    n_cached = len(sweep._LAUNCH_CACHE)
    b = sweep.run_sweeps(x, spec, 8, t=4, interpret=True)
    assert len(sweep._LAUNCH_CACHE) == n_cached   # second call hits cache
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- bench gate --------
def _run_gate(path):
    return subprocess.run(
        [sys.executable, os.path.join(_ROOT, "scripts", "bench_gate.py"),
         "--file", str(path)], capture_output=True, text=True)


def _entry(rev, **rows):
    return {"timestamp": "2026-01-01T00:00:00Z", "rev": rev,
            "rows": {k: {"us_per_call": v, "derived": ""}
                     for k, v in rows.items()}}


def test_bench_gate_pass_and_fail(tmp_path):
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps({"entries": [
        _entry("a", **{"kernel/x": 100.0, "kernel/y": 50.0}),
        _entry("b", **{"kernel/x": 110.0, "kernel/y": 40.0,
                       "sweep/new": 10.0}),   # +10% and a new row: OK
    ]}))
    r = _run_gate(ok)
    assert r.returncode == 0, r.stdout + r.stderr

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"entries": [
        _entry("a", **{"kernel/x": 100.0}),
        _entry("b", **{"kernel/x": 120.0}),   # +20% wall time: FAIL
    ]}))
    r = _run_gate(bad)
    assert r.returncode == 1
    assert "FAIL" in r.stdout


def test_bench_gate_single_entry_ok(tmp_path):
    one = tmp_path / "one.json"
    one.write_text(json.dumps({"entries": [_entry("a", **{"kernel/x": 1.0})]}))
    assert _run_gate(one).returncode == 0


def test_bench_gate_missing_rows_table_degrades(tmp_path):
    """A baseline entry without a 'rows' table (hand-edited or truncated)
    warns and passes instead of dying on a KeyError — the advisory gate
    must never be the thing that breaks CI."""
    p = tmp_path / "norows.json"
    p.write_text(json.dumps({"entries": [
        {"rev": "a", "timestamp": "t"},          # no rows at all
        _entry("b", **{"kernel/x": 100.0}),
    ]}))
    r = _run_gate(p)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "WARNING" in r.stdout


def test_bench_gate_row_missing_us_per_call_skipped(tmp_path):
    """A row lacking ``us_per_call`` in either entry is warned and
    skipped; the remaining rows still gate (and can still fail)."""
    entries = [_entry("a", **{"kernel/x": 100.0, "kernel/y": 50.0}),
               _entry("b", **{"kernel/x": 100.0, "kernel/y": 45.0})]
    del entries[0]["rows"]["kernel/x"]["us_per_call"]
    p = tmp_path / "partial.json"
    p.write_text(json.dumps({"entries": entries}))
    r = _run_gate(p)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "skipped" in r.stdout and "kernel/y" in r.stdout

    # the healthy rows still catch a real regression
    entries = [_entry("a", **{"kernel/x": 100.0, "kernel/y": 50.0}),
               _entry("b", **{"kernel/x": 100.0, "kernel/y": 75.0})]
    del entries[1]["rows"]["kernel/x"]["us_per_call"]
    p.write_text(json.dumps({"entries": entries}))
    r = _run_gate(p)
    assert r.returncode == 1
    assert "FAIL" in r.stdout


def test_bench_gate_missing_rev_fields_degrade(tmp_path):
    p = tmp_path / "norev.json"
    e = _entry("a", **{"kernel/x": 100.0})
    del e["rev"], e["timestamp"]
    p.write_text(json.dumps({"entries": [
        e, _entry("b", **{"kernel/x": 100.0})]}))
    r = _run_gate(p)
    assert r.returncode == 0, r.stdout + r.stderr
