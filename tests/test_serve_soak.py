"""Seeded soak: 60 simulated seconds of faulty traffic, zero surprises.

The acceptance bar for the serving tentpole (ISSUE: stencil-as-a-service):
drive a Poisson request mix — healthy requests interleaved with every
fault kind the service defends against (NaN inputs, oversized shapes,
already-expired deadlines, forced cache evictions, simulated OOM,
delayed dispatch) — over a 60 s :class:`SimClock` horizon and assert

  * zero unhandled exceptions escape the request path (any raise fails
    the test),
  * EVERY request resolves to a result or a typed ``ServeError``,
  * healthy requests — including batch-mates of poisoned ones — match
    the direct ``StencilProgram.run`` result within 2e-5.

Everything is seeded and the clock is simulated, so the run is
deterministic: same seed, same outcome mix, no wall-clock dependence.
"""
from __future__ import annotations

import random

import jax.numpy as jnp

from repro.api.program import compile_stencil
from repro.launch.serve_stencil import drive_sim, synth_requests
from repro.serve.faults import FaultConfig, FaultInjector
from repro.serve.stencil_service import (ServeError, ServiceConfig,
                                         ServiceCore, SimClock)

TOL = 2e-5
SOAK_MS = 60_000.0
N_REQ = 120                        # ~2 req/s over the 60 s horizon


def test_sixty_second_simulated_soak_with_faults():
    seed = 7
    cfg = ServiceConfig(max_batch=4, batch_window_ms=8.0,
                        max_cells=1 << 14, max_queue=4 * N_REQ,
                        max_inflight_per_tenant=4 * N_REQ, seed=seed)
    inj = FaultInjector(FaultConfig(
        seed=seed, nan_input_rate=0.08, oversized_rate=0.04,
        expired_rate=0.04, evict_rate=0.06, oom_batch_limit=2,
        delay_ms_range=(0, 5)))
    core = ServiceCore(cfg, clock=SimClock(), faults=inj)
    rng = random.Random(seed)
    tape = synth_requests(N_REQ, rng, inj, N_REQ / (SOAK_MS / 1e3),
                          cfg.max_cells)
    assert tape[-1][0] < SOAK_MS * 2   # the tape spans the soak horizon

    tickets = drive_sim(core, tape)    # any unhandled raise fails here

    # every request resolved — to a value or a typed error, never neither
    assert len(tickets) == N_REQ
    kinds_seen = set()
    for tk, kind in tickets:
        kinds_seen.add(kind)
        assert tk.done, f"unresolved {kind} request"
        if not tk.ok:
            assert isinstance(tk.error, ServeError), tk.error
    # the fault mix actually exercised more than the happy path
    assert "healthy" in kinds_seen and len(kinds_seen) >= 3

    # healthy requests (batch-mates of poisoned ones included) are
    # bit-for-bit trustworthy against the direct program
    checked = 0
    for tk, kind in tickets:
        if kind != "healthy" or not tk.ok:
            continue
        req = tk.request
        prog = compile_stencil(req.spec, req.x.shape, t=None)
        want = prog.run(jnp.asarray(req.x), req.total_t)
        assert float(jnp.max(jnp.abs(tk.result() - want))) < TOL
        checked += 1
    assert checked >= N_REQ // 2       # most traffic is healthy and served

    # the health report is non-empty and internally consistent:
    # ``resolved`` counts admitted requests; turned-away-at-admission
    # ones (typed Rejected / InvalidRequest / Expired-at-admission)
    # never enter the latency log
    from repro.serve.stencil_service import (Expired, InvalidRequest,
                                             Rejected)
    turned_away = sum(
        1 for tk, _ in tickets
        if isinstance(tk.error, (Rejected, InvalidRequest))
        or (isinstance(tk.error, Expired) and tk.error.stage == "admission"))
    stats = core.stats()
    assert stats["resolved"] == N_REQ - turned_away
    assert stats["batches"] >= 1
    assert core.pending() == 0


def test_soak_is_deterministic():
    """Same seed, same outcome sequence — the whole point of the
    sim-clock + seeded-injector design."""
    def outcomes(seed):
        cfg = ServiceConfig(max_batch=4, batch_window_ms=8.0,
                            max_cells=1 << 14, max_queue=256,
                            max_inflight_per_tenant=256, seed=seed)
        inj = FaultInjector(FaultConfig(
            seed=seed, nan_input_rate=0.08, oversized_rate=0.04,
            expired_rate=0.04, evict_rate=0.06, oom_batch_limit=2,
            delay_ms_range=(0, 5)))
        core = ServiceCore(cfg, clock=SimClock(), faults=inj)
        tape = synth_requests(40, random.Random(seed), inj, 50.0,
                              cfg.max_cells)
        return [(kind, "ok" if tk.ok else type(tk.error).__name__)
                for tk, kind in drive_sim(core, tape)]

    assert outcomes(11) == outcomes(11)
