"""The attention front door: ``AttentionProgram`` vs the independent dense
oracle (parity matrix over shapes × GQA × masks × dtypes), backward vs
``jax.grad`` of the oracle, chunk invariance, bounded-cache build-once
under concurrent compile, and the import-hygiene gate — the
``test_program.py`` pattern applied to the LM half."""
import concurrent.futures
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AttentionProgram, AttentionSpec, ProgramCache,
                       attention_cache_stats, attention_program_for,
                       clear_attention_caches, compile_attention)
from repro.api.attention import ATTN_PROGRAM_CACHE
from repro.models.attention import dense_attention

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def qkv(b, s, h, kv, hd, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


# ================================================= oracle parity matrix ==
# Every impl against dense_attention — the independent reference whose
# semantics test_flash_kernel.py pins the Pallas kernel to.
MATRIX = [
    # (b, s, h, kv, hd)         — GQA group sizes 2, 1 (MHA), 4 (MQA-ish)
    (2, 64, 4, 2, 32),
    (1, 128, 8, 8, 16),
    (2, 96, 4, 1, 32),
]
MASKS = [(True, None), (False, None), (True, 24)]


@pytest.mark.parametrize("impl", ["pallas", "chunked", "dense"])
@pytest.mark.parametrize("causal,window", MASKS,
                         ids=["causal", "bidir", "swa24"])
@pytest.mark.parametrize("b,s,h,kv,hd", MATRIX)
def test_program_matches_dense_oracle(b, s, h, kv, hd, causal, window,
                                      impl):
    if impl == "pallas" and s % 32:
        pytest.skip("pallas needs chunk-divisible S in this matrix")
    q, k, v = qkv(b, s, h, kv, hd)
    prog = compile_attention(heads=h, kv_heads=kv, head_dim=hd,
                             causal=causal, window=window, q_chunk=32,
                             kv_chunk=32, impl=impl, interpret=True)
    got = prog.apply(q, k, v)
    want = dense_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["pallas", "chunked"])
def test_program_bf16_matches_oracle(impl):
    q, k, v = qkv(2, 64, 4, 2, 32, dtype=jnp.bfloat16)
    prog = compile_attention(heads=4, kv_heads=2, head_dim=32,
                             q_chunk=32, kv_chunk=32, dtype=jnp.bfloat16,
                             impl=impl, interpret=True)
    got = prog.apply(q, k, v)
    assert got.dtype == jnp.bfloat16
    want = dense_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), atol=0.06, rtol=0.06)


# ============================================================== backward ==
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)],
                         ids=["causal", "swa24", "bidir"])
@pytest.mark.parametrize("impl", ["pallas", "chunked", "dense"])
def test_program_grad_matches_oracle_grad(impl, causal, window):
    b, s, h, kv, hd = 2, 64, 4, 2, 32
    q, k, v = qkv(b, s, h, kv, hd, seed=3)
    do = jax.random.normal(jax.random.PRNGKey(9), q.shape, jnp.float32)
    prog = compile_attention(heads=h, kv_heads=kv, head_dim=hd,
                             causal=causal, window=window, q_chunk=32,
                             kv_chunk=32, impl=impl, interpret=True)
    dq, dk, dv = prog.grad(q, k, v, do)

    def oracle_loss(q, k, v):
        return (dense_attention(q, k, v, causal=causal,
                                window=window) * do).sum()

    gq, gk, gv = jax.grad(oracle_loss, argnums=(0, 1, 2))(q, k, v)
    for got, want, name in ((dq, gq, "dq"), (dk, gk, "dk"), (dv, gv, "dv")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


def test_program_differentiable_inside_outer_grad():
    """prog.apply inlines under an outer trace — jax.grad through it
    equals the oracle's gradient (the transformer's training path)."""
    q, k, v = qkv(1, 64, 4, 2, 16, seed=5)
    prog = compile_attention(heads=4, kv_heads=2, head_dim=16, q_chunk=32,
                             kv_chunk=32, impl="pallas", interpret=True)
    g = jax.jit(jax.grad(lambda q: prog.apply(q, k, v).sum()))(q)
    want = jax.grad(lambda q: dense_attention(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ====================================================== chunk invariance ==
@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 64), (64, 32)])
def test_program_chunk_invariance(qc, kc):
    """Chunk sizes are an execution schedule, not semantics: every
    (q_chunk, kv_chunk) pair produces the same output."""
    q, k, v = qkv(1, 64, 4, 2, 32, seed=7)
    base = compile_attention(heads=4, kv_heads=2, head_dim=32, q_chunk=64,
                             kv_chunk=64, impl="pallas", interpret=True)
    ref = base.apply(q, k, v)
    for impl in ("pallas", "chunked"):
        prog = compile_attention(heads=4, kv_heads=2, head_dim=32,
                                 q_chunk=qc, kv_chunk=kc, impl=impl,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(prog.apply(q, k, v)),
                                   np.asarray(ref), atol=2e-5, rtol=2e-5,
                                   err_msg=f"{impl} ({qc},{kc})")


# ============================================== program cache semantics ==
def test_program_memoized_and_distinct():
    a = compile_attention(heads=4, kv_heads=2, head_dim=32, interpret=True)
    b = compile_attention(heads=4, kv_heads=2, head_dim=32, interpret=True)
    assert a is b
    c = compile_attention(heads=4, kv_heads=2, head_dim=32, window=128,
                          interpret=True)
    assert c is not a
    assert isinstance(a, AttentionProgram)
    assert a.spec == AttentionSpec(heads=4, kv_heads=2, head_dim=32)


def test_concurrent_compile_builds_once():
    """N threads compiling the same config race into get_or_build; the
    bounded cache hands every one the same handle and charges ONE miss."""
    spec = AttentionSpec(heads=8, kv_heads=4, head_dim=16, q_chunk=32,
                         kv_chunk=32)
    clear_attention_caches()
    before = ATTN_PROGRAM_CACHE.stats()["misses"]
    with concurrent.futures.ThreadPoolExecutor(max_workers=8) as ex:
        progs = list(ex.map(
            lambda _: compile_attention(spec, interpret=True), range(16)))
    assert all(p is progs[0] for p in progs)
    assert ATTN_PROGRAM_CACHE.stats()["misses"] == before + 1


def test_runner_reuse_and_cache_stats():
    clear_attention_caches()
    q, k, v = qkv(1, 64, 4, 2, 16)
    prog = compile_attention(heads=4, kv_heads=2, head_dim=16, q_chunk=32,
                             kv_chunk=32, impl="chunked", interpret=True)
    prog.apply(q, k, v)
    misses = attention_cache_stats()["attention_runners"]["misses"]
    prog.apply(q, k, v)                      # same shape: runner reused
    stats = prog.cache_stats()
    assert stats["attention_runners"]["misses"] == misses
    assert stats["attention_runners"]["hits"] >= 1
    assert isinstance(ATTN_PROGRAM_CACHE, ProgramCache)
    assert stats["attention_programs"]["size"] <= \
        stats["attention_programs"]["maxsize"]


def test_arch_config_entry_point():
    """attention_program_for maps config impl names and reuses handles."""
    import repro.configs as C

    cfg = C.get_config("h2o-danube-1.8b").reduced()
    a = attention_program_for(cfg)
    b = attention_program_for(cfg)
    assert a is b
    assert a.spec.heads == cfg.n_heads
    assert a.spec.kv_heads == cfg.kv_heads
    assert a.spec.window == cfg.swa_window
    assert a.impl == "chunked"               # flash_jnp maps to chunked


# ============================================================ validation ==
def test_program_validation_errors():
    with pytest.raises(ValueError, match="kv_heads"):
        compile_attention(heads=6, kv_heads=4, head_dim=16, interpret=True)
    with pytest.raises(ValueError, match="heads and head_dim"):
        compile_attention(heads=4, interpret=True)
    with pytest.raises(ValueError, match="impl"):
        compile_attention(heads=4, head_dim=16, impl="flash",
                          interpret=True)
    with pytest.raises(ValueError, match="float32"):
        compile_attention(heads=4, head_dim=16,
                          compute_dtype=jnp.bfloat16, interpret=True)
    prog = compile_attention(heads=4, kv_heads=2, head_dim=16, q_chunk=32,
                             kv_chunk=32, interpret=True)
    q, k, v = qkv(1, 64, 4, 2, 16)
    with pytest.raises(ValueError, match="compiled for heads"):
        prog.apply(q[:, :, :2], k, v)
    with pytest.raises(ValueError, match="dtype"):
        prog.apply(q.astype(jnp.bfloat16), k, v)
    with pytest.raises(ValueError, match="cotangent"):
        prog.grad(q, k, v, q[:, :32])
    pal = compile_attention(heads=4, kv_heads=2, head_dim=16, q_chunk=32,
                            kv_chunk=32, impl="pallas", interpret=True)
    with pytest.raises(ValueError, match="chunk-divisible"):
        pal.apply(q[:, :63], k[:, :63], v[:, :63])


def test_auto_impl_falls_back_on_undivisible():
    """impl='auto' routes undivisible shapes to the chunked path (which
    itself falls back to dense for short sequences) instead of failing."""
    prog = compile_attention(heads=4, kv_heads=2, head_dim=16, q_chunk=32,
                             kv_chunk=32, impl="auto", interpret=True)
    assert prog._resolve_impl(63, 63) == "chunked"
    q, k, v = qkv(1, 63, 4, 2, 16)
    want = dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(prog.apply(q, k, v)),
                               np.asarray(want), atol=2e-5, rtol=2e-5)


# ======================================================= import hygiene ==
def test_attention_import_initializes_no_backend():
    """compile_attention resolves interpret-vs-native at COMPILE time;
    importing the api package must not touch a backend (tier1.sh gate)."""
    code = (
        "import repro.api\n"
        "from repro.api import compile_attention, AttentionProgram\n"
        "from jax._src import xla_bridge\n"
        "assert not getattr(xla_bridge, '_backends', {}), "
        "'attention import initialized a JAX backend'\n"
        "print('clean')\n"
    )
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode == 0 and "clean" in r.stdout, r.stderr
