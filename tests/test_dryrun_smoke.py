"""Dry-run machinery smoke test: lower+compile a reduced cell sweep in a
child process with 8 placeholder devices (the production run uses 512).

Keeps deliverable (e) guarded in CI without the full 98-cell sweep."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow


def test_dryrun_cells_compile(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m,h2o-danube-1.8b",
         "--shape", "decode_32k,long_500k",
         "--mesh", "smoke", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert len(recs) == 4
    assert all(x["status"] == "ok" for x in recs), recs
    for x in recs:
        assert set(x["terms"]) == {"compute_s", "memory_s", "collective_s"}
        assert x["hlo"]["dot_flops"] >= 0
        assert x["memory"]["peak_per_device"] > 0


def test_dryrun_stencil_cell(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stencil-suite", "--shape", "j3d7pt,j2d5pt",
         "--mesh", "smoke", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert all(x["status"] == "ok" for x in recs)
    # the deep-halo exchanges must appear in the collective stats
    assert any(x["hlo"]["coll_count"].get("collective-permute", 0) > 0
               for x in recs)
