"""Dry-run machinery smoke test: lower+compile a reduced cell sweep in a
child process with 8 placeholder devices (the production run uses 512).

Keeps deliverable (e) guarded in CI without the full 98-cell sweep."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow

# Record schema contract: every "ok" cell must carry the full analysis
# payload (a silent per-cell exception produces "error" + traceback, and
# the jax cost_analysis()-returns-a-list regression surfaced as exactly
# such hidden error cells — hence this explicit schema gate).
OK_KEYS = {"arch", "shape", "mesh", "n_chips", "status", "compile_s",
           "memory", "cost_analysis_raw", "hlo", "terms", "dominant",
           "roofline_fraction", "useful_flops_ratio", "hbm_ok",
           "model_flops"}
MEMORY_KEYS = {"argument_bytes", "output_bytes", "temp_bytes",
               "alias_bytes", "code_bytes", "peak_per_device"}


def assert_ok_schema(rec):
    assert rec["status"] == "ok", rec.get("error", rec)
    missing = OK_KEYS - set(rec)
    assert not missing, f"ok record missing {missing}"
    assert MEMORY_KEYS <= set(rec["memory"])
    assert set(rec["terms"]) == {"compute_s", "memory_s", "collective_s"}
    assert set(rec["cost_analysis_raw"]) == {"flops", "bytes_accessed"}
    # normalized scalars, not the raw list jax 0.4.x hands back
    assert isinstance(rec["cost_analysis_raw"]["flops"], (int, float))
    assert rec["hlo"]["dot_flops"] >= 0
    assert rec["memory"]["peak_per_device"] > 0
    assert rec["compile_s"] >= 0


def test_dryrun_cells_compile(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m,h2o-danube-1.8b",
         "--shape", "decode_32k,long_500k",
         "--mesh", "smoke", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert len(recs) == 4
    for x in recs:
        assert_ok_schema(x)


def test_dryrun_stencil_cell(tmp_path):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stencil-suite", "--shape", "j3d7pt,j2d5pt",
         "--mesh", "smoke", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    recs = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    for x in recs:
        assert_ok_schema(x)
    # the deep-halo exchanges must appear in the collective stats
    assert any(x["hlo"]["coll_count"].get("collective-permute", 0) > 0
               for x in recs)


def test_dryrun_error_cells_are_loud(tmp_path):
    """A cell that raises must surface as status='error' with the
    exception and a traceback in the record — never silently 'ok'."""
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_host_mesh

    rec = run_cell("no-such-arch", "decode_32k", make_host_mesh(1, 1),
                   "smoke", str(tmp_path))
    assert rec["status"] == "error"
    assert "no-such-arch" in rec["error"] or "KeyError" in rec["error"]
    assert "Traceback" in rec["traceback"]
    saved = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)]
    assert saved and saved[0]["status"] == "error"
