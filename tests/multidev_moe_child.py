"""Child test: shard_map EP MoE == pjit MoE == per-token decode, 8 devices."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models import moe as M
from repro.models.params import tree_init

mesh = make_mesh((2, 4), ("data", "model"))
defs, e_pad = M.moe_defs(64, 128, 8, act="swiglu")
p = tree_init(defs, jax.random.PRNGKey(0), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
kw = dict(n_experts=8, n_padded=e_pad, top_k=2, act="swiglu",
          capacity_factor=64.0)
ref, aux_ref = M.apply_moe(x, p, **kw)          # pjit-level reference

with mesh:
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    ps = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(mesh, P())), p)
    y, aux = jax.jit(lambda x, p: M.apply_moe_ep(x, p, mesh=mesh, **kw))(xs, ps)
err = float(jnp.abs(y - ref).max())
# aux load-balance loss: per-data-shard mean of a nonlinear statistic is a
# documented approximation of the global mean (regularizer, not the model)
aerr = abs(float(aux) - float(aux_ref))
assert err < 1e-4, err
assert aerr < 0.05 * float(aux_ref), (float(aux), float(aux_ref))
print(f"EP-vs-pjit maxerr={err:.2e} aux_err={aerr:.2e}")
print("ALL-OK")
