"""Child process: sharded resumable campaigns on 8 faked CPU devices.

Run by ``tests/test_resilient.py::test_sharded_campaigns_on_faked_mesh``
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  Asserts:

  * a sharded campaign (crash + resume) is bit-exact vs ``run_sharded``;
  * a device loss mid-campaign restores elastically onto a smaller mesh
    and completes (numerically close — replanning per the bigger shard
    may legitimately reassociate, so bitwise equality is not claimed);
  * losses past a 1-device mesh resolve to ``CampaignFault('mesh_
    exhausted')``;
  * an elastic resume (checkpoint mesh != live mesh) is allowed under
    ``RetryPolicy(elastic=True)`` and refused under strict.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api.boundary import Boundary
from repro.api.program import compile_stencil
from repro.core.stencil_spec import get
from repro.faults import FaultConfig, FaultInjector, SimClock
from repro.resilient import (CampaignFault, CampaignStore, ResumeMismatch,
                             RetryPolicy, resume_campaign)

SPEC = get("j2d5pt")
SHAPE = (64, 96)
T = 22


class Crash(Exception):
    pass


def main():
    prog = compile_stencil(SPEC, SHAPE, t=4, mesh=(2, 2))
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(SHAPE), jnp.float32)
    ref = np.asarray(prog.run_sharded(x.copy(), T))

    # 1. crash after leg 2, resume: bit-exact vs uninterrupted run_sharded
    store = CampaignStore(tempfile.mkdtemp())

    def killer(leg, steps_done):
        if leg == 2:
            store.wait()
            raise Crash()

    try:
        prog.run_sharded_resumable(x, T, store=store, on_leg=killer)
        raise SystemExit("crash hook never fired")
    except Crash:
        pass
    rep = resume_campaign(prog, store, sharded=True)
    assert rep.resumed_from == 2, rep.resumed_from
    assert (np.asarray(rep.result) == ref).all(), "sharded resume not bit-exact"
    print("sharded-resume: bit-exact OK")

    # 2. device loss at leg 3: elastic restore onto a smaller mesh
    inj = FaultInjector(FaultConfig(device_loss_at_leg=(3,)))
    rep = prog.run_sharded_resumable(
        x, T, store=CampaignStore(tempfile.mkdtemp()), faults=inj,
        clock=SimClock())
    assert rep.mesh_history == [(2, 1)], rep.mesh_history
    assert np.allclose(np.asarray(rep.result), ref, atol=1e-5)
    print("elastic-restore: mesh (2,2)->(2,1) OK")

    # 3. repeated losses bottom out in a typed fault, never a hang
    inj = FaultInjector(FaultConfig(device_loss_at_leg=(1, 2, 3)))
    try:
        prog.run_sharded_resumable(
            x, T, store=CampaignStore(tempfile.mkdtemp()), faults=inj,
            clock=SimClock())
        raise SystemExit("triple device loss did not fault")
    except CampaignFault as e:
        assert e.reason == "mesh_exhausted", e.reason
    print("mesh-exhausted: typed fault OK")

    # 4. elastic resume across a mesh change; strict resume refuses it
    store = CampaignStore(tempfile.mkdtemp())
    try:
        prog.run_sharded_resumable(x, T, store=store, on_leg=killer)
    except Crash:
        pass
    smaller = compile_stencil(SPEC, SHAPE, t=4, mesh=(2, 1))
    try:
        resume_campaign(smaller, store, sharded=True,
                        policy=RetryPolicy(elastic=False))
        raise SystemExit("strict resume across meshes did not refuse")
    except ResumeMismatch:
        pass
    rep = resume_campaign(smaller, store, sharded=True,
                          policy=RetryPolicy(elastic=True))
    assert ("mesh" in [d[0] for d in rep.elastic_drift]), rep.elastic_drift
    assert np.allclose(np.asarray(rep.result), ref, atol=1e-5)
    print("elastic-resume: mesh drift allowed under elastic, refused strict OK")

    print("ALL-OK")


if __name__ == "__main__":
    main()
