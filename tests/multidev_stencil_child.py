"""Child process for multi-device distributed-stencil tests.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent test); asserts distributed == single-device reference, for 1-D and
2-D domain decompositions, deep-halo blocking on/off.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import make_distributed_stencil
from repro.core.stencil_spec import get
from repro.kernels.ref import reference_unrolled
from repro.stencils.data import init_domain


def check(name, spec, shape, dim_to_axis, mesh_shape, axes, t_total, t_block):
    mesh = jax.make_mesh(mesh_shape, axes)
    fn, pspec = make_distributed_stencil(spec, mesh, dim_to_axis, shape,
                                         t_total, t_block)
    x = init_domain(spec, shape)
    xs = jax.device_put(x, NamedSharding(mesh, pspec))
    got = fn(xs)
    want = reference_unrolled(x, spec, t_total)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, f"{name}: maxerr {err}"
    print(f"{name}: OK maxerr={err:.2e}")


def main():
    assert jax.device_count() == 8, jax.device_count()

    # 1-D decomposition of a 2-D stencil, deep halo (t_block=3)
    check("2d5pt-1dshard-deep", get("j2d5pt"), (64, 48), {0: "x"},
          (8,), ("x",), 6, 3)
    # 2-D decomposition of a 2-D box stencil (corners via two-hop), deep halo
    check("2d9pt-gol-2dshard", get("j2d9pt-gol"), (32, 64), {0: "x", 1: "y"},
          (4, 2), ("x", "y"), 4, 2)
    # radius-2 star, 2-D decomposition
    check("2d9pt-2dshard", get("j2d9pt"), (48, 32), {0: "x", 1: "y"},
          (2, 4), ("x", "y"), 4, 2)
    # 3-D stencil, 2-D decomposition over z and y
    check("3d7pt-2dshard", get("j3d7pt"), (32, 16, 20), {0: "z", 1: "y"},
          (4, 2), ("z", "y"), 4, 2)
    # box 3-D (27pt: corners in 3 dims), shallow blocks
    check("3d27pt-2dshard", get("j3d27pt"), (16, 16, 12), {0: "z", 1: "y"},
          (2, 4), ("z", "y"), 2, 1)
    # t_block == t_total (single exchange)
    check("poisson-single-exchange", get("poisson"), (24, 16, 12), {0: "z"},
          (8,), ("z",), 3, 3)
    print("ALL-OK")


if __name__ == "__main__":
    main()
