"""Service-path equivalence and robustness contracts (docs/serving.md).

The load-bearing property: a request resolved through ANY service path —
a full padded batch, a narrower ladder rung, or the degraded solo
``.run`` bottom — returns the same field as calling
``StencilProgram.run`` directly, within 2e-5, for 2-D and 3-D specs
under every boundary family.  Everything else here pins the typed-error
contract: admission, deadlines, poison isolation, and the cache
counters the retry path leans on.
"""
from __future__ import annotations

import threading

import jax.numpy as jnp
import pytest

from repro.api.boundary import Boundary
from repro.api.program import ProgramCache, compile_stencil
from repro.core.stencil_spec import get
from repro.serve.faults import FaultConfig, FaultInjector
from repro.serve.stencil_service import (Expired, InvalidRequest,
                                         PoisonedOutput, Rejected,
                                         ServeRequest, ServiceConfig,
                                         ServiceCore, SimClock,
                                         StencilService)
from repro.stencils.data import init_domain

TOL = 2e-5

CASES = [("j2d5pt", (12, 14)), ("j3d7pt", (6, 8, 5))]
BOUNDARIES = [Boundary.dirichlet(0.0), Boundary.periodic(),
              Boundary.reflect()]


def _core(**over) -> ServiceCore:
    cfg = dict(max_batch=4, batch_window_ms=1.0, max_queue=64,
               max_inflight_per_tenant=64)
    cfg.update(over)
    return ServiceCore(ServiceConfig(**cfg), clock=SimClock())


def _direct(spec, x, total_t, boundary=None):
    prog = compile_stencil(spec, x.shape, t=None, boundary=boundary)
    return prog.run(x, total_t)


# ------------------------------------------------- equivalence property ----
@pytest.mark.parametrize("name,shape", CASES)
@pytest.mark.parametrize("boundary", BOUNDARIES,
                         ids=[b.kind for b in BOUNDARIES])
def test_batched_bucket_matches_direct_run(name, shape, boundary):
    """3 requests through a width-4 bucket (so one row is PADDING) must
    match the direct unbatched program exactly enough."""
    spec = get(name)
    core = _core()
    xs = [init_domain(spec, shape, seed=i) for i in range(3)]
    tks = [core.submit(ServeRequest(spec, x, total_t=4, boundary=boundary))
           for x in xs]
    core.drain()
    assert core.counters["pad_rows"] >= 1
    for x, tk in zip(xs, tks):
        assert tk.ok, tk.error
        want = _direct(spec, x, 4, boundary)
        assert float(jnp.max(jnp.abs(tk.result() - want))) < TOL


@pytest.mark.parametrize("name,shape", CASES)
def test_degraded_ladder_matches_direct_run(name, shape):
    """Under forced OOM above width 2 plus eviction races, every request
    degrades through the ladder yet still matches the direct result."""
    spec = get(name)
    core = _core()
    core.faults = FaultInjector(FaultConfig(seed=3, evict_rate=0.4,
                                            oom_batch_limit=2))
    xs = [init_domain(spec, shape, seed=10 + i) for i in range(6)]
    tks = [core.submit(ServeRequest(spec, x, total_t=4)) for x in xs]
    core.drain()
    assert core.counters["ladder_splits"] >= 1
    for x, tk in zip(xs, tks):
        assert tk.ok, tk.error
        want = _direct(spec, x, 4)
        assert float(jnp.max(jnp.abs(tk.result() - want))) < TOL


def test_unbatched_path_matches_direct_run():
    """max_batch=1: the service bottoms out on ``.run`` and must still
    agree with calling it directly."""
    spec = get("j2d5pt")
    core = _core(max_batch=1)
    x = init_domain(spec, (10, 12), seed=0)
    tk = core.submit(ServeRequest(spec, x, total_t=6))
    core.drain()
    assert tk.ok and tk.batched_width == 1
    assert float(jnp.max(jnp.abs(tk.result() - _direct(spec, x, 6)))) < TOL


# ------------------------------------------------------------- admission ----
def test_queue_full_rejects_typed():
    core = _core(max_queue=2)
    spec = get("j2d5pt")
    xs = [init_domain(spec, (8, 8), seed=i) for i in range(3)]
    tks = [core.submit(ServeRequest(spec, x, total_t=2)) for x in xs]
    assert tks[0].error is None and tks[1].error is None
    assert isinstance(tks[2].error, Rejected)
    assert tks[2].error.reason == "queue_full"
    core.drain()


def test_tenant_cap_rejects_typed():
    core = _core(max_inflight_per_tenant=1)
    spec = get("j2d5pt")
    a = core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=0),
                                 total_t=2, tenant="alice"))
    b = core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=1),
                                 total_t=2, tenant="alice"))
    c = core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=2),
                                 total_t=2, tenant="bob"))
    assert a.error is None and c.error is None
    assert isinstance(b.error, Rejected) and b.error.reason == "tenant_cap"
    core.drain()
    assert a.ok and c.ok


def test_round_robin_prevents_tenant_starvation():
    """Starvation regression: a quiet tenant's single request, submitted
    behind a noisy tenant's burst into the same bucket, must land in the
    FIRST formed batch (per-tenant round-robin slot filling), not wait
    out the whole burst FIFO-style."""
    core = _core(max_batch=4)
    spec = get("j2d5pt")
    noisy = [core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=i),
                                      total_t=2, tenant="noisy"))
             for i in range(8)]
    quiet = core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=99),
                                     total_t=2, tenant="quiet"))
    batches = core.poll(force=True)
    assert len(batches) == 3                      # 9 tickets / max_batch 4
    first = [tk.request.tenant for tk in batches[0].tickets]
    assert "quiet" in first, f"quiet tenant starved: first batch {first}"
    # oldest-first within the noisy tenant is preserved
    assert [tk for tk in batches[0].tickets
            if tk.request.tenant == "noisy"] == noisy[:3]
    assert core.counters["multi_tenant_batches"] == 1
    for b in batches:
        core.dispatch(b)
    assert quiet.ok and all(tk.ok for tk in noisy)


def test_round_robin_single_tenant_is_fifo():
    """With one tenant the fairness path must be the old FIFO exactly."""
    core = _core(max_batch=4)
    spec = get("j2d5pt")
    tks = [core.submit(ServeRequest(spec, init_domain(spec, (8, 8), seed=i),
                                    total_t=2, tenant="solo"))
           for i in range(6)]
    batches = core.poll(force=True)
    assert [tk for b in batches for tk in b.tickets] == tks
    assert core.counters["multi_tenant_batches"] == 0
    for b in batches:
        core.dispatch(b)


def test_oversized_and_invalid_resolve_alone():
    """Validation happens BEFORE coalescing: a poison request can never
    join a bucket."""
    core = _core(max_cells=64)
    spec = get("j2d5pt")
    big = core.submit(ServeRequest(spec, jnp.zeros((16, 16)), total_t=2))
    assert isinstance(big.error, Rejected) and big.error.reason == "oversized"
    wrong_rank = core.submit(ServeRequest(spec, jnp.zeros((8,)), total_t=2))
    assert isinstance(wrong_rank.error, InvalidRequest)
    bad_t = core.submit(ServeRequest(
        spec, jnp.zeros((8, 8)), total_t=-1))
    assert isinstance(bad_t.error, InvalidRequest)
    int_dtype = core.submit(ServeRequest(
        spec, jnp.zeros((8, 8), jnp.int32), total_t=2))
    assert isinstance(int_dtype.error, InvalidRequest)
    assert core.pending() == 0          # nothing joined a bucket


# ------------------------------------------------------------- deadlines ----
def test_deadline_checked_at_every_stage():
    spec = get("j2d5pt")
    x = init_domain(spec, (8, 8), seed=0)

    # admission: already expired never queues
    core = _core()
    tk = core.submit(ServeRequest(spec, x, total_t=2, deadline_ms=0.0))
    assert isinstance(tk.error, Expired) and tk.error.stage == "admission"

    # batch formation: expires while waiting for the window
    core = _core(batch_window_ms=50.0)
    tk = core.submit(ServeRequest(spec, x, total_t=2, deadline_ms=10.0))
    live = core.submit(ServeRequest(spec, x, total_t=2))
    core.clock.advance(30.0)
    for b in core.poll(force=True):
        core.dispatch(b)
    core.drain()
    assert isinstance(tk.error, Expired)
    assert tk.error.stage == "batch_formation"
    assert live.ok                      # the batch-mate still served

    # post-dispatch: injected delay outlives the deadline
    inj = FaultInjector(FaultConfig(seed=0, delay_ms_range=(40, 40)))
    core = _core(batch_window_ms=0.0)
    core.faults = inj
    tk = core.submit(ServeRequest(spec, x, total_t=2, deadline_ms=20.0))
    core.drain()
    assert isinstance(tk.error, Expired)
    assert tk.error.stage == "post_dispatch"


# ------------------------------------------------------ poison isolation ----
@pytest.mark.parametrize("guard,expect", [
    ("reject", PoisonedOutput),
    ("retry_solo", PoisonedOutput),     # solo re-run confirms input poison
    ("propagate", None),
])
def test_nan_input_never_contaminates_batch_mates(guard, expect):
    spec = get("j2d5pt")
    core = _core(guard=guard, batch_window_ms=0.0)
    healthy_x = init_domain(spec, (8, 8), seed=1)
    poison_x = healthy_x.at[3, 3].set(jnp.nan)
    poisoned = core.submit(ServeRequest(spec, poison_x, total_t=2))
    healthy = core.submit(ServeRequest(spec, healthy_x, total_t=2))
    core.drain()
    if expect is None:
        assert poisoned.ok
        assert not bool(jnp.isfinite(poisoned.result()).all())
    else:
        assert isinstance(poisoned.error, expect)
    assert healthy.ok
    want = _direct(spec, healthy_x, 2)
    assert float(jnp.max(jnp.abs(healthy.result() - want))) < TOL


def test_result_raises_typed_error():
    spec = get("j2d5pt")
    core = _core(max_cells=16)
    tk = core.submit(ServeRequest(spec, jnp.zeros((8, 8)), total_t=2))
    with pytest.raises(Rejected):
        tk.result()


# --------------------------------------------------------- cache counters ----
def test_program_cache_concurrent_get_or_build_builds_once():
    cache = ProgramCache(8, name="t")
    builds = []

    def build():
        builds.append(1)
        return "v"

    def worker():
        assert cache.get_or_build("k", build) == "v"

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(builds) == 1
    s = cache.stats()
    assert s["misses"] == 1 and s["hits"] == 7 and s["evictions"] == 0


def test_program_cache_eviction_counter():
    cache = ProgramCache(2, name="t")
    for i in range(4):
        cache.put(i, i)
    assert cache.stats()["evictions"] == 2
    cache.clear()
    assert cache.stats()["evictions"] == 4


# ------------------------------------------------------------ async front ----
def test_asyncio_front_door_round_trip():
    import asyncio

    spec = get("j2d5pt")
    xs = [init_domain(spec, (8, 8), seed=i) for i in range(4)]

    async def go():
        svc = StencilService(ServiceConfig(max_batch=4,
                                           batch_window_ms=1.0))
        await svc.start()
        try:
            ys = await asyncio.gather(
                *[svc.submit(ServeRequest(spec, x, total_t=2)) for x in xs])
        finally:
            await svc.stop()
        return ys, svc.stats()

    ys, stats = asyncio.run(go())
    assert stats["completed"] == 4
    for x, y in zip(xs, ys):
        assert float(jnp.max(jnp.abs(y - _direct(spec, x, 2)))) < TOL
