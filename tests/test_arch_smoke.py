"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs,
plus the strongest serving invariant we have: prefill+decode logits must
equal full-forward logits exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer
from repro.models.params import tree_abstract, tree_init
from repro.train import optimizer as opt
from repro.train.train_step import loss_fn, make_train_step

ARCHS = [a for a in C.list_archs() if a != "stencil-suite"]
KEY = jax.random.PRNGKey(7)


def _batch(cfg, b=2, s=24):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encoder":
        batch = {"frames": jax.random.normal(KEY, (b, s, cfg.d_model)),
                 "mask": jax.random.uniform(KEY, (b, s)) < 0.3,
                 "labels": toks}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.vlm_patches, cfg.vlm_patch_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = C.get_config(arch).reduced()
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    batch = _batch(cfg)
    loss = loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), arch
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))
    hidden, aux = transformer.forward_hidden(
        cfg, params, {k: v for k, v in batch.items() if k != "labels"})
    s = batch.get("tokens", batch.get("frames")).shape[1]
    assert hidden.shape == (2, s, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_flow(arch):
    cfg = C.get_config(arch).reduced()
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    batch = _batch(cfg)
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch))(params)
    norms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(n) for n in norms), arch
    assert max(norms) > 0, f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if C.get_config(a).family != "encoder"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(1) logits == forward(S+1) logits, exactly."""
    cfg = C.get_config(arch).reduced()
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    b, s = 2, 24
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    fb = {"tokens": toks[:, :s]}
    extra = 0
    if cfg.family == "vlm":
        fb["patches"] = jax.random.normal(
            KEY, (b, cfg.vlm_patches, cfg.vlm_patch_dim))
        extra = cfg.vlm_patches
    pf, cache = transformer.prefill(cfg, params, fb, cache_len=s + extra + 8)
    hid, _ = transformer.forward_hidden(cfg, params, fb)
    full = transformer.logits_fn(cfg, params, hid)
    np.testing.assert_allclose(np.asarray(pf[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)
    l1, cache = transformer.decode_step(cfg, params, cache, toks[:, s:s + 1],
                                        jnp.int32(s + extra))
    fb2 = dict(fb)
    fb2["tokens"] = toks[:, :s + 1]
    hid2, _ = transformer.forward_hidden(cfg, params, fb2)
    full2 = transformer.logits_fn(cfg, params, hid2)
    np.testing.assert_allclose(np.asarray(l1[:, 0]), np.asarray(full2[:, -1]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mamba2-130m",
                                  "granite-moe-3b-a800m"])
def test_train_step_decreases_loss(arch):
    cfg = C.get_config(arch).reduced()
    ocfg = opt.OptConfig(lr=1e-2, warmup=1, total_steps=50,
                         schedule=cfg.schedule)
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    from repro.train.optimizer import opt_state_defs
    state = tree_init(opt_state_defs(transformer.param_defs(cfg),
                                     data_size=1), KEY)
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = _batch(cfg, b=4, s=16)          # fixed batch: loss must drop
    losses = []
    for _ in range(8):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, (arch, losses)
    assert int(state["count"]) == 8


def test_microbatched_grad_accumulation_matches():
    """microbatches=K must give (numerically) the same step as K=1."""
    import dataclasses
    cfg = C.get_config("h2o-danube-1.8b").reduced()
    ocfg = opt.OptConfig(lr=1e-3, warmup=1)
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    from repro.train.optimizer import opt_state_defs
    state = tree_init(opt_state_defs(transformer.param_defs(cfg),
                                     data_size=1), KEY)
    batch = _batch(cfg, b=4, s=16)
    p1, _, m1 = jax.jit(make_train_step(cfg, ocfg))(params, state, batch)
    cfg2 = dataclasses.replace(cfg, microbatches=2)
    p2, _, m2 = jax.jit(make_train_step(cfg2, ocfg))(params, state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_wsd_schedule_shape():
    ocfg = opt.OptConfig(lr=1.0, warmup=10, total_steps=100, schedule="wsd")
    lrs = [float(opt.schedule_lr(ocfg, jnp.int32(s))) for s in range(100)]
    assert lrs[5] < lrs[15]                        # warmup rises
    assert abs(lrs[40] - lrs[70]) < 1e-6           # stable plateau
    assert lrs[99] < lrs[70]                       # decay at the end


def test_checkpoint_roundtrip(tmp_path):
    from repro.train import checkpoint as ckpt
    cfg = C.get_config("mamba2-130m").reduced()
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    ckpt.save(str(tmp_path), 3, {"params": params}, block=True)
    assert ckpt.latest_step(str(tmp_path)) == 3
    like = {"params": tree_abstract(transformer.param_defs(cfg),
                                    cfg.param_dtype)}
    restored = ckpt.restore(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    from repro.train.data import batch_for_step
    cfg = C.get_config("qwen3-14b").reduced()
    spec = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    b1 = batch_for_step(cfg, "train_4k", 7, seed=1, reduced_shapes=spec)
    b2 = batch_for_step(cfg, "train_4k", 7, seed=1, reduced_shapes=spec)
    b3 = batch_for_step(cfg, "train_4k", 8, seed=1, reduced_shapes=spec)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-2.7b"])
def test_ssm_boundary_stub_mode(arch):
    """The fused-SSD dry-run stand-in keeps shapes/dtypes and finite loss
    (it is an accounting stub, not a numerical replacement)."""
    import dataclasses
    cfg = dataclasses.replace(C.get_config(arch).reduced(),
                              ssm_impl="boundary_stub")
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    batch = _batch(cfg)
    loss = loss_fn(cfg, params, batch)
    assert loss.shape == () and not bool(jnp.isnan(loss))


def test_attention_boundary_stub_mode():
    import dataclasses
    cfg = dataclasses.replace(C.get_config("qwen3-14b").reduced(),
                              attention_impl="boundary_stub")
    params = tree_init(transformer.param_defs(cfg), KEY, cfg.param_dtype)
    loss = loss_fn(cfg, params, _batch(cfg))
    assert loss.shape == () and not bool(jnp.isnan(loss))
