"""Coupled multi-field systems: the three shipped systems vs an
independent per-step numpy oracle across the full boundary × depth
matrix, fused-chain ≡ lockstep equivalence, signature cache-keying,
JSON round-trip, and the structural refusals."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import Boundary, spec_from_json
from repro.systems import (SystemSpec, compile_system, define_system,
                           get_system, system_from_json, system_names,
                           system_to_json)
from repro.systems.reactions import resolve_reaction

SHAPE = (28, 24)
SYSTEM_NAMES = ("gray-scott", "fdtd-acoustic", "advection-diffusion")
BOUNDARIES = [Boundary.periodic(), Boundary.neumann(),
              Boundary.dirichlet(0.3)]

IDENT = (((0, 0), 1.0),)
LAP01 = (((0, 0), 0.6), ((0, 1), 0.1), ((0, -1), 0.1),
         ((1, 0), 0.1), ((-1, 0), 0.1))


def fields_for(spec, shape=SHAPE, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    shp = shape if batch is None else (batch,) + shape
    return {f: jnp.asarray(rng.uniform(0.2, 0.8, shp).astype(np.float32))
            for f in spec.fields}


# ------------------------------------------------ independent oracle -------
# Deliberately NOT the tap engine or the systems executor: plain numpy
# pad + slice arithmetic, one boundary fill per step.

def oracle_extend(x, rad, b):
    x = np.asarray(x)
    if b.kind == "dirichlet":
        return np.pad(x, rad, constant_values=b.value)
    if b.kind == "periodic":
        return np.pad(x, rad, mode="wrap")
    if b.kind == "reflect":
        return np.pad(x, rad, mode="reflect")
    xe = np.pad(x, rad, mode="symmetric")
    if b.value:
        for a in range(x.ndim):
            n = x.shape[a]
            i = np.arange(xe.shape[a])
            dist = np.maximum(np.maximum(rad - i, i - (rad + n - 1)), 0)
            sh = [1] * x.ndim
            sh[a] = -1
            xe = xe + (dist * b.value).reshape(sh)
    return xe


def oracle_step(fields, spec, b):
    rad = spec.radius
    shape = next(iter(fields.values())).shape
    ext = {f: oracle_extend(fields[f], rad, b) for f in spec.fields}
    lin = {}
    for (dst, src), taps in spec.couplings:
        acc = np.zeros(shape)
        for off, c in taps:
            sl = tuple(slice(rad + o, rad + o + n)
                       for o, n in zip(off, shape))
            acc += c * ext[src][sl]
        lin[dst] = lin.get(dst, 0.0) + acc
    if spec.reaction is None:
        return lin
    rx = resolve_reaction(spec.reaction)
    prev = {f: np.asarray(fields[f]) for f in spec.fields}
    return {f: np.asarray(v) for f, v in rx(lin, prev).items()}


def oracle(fields, spec, total_t, b):
    cur = {f: np.asarray(v, np.float64) for f, v in fields.items()}
    for _ in range(total_t):
        cur = oracle_step(cur, spec, b)
    return cur


# ================================================== oracle matrix ==========
@pytest.mark.parametrize("boundary", BOUNDARIES, ids=lambda b: b.kind)
@pytest.mark.parametrize("t", [1, 2, 4])
@pytest.mark.parametrize("name", SYSTEM_NAMES)
def test_system_matches_oracle(name, t, boundary):
    """All three shipped systems × t ∈ {1,2,4} × {periodic, neumann,
    dirichlet}: the fused multi-field chain (remainder sweep included)
    matches the independent per-step oracle to < 2e-5."""
    spec = get_system(name)
    f0 = fields_for(spec)
    prog = compile_system(spec, SHAPE, t=t, boundary=boundary)
    total = 2 * t + 1
    out = prog.run(f0, total)
    want = oracle(f0, spec, total, boundary)
    for f in spec.fields:
        err = float(np.abs(np.asarray(out[f]) - want[f]).max())
        assert err < 2e-5, (name, f, t, boundary, err)


@pytest.mark.parametrize("name", SYSTEM_NAMES)
def test_fused_chain_equals_lockstep(name):
    """The fused trapezoid chain ≡ the per-field-per-step lockstep
    reference — same trajectory, wildly different dispatch count."""
    spec = get_system(name)
    f0 = fields_for(spec)
    for boundary in (Boundary.periodic(), Boundary.neumann()):
        prog = compile_system(spec, SHAPE, t=4, boundary=boundary)
        out = prog.run(f0, 8)
        ref = prog.run_lockstep(f0, 8)
        for f in spec.fields:
            np.testing.assert_allclose(
                np.asarray(out[f]), np.asarray(ref[f]),
                atol=2e-5, rtol=2e-5, err_msg=f"{name}/{f}/{boundary!r}")


def test_apply_and_run_batched():
    spec = get_system("gray-scott")
    prog = compile_system(spec, SHAPE, t=3, boundary=Boundary.periodic())
    f0 = fields_for(spec)
    # apply == run at the compiled depth
    a = prog.apply(f0)
    r = prog.run(f0, 3)
    for f in spec.fields:
        np.testing.assert_allclose(np.asarray(a[f]), np.asarray(r[f]),
                                   atol=1e-6, rtol=1e-6)
    # one vmapped dispatch == a loop of per-field runs
    fb = fields_for(spec, batch=3)
    outs = prog.run_batched(fb, 7)
    for i in range(3):
        one = prog.run({f: fb[f][i] for f in spec.fields}, 7)
        for f in spec.fields:
            np.testing.assert_allclose(
                np.asarray(outs[f][i]), np.asarray(one[f]),
                atol=1e-5, rtol=1e-5, err_msg=f"batch elem {i}/{f}")
    assert prog.run(f0, 0)["u"] is f0["u"]


# ============================================ signature / cache keying =====
def test_signature_cache_keying():
    """Programs are memoized on the system *signature*: structurally
    identical systems share a program regardless of name; any change to
    couplings, reaction params, depth, or boundary splits the key."""
    gs = get_system("gray-scott")
    renamed = SystemSpec(**{**gs.__dict__, "name": "my-gs"})
    a = compile_system(gs, SHAPE, t=2)
    assert compile_system(renamed, SHAPE, t=2) is a
    assert compile_system(gs, SHAPE, t=3) is not a
    assert compile_system(gs, SHAPE, t=2,
                          boundary=Boundary.periodic()) is not a
    tweaked = get_system("gray-scott", F=0.04)
    assert tweaked.signature != gs.signature
    assert compile_system(tweaked, SHAPE, t=2) is not a
    # JSON round-trip preserves the signature, hence the program
    rt = system_from_json(system_to_json(gs))
    assert rt.signature == gs.signature
    assert compile_system(rt, SHAPE, t=2) is a


def test_json_round_trip_and_dispatch():
    for name in SYSTEM_NAMES:
        spec = get_system(name)
        rt = system_from_json(system_to_json(spec))
        assert rt.signature == spec.signature
        assert rt.name == spec.name and rt.fields == spec.fields
    # repro.api.spec_from_json dispatches on the "fields" key
    obj = system_to_json(get_system("advection-diffusion"))
    spec = spec_from_json(obj)
    assert isinstance(spec, SystemSpec)
    assert spec.fields == ("a", "b")
    with pytest.raises(ValueError, match="'fields' and 'couplings'"):
        system_from_json({"fields": ["u"]})


def test_library_and_cost_model():
    assert system_names() == sorted(SYSTEM_NAMES)
    with pytest.raises(KeyError, match="unknown system"):
        get_system("navier-stokes")
    gs = get_system("gray-scott")
    assert gs.radius == 1 and gs.ndim == 2 and gs.nfields == 2
    # flops: 2 per tap summed over couplings (5+5 taps) + reaction
    per = gs.per_field_flops()
    assert per["u"] == per["v"] and sum(per.values()) == gs.flops_per_cell
    assert gs.a_gm == 4.0                       # 2 per field
    prog = compile_system(gs, SHAPE, t=2)
    c = prog.cost()
    assert c["flops_per_step"] == gs.flops_per_cell * SHAPE[0] * SHAPE[1]
    assert c["hbm_bytes_per_step"] == 4.0 * SHAPE[0] * SHAPE[1] * 4
    stats = prog.cache_stats()
    assert {"system_programs", "system_runners"} <= set(stats)


# ================================================= structural refusals =====
def test_refusals():
    # dangling coupling endpoint
    with pytest.raises(ValueError, match="dangling source 'w'"):
        define_system(["u"], {("u", "w"): LAP01})
    with pytest.raises(ValueError, match="dangling destination 'w'"):
        define_system(["u"], {("w", "u"): LAP01})
    # duplicate field names
    with pytest.raises(ValueError, match="duplicate field"):
        define_system(["u", "u"], {("u", "u"): LAP01})
    # a field no coupling updates
    with pytest.raises(ValueError, match="destination of no coupling"):
        define_system(["u", "v"], {("u", "u"): LAP01})
    # identity-only everywhere: no spatial coupling to block over
    with pytest.raises(ValueError, match="radius is 0"):
        define_system(["u", "v"], {("u", "v"): IDENT, ("v", "u"): IDENT,
                                   ("u", "u"): IDENT, ("v", "v"): IDENT})
    # per-pair radius > 8 refused by the shared tap validation
    far = (((0, 0), 0.5), ((0, 9), 0.5))
    with pytest.raises(ValueError, match="radius 9 exceeds"):
        define_system(["u"], {("u", "u"): far})
    # unknown reaction named at define time, registry listed
    with pytest.raises(ValueError, match="unknown reaction 'nope'"):
        define_system(["u"], {("u", "u"): LAP01}, reactions="nope")
    # mismatched field shapes at run time
    spec = get_system("gray-scott")
    prog = compile_system(spec, SHAPE, t=1)
    f0 = fields_for(spec)
    bad = dict(f0, v=jnp.zeros((8, 8), jnp.float32))
    with pytest.raises(ValueError, match="every field shares one domain"):
        prog.run(bad, 2)
    with pytest.raises(ValueError, match="has fields"):
        prog.run({"u": f0["u"]}, 2)
    # mixed-dimensionality couplings (each internally consistent)
    lap3 = (((0, 0, 0), 0.5), ((0, 0, 1), 0.25), ((0, 0, -1), 0.25))
    with pytest.raises(ValueError, match="share one dimensionality"):
        define_system(["u", "v"], {("u", "u"): LAP01, ("v", "v"): lap3})
    # shape/radius validation at compile time
    with pytest.raises(ValueError, match="halo would cover"):
        compile_system(spec, (3, 3), t=1)
    with pytest.raises(ValueError, match="is 2-D"):
        compile_system(spec, (16, 16, 16), t=1)
    with pytest.raises(ValueError, match="depth must be >= 1"):
        compile_system(spec, SHAPE, t=0)


def test_radius_zero_cross_coupling_allowed():
    """Identity-only couplings (radius 0) are legitimate as long as the
    system radius clears 1 — the advection-diffusion exchange case."""
    spec = define_system(
        ["u", "v"],
        {("u", "u"): LAP01, ("u", "v"): (((0, 0), 0.05),),
         ("v", "v"): IDENT, ("v", "u"): (((0, 0), -0.05),)})
    assert spec.radius == 1
    prog = compile_system(spec, SHAPE, t=2, boundary=Boundary.neumann())
    f0 = fields_for(spec)
    out = prog.run(f0, 4)
    want = oracle(f0, spec, 4, Boundary.neumann())
    for f in spec.fields:
        assert float(np.abs(np.asarray(out[f]) - want[f]).max()) < 2e-5
