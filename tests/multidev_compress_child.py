"""Child test: int8 compressed psum — unbiasedness, error bound, training
parity on an 8-device data-parallel mesh."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import shard_map_compat
from repro.launch.mesh import make_mesh
from repro.train.compress import (compressed_psum, compressed_psum_tree,
                                  make_compressed_allreduce_step)

mesh = make_mesh((8,), ("data",))

# ---- error bound: |compressed_psum - psum| <= n_shards * max_scale --------
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
xs = jax.device_put(x, NamedSharding(mesh, P("data")))


def f(x, key):
    return compressed_psum(x, "data", key)


got = jax.jit(shard_map_compat(f, mesh, in_specs=(P("data"), P()),
                               out_specs=P("data")))(
    xs, jax.random.PRNGKey(1))
want = jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape)
bound = 8 * float(jnp.abs(x).max()) / 127.0
err = float(jnp.abs(got - want).max())
assert err <= bound + 1e-5, (err, bound)
print(f"psum err {err:.4f} <= bound {bound:.4f}")

# ---- unbiasedness: mean over many keys converges to the true sum ---------
samples = []
for i in range(64):
    samples.append(np.asarray(jax.jit(shard_map_compat(
        f, mesh, in_specs=(P("data"), P()),
        out_specs=P("data")))(xs, jax.random.PRNGKey(100 + i))))
bias = np.abs(np.mean(samples, axis=0) - np.asarray(want)).max()
assert bias < 0.1 * bound, (bias, bound)
print(f"bias {bias:.4f} (stochastic rounding unbiased)")

# ---- training parity: compressed DP-SGD reaches a similar loss ------------
w_true = jax.random.normal(jax.random.PRNGKey(2), (16,))


def loss_fn(params, batch):
    x, y = batch
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)


k = jax.random.PRNGKey(3)
X = jax.random.normal(k, (64, 16))
Y = X @ w_true
Xs = jax.device_put(X, NamedSharding(mesh, P("data")))
Ys = jax.device_put(Y, NamedSharding(mesh, P("data")))
params = {"w": jnp.zeros((16,))}
step = make_compressed_allreduce_step(loss_fn, mesh, "data", lr=0.05)
for i in range(200):
    params = step(params, (Xs, Ys), jax.random.PRNGKey(i))
final = float(loss_fn(params, (X, Y)))
assert final < 0.05, final
print(f"compressed-DP-SGD final loss {final:.4f}")
print("ALL-OK")
