"""Resumable-campaign contracts (docs/resilience.md, DESIGN.md §14).

The load-bearing property: a campaign that crashes and resumes — at any
leg boundary, with any of the injected faults along the way — produces a
final field **bit-exact** equal to the uninterrupted
``StencilProgram.run(x, T)``, across 2-D/3-D specs and boundary
families.  Everything else pins the bounded-recovery contract: every
injected fault resolves to a recovery or a typed ``CampaignFault``,
deterministically under a seeded injector and a simulated clock — never
a hang, never a raw traceback.

Sharded-campaign assertions (bit-exact resume over a mesh, elastic
restore after device loss) run in a child process with 8 faked CPU
devices (``multidev_resilient_child.py``), per the multi-device
isolation rule in ``tests/test_sharded.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.boundary import Boundary
from repro.api.program import compile_stencil
from repro.core.stencil_spec import get
from repro.faults import FaultConfig, FaultInjector, SimClock
from repro.resilient import (CampaignFault, CampaignStore, HealthEnvelope,
                             HealthViolation, ResumeMismatch, RetryPolicy,
                             leg_schedule, resume_campaign, run_campaign)
from repro.resilient.health import probe
from repro.stencils.data import init_domain

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASES = [("j2d5pt", (12, 14)), ("j3d7pt", (6, 8, 5))]
BOUNDARIES = [Boundary.dirichlet(0.0), Boundary.periodic()]
T_TOTAL = 11      # with t=2: legs of 2 steps + a remainder leg of 1

_PROGS: dict = {}


def _prog(name, shape, boundary):
    key = (name, shape, boundary)
    if key not in _PROGS:
        _PROGS[key] = compile_stencil(get(name), shape, t=2,
                                      boundary=boundary)
    return _PROGS[key]


def _setup(name, shape, boundary):
    prog = _prog(name, shape, boundary)
    x = init_domain(get(name), shape)
    ref = prog.run(x, T_TOTAL)
    return prog, x, np.asarray(ref)


def _bitexact(a, b) -> bool:
    return (np.asarray(a) == np.asarray(b)).all()


class Crash(Exception):
    """Stands in for SIGKILL inside one test process."""


def _crash_after(leg_idx, store=None):
    def hook(leg, steps_done):
        if leg == leg_idx:
            if store is not None:
                store.wait()       # post-leg: the checkpoint landed
            raise Crash()
    return hook


# ------------------------------------------------ bit-exact resumption ----
@pytest.mark.parametrize("name,shape", CASES)
@pytest.mark.parametrize("boundary", BOUNDARIES,
                         ids=[b.kind for b in BOUNDARIES])
@pytest.mark.parametrize("interrupt", ["post_leg", "mid_save"])
def test_resumed_campaign_bitexact(tmp_path, name, shape, boundary,
                                   interrupt):
    """Crash after leg 2 — either after its checkpoint landed (post-leg)
    or with that save dying mid-``tmp`` (a mid-leg/mid-save crash, the
    leg is lost and replayed) — then resume: the final field must equal
    the uninterrupted ``run`` bitwise."""
    prog, x, ref = _setup(name, shape, boundary)
    store = CampaignStore(str(tmp_path))
    faults = None
    if interrupt == "mid_save":
        faults = FaultInjector(FaultConfig(crash_save_at_leg=(2,)))
    with pytest.raises(Crash):
        run_campaign(prog, x, T_TOTAL, store=store, faults=faults,
                     on_leg=_crash_after(2, store))
    rep = resume_campaign(prog, store)
    assert rep.resumed_from == (2 if interrupt == "post_leg" else 1)
    assert _bitexact(rep.result, ref)


@pytest.mark.parametrize("every", [1, 2, 5])
def test_fresh_campaign_matches_run(tmp_path, every):
    """No crash at all: the legged executor IS ``run``, for any leg
    width (including one wider than the whole campaign)."""
    prog, x, ref = _setup("j2d5pt", (12, 14), Boundary.periodic())
    rep = prog.run_resumable(x, T_TOTAL, store=str(tmp_path / str(every)),
                             every=every)
    assert _bitexact(rep.result, ref)
    assert rep.legs_run == rep.legs_total == len(
        leg_schedule(T_TOTAL, prog.t, every))


def test_run_resumable_zero_steps(tmp_path):
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    rep = prog.run_resumable(x, 0, store=str(tmp_path))
    assert _bitexact(rep.result, x) and rep.legs_total == 0


def test_leg_schedule_alignment():
    assert leg_schedule(10, 4, 1) == [(1, 4), (2, 4), (3, 2)]
    assert leg_schedule(16, 4, 2) == [(1, 8), (2, 8)]
    assert leg_schedule(3, 8, 1) == [(1, 3)]
    assert leg_schedule(0, 4, 1) == []
    with pytest.raises(ValueError):
        leg_schedule(4, 4, 0)


# ------------------------------------------------- fault -> recovery ----
def test_nan_leg_rolls_back_and_recovers(tmp_path):
    """A one-shot NaN blow-up at leg 3: health catches it in the fused
    probe, the runner rolls back one leg and the clean retry proceeds —
    still bit-exact."""
    prog, x, ref = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    clk = SimClock()
    inj = FaultInjector(FaultConfig(nan_at_leg=(3,)))
    rep = run_campaign(prog, x, T_TOTAL, store=str(tmp_path), faults=inj,
                       clock=clk)
    assert _bitexact(rep.result, ref)
    assert rep.rollbacks == 1 and rep.retries == 1
    assert rep.faults_injected["nan_leg"] == 1
    assert clk.now_ms() > 0          # backoff advanced the injected clock


def test_persistent_nan_exhausts_into_typed_fault(tmp_path):
    """NaN re-injected on every retry: the bounded ladder must end in
    ``CampaignFault('health')`` pinned to the leg — the no-hang case."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    inj = FaultInjector(FaultConfig(nan_at_leg=(3,), nan_persistent=True))
    with pytest.raises(CampaignFault) as ei:
        run_campaign(prog, x, T_TOTAL, store=str(tmp_path), faults=inj,
                     clock=SimClock(), policy=RetryPolicy(max_retries=2))
    assert ei.value.reason == "health" and ei.value.leg == 3
    assert isinstance(ei.value.__cause__, HealthViolation)


def test_corrupt_checkpoint_skipped_at_rollback(tmp_path):
    """Leg 2's checkpoint is corrupted on disk; the NaN at leg 3 forces
    a rollback, which must skip the bad checkpoint (checksum refusal),
    land on leg 1, and replay — bit-exact."""
    prog, x, ref = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    inj = FaultInjector(FaultConfig(corrupt_ckpt_at_leg=(2,),
                                    nan_at_leg=(3,)))
    rep = run_campaign(prog, x, T_TOTAL, store=str(tmp_path), faults=inj,
                       clock=SimClock())
    assert _bitexact(rep.result, ref)
    assert [leg for leg, _ in rep.corrupt_skipped] == [2]


def test_all_checkpoints_corrupt_is_typed(tmp_path):
    """Every payload on disk flipped after the crash: resume must refuse
    with ``CampaignFault('checkpoints_corrupt')``, not restart silently
    from garbage."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    store = CampaignStore(str(tmp_path))
    with pytest.raises(Crash):
        run_campaign(prog, x, T_TOTAL, store=store,
                     on_leg=_crash_after(2, store))
    from repro.resilient.store import PAYLOAD, _flip_payload_bytes
    for leg in store.legs():
        _flip_payload_bytes(os.path.join(store.root, f"leg_{leg}", PAYLOAD))
    with pytest.raises(CampaignFault) as ei:
        resume_campaign(prog, store)
    assert ei.value.reason == "checkpoints_corrupt"


def test_resume_without_checkpoint_is_typed(tmp_path):
    prog, _, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    with pytest.raises(CampaignFault) as ei:
        resume_campaign(prog, CampaignStore(str(tmp_path)))
    assert ei.value.reason == "no_checkpoint"


def test_resume_fingerprint_mismatch_refused(tmp_path):
    """A checkpoint written under one program must refuse to resume
    under a drifted one — wrong depth, wrong boundary — and the error
    names each mismatched field with its fix."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    store = CampaignStore(str(tmp_path))
    with pytest.raises(Crash):
        run_campaign(prog, x, T_TOTAL, store=store,
                     on_leg=_crash_after(2, store))
    drifted = compile_stencil(get("j2d5pt"), (12, 14), t=3,
                              boundary=Boundary.periodic())
    with pytest.raises(ResumeMismatch) as ei:
        resume_campaign(drifted, store)
    msg = str(ei.value)
    assert "t:" in msg and "boundary:" in msg and "fix:" in msg


def test_permanent_error_is_not_retried(tmp_path):
    """A genuine bug in the loop surfaces as ``CampaignFault('internal')``
    on the first hit — no rollback/retry burn."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))

    class Boom(HealthEnvelope):
        def judge(self, **kw):
            raise TypeError("boom")

    with pytest.raises(CampaignFault) as ei:
        run_campaign(prog, x, T_TOTAL, store=str(tmp_path), health=Boom(),
                     clock=SimClock())
    assert ei.value.reason == "internal" and "TypeError" in str(ei.value)


# ------------------------------------------------------ health envelope ----
def test_health_envelope_judgements():
    env = HealthEnvelope(max_growth=1.5, max_rms=10.0)
    env.judge(finite=True, rms=1.0, prev_rms=0.9, leg=1)       # healthy
    with pytest.raises(HealthViolation) as ei:
        env.judge(finite=False, rms=float("nan"), prev_rms=None, leg=2)
    assert ei.value.reason == "nonfinite"
    with pytest.raises(HealthViolation) as ei:
        env.judge(finite=True, rms=11.0, prev_rms=10.5, leg=3)
    assert ei.value.reason == "rms_ceiling"
    with pytest.raises(HealthViolation) as ei:
        env.judge(finite=True, rms=2.0, prev_rms=1.0, leg=4)
    assert ei.value.reason == "rms_drift"


def test_probe_is_one_fused_reduction():
    finite, rms = probe(jnp.ones((4, 4)))
    assert finite and rms == pytest.approx(1.0)
    finite, _ = probe(jnp.array([[1.0, float("inf")], [0.0, 2.0]]))
    assert not finite


def test_rms_envelope_trips_campaign(tmp_path):
    """An absurdly tight rms ceiling turns a healthy run into a typed
    health fault — the drift guard is live end-to-end."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    with pytest.raises(CampaignFault) as ei:
        run_campaign(prog, x, T_TOTAL, store=str(tmp_path),
                     health=HealthEnvelope(max_rms=1e-30),
                     clock=SimClock(), policy=RetryPolicy(max_retries=1))
    assert ei.value.reason == "health"


# ---------------------------------------------------------- store unit ----
def test_store_atomicity_and_prune(tmp_path):
    store = CampaignStore(str(tmp_path), keep=2)
    x = np.arange(12.0, dtype=np.float32).reshape(3, 4)
    for leg in (1, 2, 3):
        store.save(leg, x * leg, {"steps_done": leg}, block=True)
    assert store.legs() == [2, 3]          # pruned to keep=2
    leg, arr, man, skipped = store.load_latest_good()
    assert leg == 3 and man["steps_done"] == 3 and not skipped
    assert (arr == x * 3).all()
    # a crashed save leaves only an invisible tmp dir
    store.save(4, x, {"steps_done": 4}, block=True, sabotage="crash")
    assert store.latest_leg() == 3
    assert any(".tmp" in d for d in os.listdir(tmp_path))


def test_store_checksum_refuses_corrupt_payload(tmp_path):
    from repro.resilient.store import CorruptCheckpoint
    store = CampaignStore(str(tmp_path))
    x = np.ones((5, 5), np.float32)
    store.save(1, x, {"steps_done": 1}, block=True)
    store.save(2, x * 2, {"steps_done": 2}, block=True, sabotage="corrupt")
    with pytest.raises(CorruptCheckpoint):
        store.load(2)
    leg, _, _, skipped = store.load_latest_good()
    assert leg == 1 and [s[0] for s in skipped] == [2]


def test_store_manifest_garbage_is_corrupt(tmp_path):
    from repro.resilient.store import MANIFEST, CheckpointError
    store = CampaignStore(str(tmp_path))
    store.save(1, np.ones(3, np.float32), {"steps_done": 1}, block=True)
    with open(os.path.join(store.root, "leg_1", MANIFEST), "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError):
        store.load_latest_good()


# --------------------------------------------------------- seeded soak ----
def _soak(seed: int, tmp_path) -> dict:
    prog, x, ref = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    cfg = FaultConfig(seed=seed, nan_at_leg=(2, 4),
                      corrupt_ckpt_at_leg=(3,), crash_save_at_leg=(5,))
    inj, clk = FaultInjector(cfg), SimClock()
    store = CampaignStore(str(tmp_path / f"s{seed}"))
    try:
        rep = run_campaign(prog, x, T_TOTAL, store=store, faults=inj,
                           clock=clk)
        out = {"outcome": "ok", "bitexact": _bitexact(rep.result, ref),
               "rollbacks": rep.rollbacks, "retries": rep.retries,
               "injected": rep.faults_injected}
    except CampaignFault as e:
        out = {"outcome": e.reason, "injected": inj.stats()}
    out["clock_ms"] = round(clk.now_ms(), 6)
    return out


def test_soak_every_fault_resolves_deterministically(tmp_path):
    """The acceptance soak, short form: under a mixed fault diet every
    campaign either completes bit-exact or resolves to a typed
    ``CampaignFault`` — and rerunning a seed reproduces the identical
    outcome, clock included."""
    for seed in (0, 1):
        a = _soak(seed, tmp_path / "a")
        b = _soak(seed, tmp_path / "b")
        assert a == b
        assert a["outcome"] == "ok" and a["bitexact"]


@pytest.mark.slow
def test_soak_long_seeded(tmp_path):
    """Longer soak across more seeds and heavier fault diets (slow tier)."""
    for seed in range(6):
        prog, x, ref = _setup("j2d5pt", (12, 14), Boundary.periodic())
        cfg = FaultConfig(seed=seed, nan_at_leg=(1, 3, 5),
                          corrupt_ckpt_at_leg=(2, 4),
                          crash_save_at_leg=(3,),
                          nan_persistent=(seed % 3 == 2))
        inj, clk = FaultInjector(cfg), SimClock()
        try:
            rep = run_campaign(prog, x, T_TOTAL,
                               store=str(tmp_path / f"L{seed}"),
                               faults=inj, clock=clk,
                               policy=RetryPolicy(max_retries=2, seed=seed))
            assert _bitexact(rep.result, ref)
        except CampaignFault as e:
            assert e.reason in ("health", "retries_exhausted")


# ------------------------------------------------------ sharded (child) ----
@pytest.mark.slow
def test_sharded_campaigns_on_faked_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests",
                                      "multidev_resilient_child.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"child failed:\n{r.stdout[-4000:]}\n{r.stderr[-4000:]}"
    assert "ALL-OK" in r.stdout


# --------------------------------------------------- CLI crash-restart ----
@pytest.mark.slow
def test_cli_kill_and_resume_bitexact(tmp_path):
    """The CI smoke, as a test: run, SIGKILL after leg 2 (exit 137),
    resume with ``--resume auto``, diff against the uninterrupted run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    base = [sys.executable, "-m", "repro.launch.stencil_run",
            "--stencil", "j2d5pt", "--scale", "48", "--T", "24"]
    ref, out = str(tmp_path / "ref.npy"), str(tmp_path / "out.npy")
    r = subprocess.run(base + ["--checkpoint-dir", str(tmp_path / "a"),
                               "--out", ref],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run(base + ["--checkpoint-dir", str(tmp_path / "b"),
                               "--kill-after-leg", "2"],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == -9 or r.returncode == 137
    r = subprocess.run(base + ["--checkpoint-dir", str(tmp_path / "b"),
                               "--resume", "auto", "--out", out],
                       env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed@leg2" in r.stdout
    assert (np.load(ref) == np.load(out)).all()


def test_report_is_json_serializable(tmp_path):
    """Operators log reports; everything but the array must serialize."""
    prog, x, _ = _setup("j2d5pt", (12, 14), Boundary.dirichlet(0.0))
    rep = prog.run_resumable(x, T_TOTAL, store=str(tmp_path))
    d = {k: v for k, v in rep.__dict__.items() if k != "result"}
    json.dumps(d)
