"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracle.

The Pallas kernels run in interpret mode on CPU (the TPU lowering path is
exercised structurally by the BlockSpecs; numerics are identical).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil_spec import TABLE2, get
from repro.kernels import ops, ref
from repro.stencils.data import init_domain

SPECS_2D = [s for s in TABLE2.values() if s.ndim == 2]
SPECS_3D = [s for s in TABLE2.values() if s.ndim == 3]


def _check(got, want, dtype):
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", [(40, 56), (33, 129), (64, 64)])
@pytest.mark.parametrize("t", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ebisu2d_matches_reference(spec, shape, t, dtype):
    x = init_domain(spec, shape, dtype=dtype)
    want = ref.reference_unrolled(x.astype(jnp.float32), spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    assert got.dtype == x.dtype
    assert got.shape == x.shape
    _check(got, want, dtype)


@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name)
def test_ebisu2d_scratch_mode(spec):
    x = init_domain(spec, (48, 72))
    t = 2
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, mode="scratch", interpret=True)
    _check(got, want, jnp.float32)


@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name)
def test_ebisu2d_deep_blocking(spec):
    """Depths comparable to the paper's Table 3 EBISU column."""
    from repro.core.stencil_spec import TABLE3_DEPTHS
    t = TABLE3_DEPTHS[spec.name]["ebisu"]
    x = init_domain(spec, (96, 80))
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    _check(got, want, jnp.float32)


@pytest.mark.parametrize("spec", SPECS_3D, ids=lambda s: s.name)
@pytest.mark.parametrize("shape", [(20, 9, 13), (24, 16, 16), (17, 7, 11)])
@pytest.mark.parametrize("t", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ebisu3d_matches_reference(spec, shape, t, dtype):
    x = init_domain(spec, shape, dtype=dtype)
    want = ref.reference_unrolled(x.astype(jnp.float32), spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    assert got.dtype == x.dtype
    assert got.shape == x.shape
    _check(got, want, dtype)


@pytest.mark.parametrize("spec", SPECS_3D, ids=lambda s: s.name)
def test_ebisu3d_deep_blocking(spec):
    from repro.core.stencil_spec import TABLE3_DEPTHS
    t = TABLE3_DEPTHS[spec.name]["ebisu"]
    x = init_domain(spec, (2 * t * spec.radius + 8, 12, 12))
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    _check(got, want, jnp.float32)


def test_t_zero_and_one():
    spec = get("j2d5pt")
    x = init_domain(spec, (32, 32))
    got = ops.ebisu_stencil(x, spec, 1, interpret=True)
    _check(got, ref.stencil_step(x, spec), jnp.float32)


def test_non_divisible_domains():
    """Domains that don't divide the block sizes (padding correctness)."""
    spec = get("j3d7pt")
    x = init_domain(spec, (17, 7, 11))
    want = ref.reference_unrolled(x, spec, 2)
    got = ops.ebisu_stencil(x, spec, 2, interpret=True)
    _check(got, want, jnp.float32)


@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name)
@pytest.mark.parametrize("t", [1, 4])
def test_ebisu2d_streaming_mode(spec, t):
    """The paper's 2-D scheme: stream one dim through the circular
    multi-queue (lift_2d_to_3d) — no overlapped halo along the stream."""
    x = init_domain(spec, (72, 56))
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, mode="stream", interpret=True)
    _check(got, want, jnp.float32)


def test_stream_equals_strip_modes():
    """All three 2-D execution modes agree with each other exactly."""
    spec = get("j2d9pt")
    x = init_domain(spec, (64, 48))
    outs = [ops.ebisu_stencil(x, spec, 3, mode=m, interpret=True)
            for m in ("fused", "scratch", "stream")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-5)


# ------------------------------------------------ planner-chosen depths ----
# All nine Table-2 specs at the depth (and tile/batch) the §6 planner picks
# for v5e, on odd / non-multiple domains, through the full plan-wired path.

def _plan_for(spec):
    from repro.core import roofline as rl
    from repro.core.planner import plan
    return plan(spec, rl.TPU_V5E)


@pytest.mark.parametrize("mode", ["fused", "scratch"])
@pytest.mark.parametrize("spec", SPECS_2D, ids=lambda s: s.name)
def test_ebisu2d_planner_depth(spec, mode):
    p = _plan_for(spec)
    x = init_domain(spec, (97, 83))
    want = ref.reference_unrolled(x, spec, p.t)
    got = ops.ebisu_stencil(x, spec, p.t, plan=p, mode=mode, interpret=True)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, (spec.name, p.t, mode, err)


@pytest.mark.parametrize("spec", SPECS_3D, ids=lambda s: s.name)
def test_ebisu3d_planner_depth(spec):
    p = _plan_for(spec)
    x = init_domain(spec, (2 * spec.halo(p.t) + 5, 9, 11))
    want = ref.reference_unrolled(x, spec, p.t)
    got = ops.ebisu_stencil(x, spec, p.t, plan=p, interpret=True)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-4, (spec.name, p.t, err)


# ------------------------------------------------ XY device tiling ---------
# §6.3/§6.4 executed: the 3-D grid steps along y/x with halo-exact rim
# fetching, so planner-chosen in-plane tiles actually run.

@pytest.mark.parametrize("spec", SPECS_3D, ids=lambda s: s.name)
def test_ebisu3d_xy_tiled_matches_untiled(spec):
    """XY-tiled launch == untiled launch == oracle on a domain larger than
    one tile (corner rim views exercised by the box stencils).  Both
    launches go through the program front door with pinned plans — the
    sole dispatch path."""
    import dataclasses

    from repro.api import compile_stencil
    from repro.kernels.stencil3d import launch_geometry_3d

    t = 2
    halo = spec.halo(t)
    shape = (3 * halo + 5, 4 * halo + 3, 4 * halo + 6)
    x = init_domain(spec, shape)
    want = ref.reference_unrolled(x, spec, t)
    base = _plan_for(spec)

    def pinned(ty, tx):          # a tile >= the extent leaves the axis untiled
        return dataclasses.replace(base, t=t, halo=halo, lazy_batch=halo,
                                   block=(halo, ty, tx))

    untiled = compile_stencil(
        spec, shape, t=t, interpret=True,
        plan=pinned(shape[1], shape[2])).apply(x)
    tiled = compile_stencil(
        spec, shape, t=t, interpret=True,
        plan=pinned(2 * halo, 2 * halo)).apply(x)
    g = launch_geometry_3d(spec, t, shape, zc=halo, ty=2 * halo,
                           tx=2 * halo)
    assert g["grid"][1] > 1 and g["grid"][2] > 1, g
    _check(tiled, want, jnp.float32)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(untiled),
                               atol=1e-5, rtol=1e-5)


def test_ebisu3d_launch_geometry_honors_plan():
    """No planner output remains decorative: when the §6 planner tiles XY
    (the A100 scratchpad model does, on the paper domain), the launch grid
    the kernel resolves steps along y/x at exactly plan.block[1:]."""
    from repro.core import roofline as rl
    from repro.core.planner import plan

    spec = get("j3d7pt")
    p = plan(spec, rl.A100_FP64)
    assert p.block[1] < spec.domain[1] or p.block[2] < spec.domain[2], p
    g = ops.launch_geometry(spec, p.t, spec.domain, plan=p)
    assert g["grid"][1] > 1 or g["grid"][2] > 1, g
    assert g["block"][1:] == p.block[1:]


def test_ebisu3d_xy_tiling_plan_wired_end_to_end():
    """A plan whose block tiles XY flows through ops.ebisu_stencil into a
    tiled launch that still matches the oracle."""
    import dataclasses

    spec = get("j3d7pt")
    p = _plan_for(spec)
    halo = spec.halo(2)
    small = dataclasses.replace(p, t=2, block=(2 * halo, 2 * halo, 2 * halo),
                                halo=halo, lazy_batch=2 * halo)
    x = init_domain(spec, (10, 12, 14))
    g = ops.launch_geometry(spec, 2, x.shape, plan=small)
    assert g["grid"][1] > 1 and g["grid"][2] > 1, g
    want = ref.reference_unrolled(x, spec, 2)
    got = ops.ebisu_stencil(x, spec, 2, plan=small, interpret=True)
    _check(got, want, jnp.float32)
