"""Validate the §5/§6 model implementation against the paper's own numbers.

These tests pin the model to the claims in the paper text — they are the
CPU-container substitute for re-measuring on an A100 and double as the
"faithful reproduction" evidence recorded in EXPERIMENTS.md.
"""
import math

import pytest

from repro.core import roofline as rl
from repro.core.planner import plan, next_pow2, minimal_parallelism
from repro.core.stencil_spec import TABLE2, TABLE3_DEPTHS, get


def test_desired_depth_2d5pt_matches_paper():
    """§6.2.1: 'According to Equation 17, we have t ≥ 6.3'."""
    t = rl.desired_depth(get("j2d5pt"), rl.A100_FP64, rst=True)
    assert t == pytest.approx(6.3, abs=0.1)


def test_desired_depth_3d7pt_device_tiled_matches_paper():
    """§6.2.2: with tile 32×32, a_sm=4.5, a_gm=2 → t > 18.34."""
    t = rl.desired_depth_device_tiled(get("j3d7pt"), rl.A100_FP64, (32, 32))
    assert t == pytest.approx(18.34, abs=0.15)


def test_min_tile_width_3d7pt_matches_paper():
    """§6.4.2: Eq 23 gives tile_x = tile_y ≥ 22.3 for j3d7pt."""
    w = rl.min_tile_width(get("j3d7pt"), rl.A100_FP64)
    assert w == pytest.approx(22.3, abs=0.2)


def test_v_dtile_2d5pt_matches_paper():
    """§6.3.1: T_sm = 2.05 µs, T_Dsync = 1.2 µs → V_Dtile ≈ 63%."""
    v = 2.05e-6 / (2.05e-6 + rl.A100_FP64.t_dsync)
    assert v == pytest.approx(0.63, abs=0.01)
    assert rl.v_dtile(2.05e-6, rl.A100_FP64, 1) == pytest.approx(v)


def test_v_smtile_2d5pt_matches_paper():
    """§6.3.1: overlapped tiling at t=7, rad=1, tile_x=256 → V ≈ 95%."""
    v = rl.v_smtile(get("j2d5pt"), 7, (256, 256))
    assert v == pytest.approx(0.95, abs=0.03)


def test_v_smtile_3d7pt_matches_paper():
    """§6.3.2 quotes V_SMtile ≈ 77% for tile 34, rad=1, t=3 via
    (34 − 2·rad·t)²/34².  Evaluated literally that is (28/34)² ≈ 0.678; the
    paper's quoted 77% appears to use a one-sided halo count.  We pin our
    Eq-9 implementation to the literal two-sided form and record the
    discrepancy (also noted in EXPERIMENTS.md §Fidelity-notes)."""
    spec = get("j3d7pt")
    # Eq 9 literal (one-sided, as published): ((34-3)/34)² ≈ 0.83
    assert rl.v_smtile(spec, 3, (34, 34)) == pytest.approx((31 / 34) ** 2, abs=1e-9)
    # §6.3.2's in-text two-sided variant: ((34-6)/34)² ≈ 0.68; quoted "≈77%"
    # sits between the two readings — the fuzziness is recorded, our model
    # keeps the published Eq-8/9 form.
    assert (28 / 34) ** 2 == pytest.approx(0.678, abs=1e-3)


def test_bottleneck_shifts_with_depth():
    """Eq 17's purpose: below t* the kernel is gm-bound, above it sm-bound."""
    spec = get("j2d5pt")
    hw = rl.A100_FP64
    t_star = rl.desired_depth(spec, hw)
    below = rl.attainable(spec, max(1, int(t_star) - 2), hw)
    above = rl.attainable(spec, int(t_star) + 2, hw)
    assert below.bottleneck == "gm"
    assert above.bottleneck in ("sm", "cmp")


def test_attainable_performance_2d5pt_scale():
    """§6.2.1: measured 440 GCells/s at t=7, 482 at t=12 on A100.

    The attainable bound P at the sm-bottleneck is B_sm/(a_sm·S_cell) =
    19.49e12/(4·8) ≈ 609 GCells/s; the paper's measured 482 GCells/s is 79%
    of it — consistent with the paper's own '80% of attainable' (§7.4.7)."""
    spec = get("j2d5pt")
    res = rl.attainable(spec, 12, rl.A100_FP64, rst=True)
    p_gcells = res.p_cells_per_s / 1e9
    assert p_gcells == pytest.approx(609, rel=0.02)
    assert 0.75 < 482 / p_gcells < 0.85


def test_deeper_is_monotone_until_shift():
    """P(t) strictly improves while gm-bound, then plateaus (sm/cmp-bound)."""
    spec = get("j2d9pt")
    hw = rl.A100_FP64
    perf = [rl.attainable(spec, t, hw).p_cells_per_s for t in range(1, 16)]
    t_star = math.ceil(rl.desired_depth(spec, hw))
    for i in range(0, t_star - 2):
        assert perf[i + 1] > perf[i]
    assert perf[-1] == pytest.approx(perf[t_star + 1], rel=0.01)


def test_planner_depths_in_table3_ballpark():
    """Planner depths should land in the regime of the paper's Table 3 EBISU
    column (same order of magnitude, deeper than the SOTA baselines)."""
    for name, spec in TABLE2.items():
        p = plan(spec, rl.A100_FP64)
        ebisu_t = TABLE3_DEPTHS[name]["ebisu"]
        assert p.t >= 1
        assert p.t <= 4 * ebisu_t + 8, f"{name}: planner t={p.t} wildly deep"


def test_planner_vmem_budget():
    for name, spec in TABLE2.items():
        for hw in (rl.A100_FP64, rl.TPU_V5E):
            p = plan(spec, hw)
            # device tiling spans the device-wide scratchpad budget (§4.1)
            budget = hw.onchip_device_bytes or hw.onchip_bytes
            if spec.ndim == 3:
                assert p.vmem_bytes <= budget * 1.01, (name, hw.name)
            assert p.halo == spec.radius * p.t
            assert p.ring == next_pow2(2 * spec.radius + 2)


def test_little_law_parallelism():
    """§6.1 analogue: enough bytes in flight to cover HBM latency."""
    par = minimal_parallelism(rl.TPU_V5E, plane_bytes=288 * 384 * 4)
    assert par.bytes_in_flight == pytest.approx(500e-9 * 819e9)
    assert 2 <= par.num_buffers <= 4
    assert par.ilp == 4


def test_tpu_affords_deeper_blocking_than_a100():
    """The core EBISU thesis transferred: bigger scratchpad (128 MiB VMEM vs
    17.7 MB device-wide smem) ⇒ deeper *affordable* temporal blocking.  (The
    chosen depth can be shallower when the v5e VPU makes the kernel compute-
    bound — the planner correctly stops early; capacity is what transfers.)"""
    from repro.core.planner import vmem_required_3d

    def max_affordable_t(spec, hw, ty, tx):
        budget = hw.onchip_device_bytes or hw.onchip_bytes
        t = 0
        while vmem_required_3d(spec, t + 1, 16, ty, tx, hw.s_cell, 2) <= budget:
            t += 1
            if t > 512:
                break
        return t

    for name in ("j3d7pt", "j3d27pt", "poisson"):
        spec = get(name)
        assert (max_affordable_t(spec, rl.TPU_V5E, 288, 384)
                > max_affordable_t(spec, rl.A100_FP64, 288, 384))
