"""Sharded deep-halo execution tests (DESIGN.md §12, docs/sharding.md).

Multi-device assertions run in a child process with 8 faked CPU devices
(`multidev_sharded_child.py`), per the dry-run isolation rule: the main
test process keeps its default 1-device view.  What runs here directly
is everything that needs no mesh (schedules, parsing, refusal helpers)
plus the 1-device-mesh transparent fallback.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_run_sharded_matches_run_on_faked_meshes():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests",
                                      "multidev_sharded_child.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"child failed:\n{r.stdout[-4000:]}\n{r.stderr[-4000:]}"
    assert "ALL-OK" in r.stdout
    # the full matrix ran: 10 specs x 2 meshes x 3 depths x 2 boundaries
    assert "equivalence: 120 configs OK" in r.stdout
    assert r.stdout.count("exchange-count") == 3
    assert r.stdout.count("refusal") == 3


# ------------------------------------------------- no-mesh-needed tests ----
def test_planned_exchange_rounds():
    from repro.api import planned_exchange_rounds
    assert planned_exchange_rounds(64, 4) == 16
    assert planned_exchange_rounds(9, 4) == 3     # 4, 4, remainder 1
    assert planned_exchange_rounds(3, 8) == 1     # one shallow block
    assert planned_exchange_rounds(5, 1) == 5     # t=1 IS per-step


def test_shard_extents_and_partition_spec_helpers():
    import numpy as np
    from jax.sharding import Mesh

    from repro.api.sharded import (shard_extents, sharded_partition_spec)

    class _Dev:                                   # no backend needed
        def __init__(self, i):
            self.id = i

    mesh = Mesh(np.array([[_Dev(0), _Dev(1)], [_Dev(2), _Dev(3)]]),
                ("shard0", "shard1"))
    assert shard_extents((8, 32, 5), mesh) == (4, 16, 5)
    assert sharded_partition_spec(3, mesh) == \
        __import__("jax").sharding.PartitionSpec("shard0", "shard1", None)


def test_validate_mesh_for_refusals_without_devices():
    import numpy as np
    from jax.sharding import Mesh

    from repro.api.sharded import validate_mesh_for
    from repro.core.stencil_spec import get

    class _Dev:
        def __init__(self, i):
            self.id = i

    mesh = Mesh(np.array([[_Dev(0), _Dev(1)], [_Dev(2), _Dev(3)]]),
                ("shard0", "shard1"))
    spec = get("j2d5pt")
    with pytest.raises(ValueError, match="not divisible"):
        validate_mesh_for(spec, (9, 32), mesh, 2, None)
    with pytest.raises(ValueError, match="Reduce t"):
        validate_mesh_for(spec, (8, 32), mesh, 8, None)
    validate_mesh_for(spec, (8, 32), mesh, 2, None)   # fits: no raise


def test_parse_mesh_cli():
    import argparse

    from repro.launch.stencil_run import parse_mesh
    assert parse_mesh("8") == (8,)
    assert parse_mesh("2x4") == (2, 4)
    assert parse_mesh("2,4") == (2, 4)
    with pytest.raises(argparse.ArgumentTypeError):
        parse_mesh("2xbad")
    with pytest.raises(argparse.ArgumentTypeError):
        parse_mesh("0x4")


def test_single_device_mesh_falls_back_to_run():
    """mesh of total size 1: run_sharded is transparently .run — works on
    the plain 1-device test process."""
    import jax.numpy as jnp

    from repro.api import compile_stencil
    from repro.core.stencil_spec import get
    from repro.stencils.data import init_domain

    spec = get("j2d5pt")
    prog = compile_stencil(spec, (32, 48), t=2, mesh=1, interpret=True)
    single = compile_stencil(spec, (32, 48), t=2, interpret=True)
    x = init_domain(spec, (32, 48))
    assert prog.mesh is not None and prog.mesh.size == 1
    got = prog.run_sharded(x, 5)
    want = single.run(x, 5)
    assert float(jnp.abs(got - want).max()) == 0.0


def test_run_sharded_without_mesh_is_actionable():
    from repro.api import compile_stencil
    from repro.core.stencil_spec import get
    from repro.stencils.data import init_domain

    spec = get("j2d5pt")
    prog = compile_stencil(spec, (32, 48), t=2, interpret=True)
    x = init_domain(spec, (32, 48))
    with pytest.raises(ValueError, match="mesh-compiled"):
        prog.run_sharded(x, 4)


def test_mesh_programs_are_cached_separately():
    from repro.api import compile_stencil
    from repro.core.stencil_spec import get

    spec = get("j2d5pt")
    a = compile_stencil(spec, (32, 48), t=2, interpret=True)
    b = compile_stencil(spec, (32, 48), t=2, mesh=1, interpret=True)
    c = compile_stencil(spec, (32, 48), t=2, mesh=1, interpret=True)
    assert a is not b            # mesh is part of the program identity
    assert b is c                # same mesh: same memoized handle
