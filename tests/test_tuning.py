"""Contract tests for ``repro.tuning`` (ISSUE 8): plan DB atomicity and
keying, zero-search tuned compiles, the measured search, and the CLI.

The load-bearing promises:

  * a crash at ANY point during a ``PlanDB.put`` never corrupts what
    ``get`` offers (SIGKILLed child process, ``test_checkpoint.py``
    harness) — the newest VISIBLE record always reads back intact;
  * corrupt / stale records are a warning + miss, never an exception;
  * the key really keys: same signature+bucket+hw+tier hits, any
    component changed misses;
  * ``compile_stencil(..., mode="tuned")`` with a warm DB performs ZERO
    timing calls (the ``search.TIMING`` injected counter) — the whole
    point of persisting winners.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import textwrap

import pytest

from repro.core.stencil_spec import get
from repro.tuning import plandb as P
from repro.tuning import search as S

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC = get("j2d5pt")
SHAPE = (64, 64)


def _key(tmp_path, hw="cpu:test", tier="interpret", shape=SHAPE):
    return P.db_key(SPEC, shape, hw, tier)


def _record(key):
    from repro.api.program import plan_bucketed
    from repro.core import roofline as rl

    plan = plan_bucketed(SPEC, SHAPE, rl.TPU_V5E)
    return P.make_record(key, plan, "fused", {"best_us": 1.0})


# ------------------------------------------------------------ atomicity ----
CHILD = textwrap.dedent("""
    import os, signal, sys
    from repro.core.stencil_spec import get
    from repro.core import roofline as rl
    from repro.api.program import plan_bucketed
    from repro.tuning import plandb as P

    root = sys.argv[1]
    spec = get("j2d5pt")
    key = P.db_key(spec, (64, 64), "cpu:test", "interpret")
    plan = plan_bucketed(spec, (64, 64), rl.TPU_V5E)
    db = P.PlanDB(root)
    # record A: fully landed (rename done) before the crash window opens
    db.put(key, P.make_record(key, plan, "fused", {"best_us": 111.0}))
    # record B: the writer dies before its atomic rename — exactly the
    # on-disk state a SIGKILL mid-save leaves behind
    db.put(key, P.make_record(key, plan, "scratch", {"best_us": 222.0}),
           sabotage="crash")
    print("KILLING", flush=True)
    os.kill(os.getpid(), signal.SIGKILL)
""")


def test_sigkill_mid_put_leaves_visible_record_intact(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", CHILD, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert "KILLING" in r.stdout

    db = P.PlanDB(str(tmp_path))
    key = P.db_key(SPEC, (64, 64), "cpu:test", "interpret")
    rec = db.get(key)                      # record A, never half-of-B
    assert rec is not None
    assert rec["measured"]["best_us"] == 111.0
    assert rec["plan"]["exec_mode"] == "fused"
    orphans = [f for f in os.listdir(tmp_path) if ".json.tmp" in f]
    assert orphans, "the crashed save should leave a .tmp orphan"
    # entries() never lists orphans; prune_stale reclaims them
    assert all(".tmp" not in p for p, _ in db.entries())
    db.prune_stale()
    assert not [f for f in os.listdir(tmp_path) if ".json.tmp" in f]
    assert db.get(key)["measured"]["best_us"] == 111.0


# ------------------------------------------------- corrupt / stale skip ----
def test_corrupt_record_is_warned_miss_not_fatal(tmp_path):
    db = P.PlanDB(str(tmp_path))
    key = _key(tmp_path)
    db.put(key, _record(key), sabotage="corrupt")
    with pytest.warns(UserWarning, match="corrupt"):
        assert db.get(key) is None
    # truncated-on-disk (unparseable) variant
    db2 = P.PlanDB(str(tmp_path / "b"))
    path = db2.put(key, _record(key))
    with open(path, "w") as f:
        f.write('{"key": {"trunc')
    with pytest.warns(UserWarning, match="corrupt"):
        assert db2.get(key) is None


def test_stale_jax_version_is_warned_miss_and_prunable(tmp_path):
    db = P.PlanDB(str(tmp_path))
    key = _key(tmp_path)
    rec = _record(key)
    rec["jax_version"] = "0.0.1"           # tuned under another toolchain
    db.put(key, rec)
    with pytest.warns(UserWarning, match="stale"):
        assert db.get(key) is None
    removed = db.prune_stale()
    assert len(removed) == 1
    assert db.entries() == []


# ---------------------------------------------------------------- keying ----
def test_key_hits_and_misses(tmp_path):
    db = P.PlanDB(str(tmp_path))
    key = _key(tmp_path)
    db.put(key, _record(key))
    assert db.get(key) is not None
    # same 64-bucket, different exact shape -> same key -> hit
    assert P.db_key(SPEC, (63, 57), "cpu:test", "interpret") == key
    # any key component changed -> miss
    assert db.get(P.db_key(SPEC, SHAPE, "tpu:v5e", "interpret")) is None
    assert db.get(P.db_key(SPEC, SHAPE, "cpu:test", "native")) is None
    assert db.get(P.db_key(SPEC, (256, 256), "cpu:test",
                           "interpret")) is None
    assert db.get(P.db_key(get("j2d9pt"), SHAPE, "cpu:test",
                           "interpret")) is None
    with pytest.raises(ValueError, match="tier"):
        P.db_key(SPEC, SHAPE, "cpu:test", "tuned")


# ------------------------------------------- tuned mode through the API ----
@pytest.fixture(scope="module")
def warm_db(tmp_path_factory):
    """One tiny-budget search shared by the tuned-mode tests."""
    root = str(tmp_path_factory.mktemp("plandb"))
    db = P.PlanDB(root)
    res = S.tune(SPEC, SHAPE, db=db, budget=6, max_candidates=3, total_t=4)
    assert res.timing_calls > 0            # the search DID time things
    return db, res


def test_tuned_compile_warm_db_zero_timing(warm_db):
    from repro.api import compile_stencil

    db, res = warm_db
    before = S.TIMING["calls"]
    prog = compile_stencil(SPEC, SHAPE, mode="tuned", plan_db=db)
    assert S.TIMING["calls"] == before, \
        "warm-DB tuned compile must perform zero timing calls"
    assert prog.tuned["source"] == "plandb"
    assert prog.t == res.record["plan"]["t"]
    assert prog.mode == res.record["plan"]["exec_mode"]
    assert tuple(prog.plan.block) == tuple(res.record["plan"]["block"])
    # tuned execution goes through the normal runner path
    from repro.stencils.data import init_domain
    from repro.kernels import ref
    x = init_domain(SPEC, SHAPE)
    got = prog.apply(x)
    want = ref.reference(x, SPEC, prog.t)
    assert float(abs(got - want).max()) < 1e-4


def test_tuned_compile_cold_db_falls_back_analytic(tmp_path):
    from repro.api import compile_stencil

    before = S.TIMING["calls"]
    prog = compile_stencil(SPEC, (192, 192), mode="tuned",
                           plan_db=str(tmp_path))
    assert S.TIMING["calls"] == before     # a miss searches NOTHING
    assert prog.tuned["source"] == "analytic_fallback"
    assert prog.mode == "fused"


def test_tuned_mode_refuses_explicit_overrides(tmp_path):
    from repro.api import compile_stencil
    from repro.api.program import plan_bucketed
    from repro.core import roofline as rl

    with pytest.raises(ValueError, match="drop t="):
        compile_stencil(SPEC, SHAPE, mode="tuned", t=4,
                        plan_db=str(tmp_path))
    with pytest.raises(ValueError, match="drop plan="):
        compile_stencil(SPEC, SHAPE, mode="tuned",
                        plan=plan_bucketed(SPEC, SHAPE, rl.TPU_V5E),
                        plan_db=str(tmp_path))
    with pytest.raises(ValueError, match="single-device"):
        compile_stencil(SPEC, SHAPE, mode="tuned", mesh=1,
                        plan_db=str(tmp_path))


# ------------------------------------------------------------ the search ----
def test_neighborhood_seeds_plan_first_and_is_deterministic():
    from repro.api.program import plan_bucketed
    from repro.core import roofline as rl

    plan = plan_bucketed(SPEC, SHAPE, rl.TPU_V5E)
    cands = S.neighborhood(SPEC, SHAPE, plan, max_candidates=8)
    assert cands == S.neighborhood(SPEC, SHAPE, plan, max_candidates=8)
    seed = cands[0]
    assert (seed.t, tuple(seed.block), seed.exec_mode) == \
        (plan.t, tuple(plan.block), "fused")
    assert len(cands) <= 8
    assert len(set(cands)) == len(cands)


def test_plan_from_record_roundtrip(warm_db):
    from repro.core import roofline as rl

    _, res = warm_db
    plan = P.plan_from_record(SPEC, SHAPE, rl.TPU_V5E, res.record)
    assert plan.t == res.plan.t
    assert tuple(plan.block) == tuple(res.plan.block)
    assert plan.lazy_batch == res.plan.lazy_batch
    assert plan.halo == SPEC.halo(plan.t)
    assert (plan.parallelism.num_buffers
            == res.plan.parallelism.num_buffers)


# ----------------------------------------------------------------- CLI ----
def test_cli_sweep_check_showdb_prune(tmp_path, capsys):
    from repro.tuning.cli import main

    db = str(tmp_path / "db")
    assert main(["check", "--stencil", "j2d5pt", "--scale", "64",
                 "--db", db]) == 1         # cold DB -> miss -> nonzero
    assert main(["sweep", "--stencil", "j2d5pt", "--scale", "64",
                 "--budget", "6", "--candidates", "3", "--db", db]) == 0
    assert main(["check", "--stencil", "j2d5pt", "--scale", "64",
                 "--db", db]) == 0         # warm -> hit
    assert main(["show-db", "--db", db]) == 0
    out = capsys.readouterr().out
    assert "1 record(s)" in out
    assert main(["prune-stale", "--db", db]) == 0
    assert main(["check", "--stencil", "j2d5pt", "--scale", "64",
                 "--db", db]) == 0         # live-version record survives


def test_autotune_shim_translates_and_delegates(tmp_path):
    import importlib.util

    spec_path = os.path.join(ROOT, "scripts", "autotune_stencil.py")
    sp = importlib.util.spec_from_file_location("autotune_shim", spec_path)
    shim = importlib.util.module_from_spec(sp)
    sp.loader.exec_module(shim)
    db = str(tmp_path / "db")
    with pytest.warns(DeprecationWarning, match="repro.tuning sweep"):
        rc = shim.main(["--stencil", "j2d5pt", "--scale", "64",
                        "--depths", "1,2", "--budget", "6",
                        "--candidates", "3", "--db", db])
    assert rc == 0
    assert P.PlanDB(db).entries()          # the sweep really persisted
