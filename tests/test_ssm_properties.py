"""SSD invariants: chunked scan == sequential recurrence (the LM-side
'blocked == unblocked' contract, mirroring the stencil tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.ssm import ssd_chunked, ssd_decode_step

SETTINGS = dict(max_examples=15, deadline=None)


def _sequential(x, dt, A, B, C, D):
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = jnp.zeros((b, h, n, p))
    ys = []
    for i in range(s):
        y, state = ssd_decode_step(state, x[:, i], dt[:, i], A, B[:, i],
                                   C[:, i], D)
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@given(s=st.integers(3, 33), chunk=st.integers(2, 16),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_chunked_equals_sequential(s, chunk, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h, p, n = 2, 3, 4, 5
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, h, n)) * 0.5
    D = jnp.ones((h,))
    y_chunk, st_chunk = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    y_seq, st_seq = _sequential(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_seq),
                               atol=2e-4, rtol=2e-4)


@given(chunk1=st.integers(2, 8), chunk2=st.integers(9, 32),
       seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_chunk_size_invariance(chunk1, chunk2, seed):
    """Temporal-blocking depth must not change the result (paper's contract)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 24, 2, 4, 3
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, h, n)) * 0.5
    D = jnp.zeros((h,))
    y1, s1 = ssd_chunked(x, dt, A, B, C, D, chunk=chunk1)
    y2, s2 = ssd_chunked(x, dt, A, B, C, D, chunk=chunk2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-4, rtol=2e-4)


def test_flash_attention_matches_dense():
    from repro.models.attention import dense_attention, flash_attention
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    for window in (None, 24):
        want = dense_attention(q, k, v, causal=True, window=window)
        got = flash_attention(q, k, v, causal=True, window=window,
                              q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_flash_attention_bidirectional():
    from repro.models.attention import dense_attention, flash_attention
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 32, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 4, 8))
    v = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 4, 8))
    want = dense_attention(q, k, v, causal=False)
    got = flash_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
