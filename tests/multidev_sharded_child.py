"""Child process for ``StencilProgram.run_sharded`` multi-device tests.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
parent, ``tests/test_sharded.py``).  Asserts, on 1x8 and 2x4 faked CPU
meshes:

  * sharded == single-device ``.run`` (allclose at compute dtype) for all
    nine Table-2 specs plus a user-defined ``define_stencil`` spec, for
    t in {1, 2, 4} x {periodic, dirichlet(0)} (T = 2t+1 exercises the
    remainder block), plus reflect / dirichlet(v) / bf16 spot checks;
  * exactly ONE ppermute round per temporal block per sharded axis
    direction — not one per time step;
  * non-divisible domains and too-deep halos are refused with actionable
    errors.

Domain sizing: dim0 = 8*rad, dim1 = 32*rad (divisible by both meshes,
shard >= t*rad at every t tested), trailing 3-D dim unsharded and small.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.api import (Boundary, compile_stencil, count_ppermutes,
                       define_stencil, planned_exchange_rounds)
from repro.api.sharded import build_sharded_runner
from repro.core.stencil_spec import TABLE2
from repro.stencils.data import init_domain

MESHES = ((1, 8), (2, 4))
DEPTHS = (1, 2, 4)
BOUNDARIES = (Boundary.periodic(), Boundary.dirichlet(0.0))

CUSTOM = define_stencil(
    (((0, 0), 0.55), ((0, 1), 0.2), ((0, -1), 0.1),
     ((1, 0), 0.08), ((-1, 0), 0.04)), name="aniso5")  # unnormalized


def domain_for(spec, mesh):
    """Uniform shards on both meshes, shard >= 4*rad (the t=4 halo)."""
    rad = spec.radius
    dims = [8 * rad, 32 * rad]
    if spec.ndim == 3:
        dims.append(max(2 * rad + 2, 8))
    return tuple(dims)


def check_equivalence():
    n = 0
    for spec in list(TABLE2.values()) + [CUSTOM]:
        for mesh in MESHES:
            shape = domain_for(spec, mesh)
            x = init_domain(spec, shape)
            for t in DEPTHS:
                for boundary in BOUNDARIES:
                    total = 2 * t + 1      # full, full, remainder
                    prog = compile_stencil(spec, shape, t=t, mesh=mesh,
                                           boundary=boundary,
                                           interpret=True)
                    single = compile_stencil(spec, shape, t=t,
                                             boundary=boundary,
                                             interpret=True)
                    got = prog.run_sharded(x, total)
                    want = single.run(x, total)
                    assert got.dtype == want.dtype == x.dtype
                    err = float(jnp.abs(got - want).max())
                    assert err < 2e-5, (spec.name, mesh, t, boundary, err)
                    n += 1
    print(f"equivalence: {n} configs OK "
          f"({len(TABLE2) + 1} specs x {len(MESHES)} meshes x "
          f"{len(DEPTHS)} depths x {len(BOUNDARIES)} boundaries)")


def check_exchange_counts():
    """One ppermute round per temporal block — NOT per time step."""
    for name, mesh, t, total in (("j2d5pt", (2, 4), 4, 9),
                                 ("j3d7pt", (1, 8), 2, 6),
                                 ("j2d9pt", (2, 4), 2, 5)):
        spec = TABLE2[name]
        shape = domain_for(spec, mesh)
        prog = compile_stencil(spec, shape, t=t, mesh=mesh,
                               boundary=Boundary.periodic(), interpret=True)
        fn = build_sharded_runner(prog, total)
        x = init_domain(spec, shape)
        axes = sum(1 for nn in mesh if nn > 1)
        blocks = planned_exchange_rounds(total, t)
        got = count_ppermutes(fn, x)
        want = blocks * 2 * axes           # 2 directions per sharded axis
        per_step = total * 2 * axes        # the classic scheme's count
        assert got == want, (name, got, want)
        assert got < per_step or t == 1, (name, got, per_step)
        print(f"exchange-count {name} mesh={mesh} t={t} T={total}: "
              f"{got} ppermutes == {blocks} blocks x 2 x {axes} axes "
              f"(per-step scheme: {per_step})")


def check_spot_cases():
    # reflect: self-mirrored edge shards (mirror-symmetric taps)
    spec = TABLE2["j2d9pt"]
    shape = (16, 96)                       # shard >= h+1 on 1x8 at t=4
    x = init_domain(spec, shape)
    for boundary, t in ((Boundary.reflect(), 4),
                        (Boundary.dirichlet(0.7), 4)):   # s=1: any depth
        prog = compile_stencil(spec, shape, t=t, mesh=(1, 8),
                               boundary=boundary, interpret=True)
        single = compile_stencil(spec, shape, t=t, boundary=boundary,
                                 interpret=True)
        err = float(jnp.abs(prog.run_sharded(x, 2 * t + 1)
                            - single.run(x, 2 * t + 1)).max())
        assert err < 2e-5, (boundary, err)
        print(f"spot {boundary!r}: OK maxerr={err:.2e}")

    # unnormalized dirichlet(v): depth-1 blocks via the affine closure
    prog = compile_stencil(CUSTOM, (8, 32), t=1, mesh=(2, 4),
                           boundary=Boundary.dirichlet(0.3), interpret=True)
    single = compile_stencil(CUSTOM, (8, 32), t=1,
                             boundary=Boundary.dirichlet(0.3),
                             interpret=True)
    xa = init_domain(CUSTOM, (8, 32))
    err = float(jnp.abs(prog.run_sharded(xa, 3) - single.run(xa, 3)).max())
    assert err < 2e-5, err
    print(f"spot affine dirichlet(0.3) s!=1 t=1: OK maxerr={err:.2e}")

    # bf16 storage computes in f32 and lands back in bf16
    spec = TABLE2["j2d5pt"]
    prog = compile_stencil(spec, (8, 32), t=2, mesh=(2, 4),
                           dtype=jnp.bfloat16, interpret=True)
    xb = init_domain(spec, (8, 32), dtype=jnp.bfloat16)
    yb = prog.run_sharded(xb, 5)
    assert yb.dtype == jnp.bfloat16, yb.dtype
    print("spot bf16 storage: OK")

    # T=0 is the identity
    y0 = prog.run_sharded(xb, 0)
    assert y0 is xb
    print("spot T=0 identity: OK")


def check_refusals():
    spec = TABLE2["j2d5pt"]
    # non-divisible domain
    try:
        compile_stencil(spec, (17, 32), t=2, mesh=(2, 4), interpret=True)
        raise AssertionError("non-divisible domain not refused")
    except ValueError as e:
        msg = str(e)
        assert "divisible" in msg and "pad the domain" in msg, msg
        print("refusal non-divisible: OK")
    # halo deeper than one shard
    try:
        compile_stencil(spec, (8, 32), t=8, mesh=(2, 4), interpret=True)
        raise AssertionError("too-deep halo not refused")
    except ValueError as e:
        msg = str(e)
        assert "Reduce t" in msg and "one neighbor hop" in msg, msg
        print("refusal deep-halo: OK")
    # mesh with more axes than the domain has dims
    try:
        compile_stencil(spec, (8, 32), t=2, mesh=(2, 2, 2), interpret=True)
        raise AssertionError("over-ranked mesh not refused")
    except ValueError as e:
        assert "mesh has 3 axes" in str(e), e
        print("refusal mesh rank: OK")


def main():
    assert jax.device_count() == 8, jax.device_count()
    check_equivalence()
    check_exchange_counts()
    check_spot_cases()
    check_refusals()
    print("ALL-OK")


if __name__ == "__main__":
    main()
