"""The open stencil definition layer: derived cost models vs Table 2
(paper fidelity as a test), ``define_stencil`` validation, randomized
user specs vs an independent pad/roll oracle, registry-free planning,
the affine Dirichlet closure, and the compute-dtype policy."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Boundary, compile_stencil, define_stencil,
                       from_operator, parse_taps, plan_bucketed,
                       resolve_compute_dtype, spec_from_json)
from repro.api.define import OPERATORS
from repro.core import roofline as rl
from repro.core.stencil_spec import (DEFAULT_DOMAINS, MAX_RADIUS, TABLE2,
                                     derive_a_sm, derive_a_sm_rst,
                                     derive_cost_model,
                                     derive_flops_per_cell, get,
                                     validate_spec)
from repro.kernels import ref
from repro.stencils.data import init_domain

ALL_SPECS = list(TABLE2.values())


# ------------------------------------------------- independent oracle ------
# Deliberately NOT the tap engine: numpy zero-pad ghost ring + hand-written
# slices (zero Dirichlet), jnp.roll (periodic).

def pad_oracle(x, taps, t):
    x = np.asarray(x, np.float64)
    rad = max(max(abs(o) for o in off) for off, _ in taps)
    for _ in range(t):
        xe = np.pad(x, rad)
        acc = np.zeros_like(x)
        for off, c in taps:
            sl = tuple(slice(rad + o, rad + o + n)
                       for o, n in zip(off, x.shape))
            acc = acc + c * xe[sl]
        x = acc
    return x


def roll_oracle(x, taps, t):
    acc = x
    for _ in range(t):
        nxt = jnp.zeros_like(acc)
        for off, c in taps:
            nxt = nxt + c * jnp.roll(acc, tuple(-o for o in off),
                                     axis=tuple(range(acc.ndim)))
        acc = nxt
    return acc


# ====================================================== paper fidelity ====
@pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
def test_derivation_reproduces_table2(spec):
    """The analytic cost model reproduces the paper's published numbers:
    ``a_sm`` and ``a_sm (RST)`` exactly for all nine benchmarks, and
    flops/cell for eight — j2d25pt is the paper's lone 1-FLOP-per-FMA
    count, pinned below as the single registered divergence."""
    assert derive_a_sm(spec.taps) == spec.a_sm, spec.name
    assert derive_a_sm_rst(spec.taps, spec.ndim) == spec.a_sm_rst, spec.name
    if spec.name == "j2d25pt":
        assert spec.flops_per_cell == 25          # Table-2 verbatim override
        assert derive_flops_per_cell(spec.taps) == 50   # our 2/tap convention
    else:
        assert derive_flops_per_cell(spec.taps) == spec.flops_per_cell


def test_derived_geometry_matches_registry():
    """ndim / radius / shape_kind are derived from the tap set — the
    registry entries went through the same builder, so they agree."""
    for spec in ALL_SPECS:
        rebuilt = define_stencil(spec.taps, name=spec.name,
                                 domain=spec.domain)
        assert rebuilt.ndim == spec.ndim
        assert rebuilt.radius == spec.radius
        assert rebuilt.shape_kind == spec.shape_kind
        # same taps, derived (non-overridden) cost numbers → the derived
        # model is what define_stencil users get by default
        assert rebuilt.a_sm == derive_a_sm(spec.taps)


# ========================================================== validation ====
def test_validation_errors_are_precise():
    with pytest.raises(ValueError, match="non-empty tap set"):
        define_stencil([])
    with pytest.raises(ValueError, match="inconsistent offset arity"):
        define_stencil([((0, 0), 1.0), ((0, 0, 1), 0.5)])
    with pytest.raises(ValueError, match="non-integer"):
        define_stencil([((0.5, 0), 1.0), ((1, 0), 1.0)])
    with pytest.raises(ValueError, match="duplicate tap offset"):
        define_stencil([((0, 0), 0.5), ((0, 0), 0.5), ((0, 1), 0.3)])
    with pytest.raises(ValueError, match="zero coefficient"):
        define_stencil([((0, 0), 0.5), ((0, 1), 0.0)])
    with pytest.raises(ValueError, match="non-finite"):
        define_stencil([((0, 0), float("nan")), ((0, 1), 1.0)])
    with pytest.raises(ValueError, match="radius is 0"):
        define_stencil([((0, 0), 1.0)])
    with pytest.raises(ValueError, match=f"bound {MAX_RADIUS}"):
        define_stencil([((0, 0), 1.0), ((0, MAX_RADIUS + 1), 1.0)])
    with pytest.raises(ValueError, match="2-D or 3-D"):
        define_stencil([((0,), 1.0), ((1,), 1.0)])
    with pytest.raises(ValueError, match="cannot normalize"):
        define_stencil([((0, 0), -1.0), ((0, 1), 1.0)], normalize=True)
    with pytest.raises(ValueError, match="domain"):
        define_stencil([((0, 0), 0.5), ((0, 1), 0.5)], domain=(64,))


def test_compile_validates_hand_built_specs():
    """compile_stencil runs the same validation pass, so inconsistent
    hand-built (dataclasses.replace'd) specs fail with a precise error
    instead of mislaunching."""
    good = get("j2d5pt")
    bad_radius = dataclasses.replace(good, radius=2)
    with pytest.raises(ValueError, match="radius=2 but the tap set"):
        compile_stencil(bad_radius, (32, 32), t=1, interpret=True)
    bad_cost = dataclasses.replace(good, a_sm=-1.0)
    with pytest.raises(ValueError, match="a_sm"):
        compile_stencil(bad_cost, (32, 32), t=1, interpret=True)
    with pytest.raises(KeyError, match="define_stencil"):
        get("nonexistent")


# ============================================= randomized user stencils ====
def _random_taps(rng, ndim, radius, npoints):
    box = [off for off in np.ndindex(*(2 * radius + 1,) * ndim)]
    offs = [tuple(int(o) - radius for o in off) for off in box]
    rng.shuffle(offs)
    chosen = offs[:npoints]
    if all(max(abs(o) for o in off) == 0 for off in chosen):
        chosen[0] = (radius,) + (0,) * (ndim - 1)   # ensure radius >= 1
    return tuple((off, float(rng.uniform(0.1, 1.0))) for off in chosen)


RANDOM_CASES = [(2, 1, 4), (2, 2, 7), (3, 1, 5), (3, 2, 9)]


@pytest.mark.parametrize("ndim,radius,npoints", RANDOM_CASES)
def test_random_specs_match_pad_oracle(ndim, radius, npoints):
    """Seeded random tap sets (no registry, no hypothesis) compile and
    match the independent numpy pad oracle at t ∈ {1, 2, 4}."""
    rng = np.random.RandomState(ndim * 100 + radius * 10 + npoints)
    spec = define_stencil(_random_taps(rng, ndim, radius, npoints),
                          normalize=True)
    assert spec.name.startswith("user")          # not a registry entry
    shape = (26, 21) if ndim == 2 else (10, 9, 11)
    x = init_domain(spec, shape)
    for t in (1, 2, 4):
        prog = compile_stencil(spec, shape, t=t, interpret=True)
        got = np.asarray(prog.apply(x))
        want = pad_oracle(x, spec.taps, t)
        assert np.abs(got - want).max() < 1e-4, (spec.taps, t)


def test_random_spec_periodic_matches_roll_oracle():
    rng = np.random.RandomState(7)
    spec = define_stencil(_random_taps(rng, 2, 1, 5), normalize=True)
    x = init_domain(spec, (24, 20))
    for t in (1, 2, 4):
        prog = compile_stencil(spec, x.shape, t=t,
                               boundary=Boundary.periodic(), interpret=True)
        err = float(jnp.abs(prog.apply(x) - roll_oracle(x, spec.taps, t)).max())
        assert err < 1e-4, t


def test_unnormalized_spec_zero_dirichlet_any_depth():
    """Tap sums != 1 are first-class under zero Dirichlet (the zero-fill
    reduction is sum-agnostic) — including the executor's chained path."""
    taps = (((0, 0), 0.55), ((0, 1), 0.2), ((0, -1), 0.1),
            ((1, 0), 0.08), ((-1, 0), 0.04))               # s = 0.97
    spec = define_stencil(taps, name="aniso5")
    x = init_domain(spec, (30, 26))
    for t in (1, 2, 4):
        prog = compile_stencil(spec, x.shape, t=t, interpret=True)
        want = pad_oracle(x, taps, t)
        assert np.abs(np.asarray(prog.apply(x)) - want).max() < 1e-4
    assert np.abs(np.asarray(prog.run(x, 6)) - pad_oracle(x, taps, 6)
                  ).max() < 1e-4


# ================================================ registry-free planning ==
def test_custom_spec_plans_without_registry():
    """plan_bucketed keys on tap structure: a spec absent from TABLE2
    plans, and two differently-named specs with identical structure share
    ONE cached plan."""
    taps = (((0, 0), 0.5), ((0, 1), 0.2), ((0, -1), 0.1),
            ((1, 0), 0.1), ((-1, 0), 0.1))
    a = define_stencil(taps, name="custom-a")
    b = define_stencil(taps, name="custom-b")
    assert a.name not in TABLE2 and b.name not in TABLE2
    pa = plan_bucketed(a, (200, 200))
    pb = plan_bucketed(b, (220, 240))       # same 64-bucket: (256, 256)
    assert pa is pb                          # structure-keyed cache hit
    # an override of the cost model changes planning identity
    c = define_stencil(taps, name="custom-c", a_sm_rst=40.0)
    assert c.signature != a.signature
    pc = plan_bucketed(c, (200, 200))
    assert pc is not pa


def test_operator_cost_summary_flags_overrides():
    s = rl.spec_cost_summary(get("j2d25pt"))
    assert s["overridden"] == ["flops_per_cell"]
    user = define_stencil((((0, 0), 0.6), ((0, 1), 0.2), ((0, -1), 0.2)))
    assert rl.spec_cost_summary(user)["overridden"] == []
    assert user.domain == DEFAULT_DOMAINS[2]


# =============================================== affine Dirichlet closure ==
def test_affine_dirichlet_exact_at_depth_one():
    """dirichlet(v) with tap sum s != 1: u' = Z(u - v) + v*s per sweep is
    exact — apply and the chained executor match the per-step oracle."""
    taps = (((0, 0), 0.55), ((0, 1), 0.2), ((0, -1), 0.1),
            ((1, 0), 0.08), ((-1, 0), 0.04))
    spec = define_stencil(taps, name="aniso-affine")
    b = Boundary.dirichlet(0.5)
    x = init_domain(spec, (28, 24))
    prog = compile_stencil(spec, x.shape, t=1, boundary=b, interpret=True)
    for T in (1, 3):
        got = prog.run(x, T) if T > 1 else prog.apply(x)
        want = ref.reference(x, spec, T, boundary=b)
        assert float(jnp.abs(got - want).max()) < 1e-4, T


def test_affine_dirichlet_depth_two_raises_actionably():
    taps = (((0, 0), 0.55), ((0, 1), 0.2), ((0, -1), 0.1),
            ((1, 0), 0.08), ((-1, 0), 0.04))
    spec = define_stencil(taps)
    with pytest.raises(ValueError) as ei:
        compile_stencil(spec, (28, 24), t=2,
                        boundary=Boundary.dirichlet(0.5), interpret=True)
    msg = str(ei.value)
    assert "affine closure" in msg and "normalize" in msg and "t=1" in msg
    # the runtime depth override is checked too
    prog = compile_stencil(spec, (28, 24), t=1,
                           boundary=Boundary.dirichlet(0.5), interpret=True)
    x = init_domain(spec, (28, 24))
    with pytest.raises(ValueError, match="affine closure"):
        prog.apply(x, t=3)


def test_normalized_dirichlet_constant_shift_unchanged():
    """s == 1 keeps the zero-copy constant-shift path at any depth."""
    spec = get("j2d9pt")
    b = Boundary.dirichlet(0.7)
    x = init_domain(spec, (30, 26))
    prog = compile_stencil(spec, x.shape, t=4, boundary=b, interpret=True)
    err = float(jnp.abs(prog.run(x, 9)
                        - ref.reference(x, spec, 9, boundary=b)).max())
    assert err < 1e-4


# ===================================================== operator builders ==
@pytest.mark.parametrize("kind", sorted(OPERATORS))
def test_from_operator_compiles_and_matches_reference(kind):
    spec = from_operator(kind, ndim=2, radius=1)
    x = init_domain(spec, (26, 22))
    prog = compile_stencil(spec, x.shape, t=2, interpret=True)
    err = float(jnp.abs(prog.apply(x) - ref.reference(x, spec, 2)).max())
    assert err < 1e-4, kind


def test_diffusion_at_stability_limit_drops_zero_center():
    """alpha = 1/(2·ndim) zeroes the center weight exactly — a valid
    pure-neighbor smoother, not a 'zero coefficient' error."""
    spec = from_operator("diffusion", ndim=2, alpha=0.25)
    assert all(off != (0, 0) for off, _ in spec.taps)
    assert abs(spec.tap_sum - 1.0) < 1e-12


def test_numpy_integer_offsets_accepted():
    off = np.array([0, 1])
    spec = define_stencil([((int(off[0]), int(off[0])), 0.5),
                           ((np.int64(0), np.int64(1)), 0.25),
                           ((np.int64(0), np.int64(-1)), 0.25)])
    assert spec.taps[1][0] == (0, 1)
    assert all(type(o) is int for t, _ in spec.taps for o in t)


def test_operator_tap_sums():
    assert abs(from_operator("laplacian", ndim=3).tap_sum) < 1e-12
    assert abs(from_operator("diffusion", ndim=3, alpha=0.1).tap_sum
               - 1.0) < 1e-12
    assert abs(from_operator("blur", ndim=2, radius=2).tap_sum - 1.0) < 1e-9
    with pytest.raises(ValueError, match="unknown operator"):
        from_operator("conv")
    with pytest.raises(ValueError, match="radius 1 or 2"):
        from_operator("laplacian", radius=3)


# ======================================================== dtype policy ====
def test_resolve_compute_dtype_policy():
    assert resolve_compute_dtype(jnp.float32) == jnp.dtype(jnp.float32)
    assert resolve_compute_dtype(jnp.bfloat16) == jnp.dtype(jnp.float32)
    assert resolve_compute_dtype(jnp.bfloat16,
                                 jnp.bfloat16) == jnp.dtype(jnp.bfloat16)
    with pytest.raises(ValueError, match="floating"):
        resolve_compute_dtype(jnp.int32)
    with pytest.raises(ValueError, match="floating"):
        resolve_compute_dtype(jnp.float32, jnp.int8)


def test_bf16_storage_f32_compute_beats_bf16_compute():
    """The satellite tolerance test: bf16 cells stepped in f32 (the
    default policy) round once at the end; stepping in bf16 rounds every
    sweep and visibly drifts from the f32 oracle."""
    spec = get("j2d5pt")
    x = init_domain(spec, (48, 40), dtype=jnp.bfloat16)
    want = ref.reference(x.astype(jnp.float32), spec, 8)
    prog_f32 = compile_stencil(spec, x.shape, t=4, dtype=jnp.bfloat16,
                               interpret=True)
    prog_bf16 = compile_stencil(spec, x.shape, t=4, dtype=jnp.bfloat16,
                                compute_dtype=jnp.bfloat16, interpret=True)
    assert prog_f32.compute_dtype == jnp.dtype(jnp.float32)
    assert prog_bf16.compute_dtype == jnp.dtype(jnp.bfloat16)
    e_f32 = float(jnp.abs(prog_f32.run(x, 8).astype(jnp.float32)
                          - want).max())
    e_bf16 = float(jnp.abs(prog_bf16.run(x, 8).astype(jnp.float32)
                           - want).max())
    assert e_f32 < 5e-3                       # one final rounding
    assert e_bf16 > e_f32                     # per-sweep rounding drifts
    # distinct programs (dtype policy is part of the cache key)
    assert prog_f32 is not prog_bf16


def test_compute_dtype_threads_through_apply_and_3d():
    spec = get("j3d7pt")
    x = init_domain(spec, (12, 9, 11), dtype=jnp.bfloat16)
    prog = compile_stencil(spec, x.shape, t=2, dtype=jnp.bfloat16,
                           interpret=True)
    y = prog.apply(x)
    assert y.dtype == jnp.bfloat16
    want = ref.reference(x.astype(jnp.float32), spec, 2)
    assert float(jnp.abs(y.astype(jnp.float32) - want).max()) < 5e-3


# ========================================================== CLI adapters ==
def test_parse_taps_and_spec_json():
    taps = parse_taps('[[[0,0],0.6],[[0,1],0.2],[[0,-1],0.2]]')
    assert taps == (((0, 0), 0.6), ((0, 1), 0.2), ((0, -1), 0.2))
    with pytest.raises(ValueError, match="JSON"):
        parse_taps("not json")
    with pytest.raises(ValueError, match=r"\[offset, coeff\]"):
        parse_taps('[[0.5, 1]]')
    with pytest.raises(ValueError, match="non-integer"):
        parse_taps('[[[0, 1.9], 0.5], [[0, 0], 0.5]]')
    with pytest.raises(ValueError, match="'kind'"):
        spec_from_json({"operator": {"ndim": 2}})
    spec = spec_from_json({"taps": [[[0, 0], 0.5], [[0, 1], 0.5]],
                           "name": "mine", "domain": [256, 512],
                           "flops_per_cell": 99})
    assert spec.name == "mine" and spec.domain == (256, 512)
    assert spec.flops_per_cell == 99          # explicit override
    assert spec.a_sm == derive_a_sm(spec.taps)   # rest derived
    op = spec_from_json({"operator": {"kind": "diffusion", "ndim": 2}})
    assert abs(op.tap_sum - 1.0) < 1e-12
    with pytest.raises(ValueError, match="'taps'"):
        spec_from_json({"name": "no-taps"})


def test_acceptance_anisotropic_unnormalized_end_to_end():
    """The issue's acceptance case in one test: an anisotropic
    unnormalized 2-D 5-point absent from Table 2 compiles via
    define_stencil + compile_stencil, plans without registry lookups, and
    matches the independent oracle at t ∈ {1, 2, 4} under every boundary
    its tap set admits; the inadmissible combination fails at compile
    time with an actionable message."""
    taps = (((0, 0), 0.5), ((0, 1), 0.25), ((0, -1), 0.05),
            ((1, 0), 0.15), ((-1, 0), 0.03))               # s = 0.98
    spec = define_stencil(taps, name="accept-aniso")
    assert spec.name not in TABLE2
    x = init_domain(spec, (30, 27))
    derived = derive_cost_model(taps, 2)
    assert (spec.flops_per_cell, spec.a_sm, spec.a_sm_rst) == \
        (derived["flops_per_cell"], derived["a_sm"], derived["a_sm_rst"])
    for t in (1, 2, 4):
        # admissible: zero Dirichlet (any s) and periodic (any s)
        p0 = compile_stencil(spec, x.shape, t=t, interpret=True)
        assert np.abs(np.asarray(p0.apply(x))
                      - pad_oracle(x, taps, t)).max() < 1e-4
        pp = compile_stencil(spec, x.shape, t=t,
                             boundary=Boundary.periodic(), interpret=True)
        assert float(jnp.abs(pp.apply(x)
                             - roll_oracle(x, taps, t)).max()) < 1e-4
    # not mirror-symmetric → reflect refuses, actionably
    with pytest.raises(ValueError, match="mirror"):
        compile_stencil(spec, x.shape, t=1, boundary=Boundary.reflect())
    # unnormalized + non-zero Dirichlet beyond depth 1 → refuses
    with pytest.raises(ValueError, match="affine closure"):
        compile_stencil(spec, x.shape, t=4, boundary=Boundary.dirichlet(1.0))
