"""Atomicity contract of ``repro.train.checkpoint`` (DESIGN.md §5).

The promise under test: a crash at ANY point during a save never
corrupts what ``latest_step`` offers — the newest *visible* checkpoint
always restores intact, because saves land in a ``.tmp`` directory and
become visible only via the final atomic rename.  A child process is
SIGKILLed while its async writer is mid-save to prove it; the
corrupted-manifest cases pin the refusal behavior when the disk (not
the writer) is the liar.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.train import checkpoint

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(step: int) -> dict:
    return {"w": np.full((8, 8), float(step), np.float32),
            "b": np.arange(4, dtype=np.float32) * step}


# ------------------------------------------------ kill mid-save (child) ----
CHILD = textwrap.dedent("""
    import os, signal, sys
    import numpy as np
    from repro.train import checkpoint

    ckpt = sys.argv[1]
    # step 1: landed and fsync-visible before the crash window opens
    tree1 = {"w": np.full((8, 8), 1.0, np.float32),
             "b": np.arange(4, dtype=np.float32)}
    checkpoint.save(ckpt, 1, tree1, block=True)
    # step 2: a fat tree so the async writer is still inside the .tmp
    # directory when the SIGKILL lands
    tree2 = {"w": np.full((2048, 2048), 2.0, np.float32),
             "b": np.arange(4, dtype=np.float32) * 2}
    checkpoint.save(ckpt, 2, tree2, block=False)
    print("KILLING", flush=True)
    os.kill(os.getpid(), signal.SIGKILL)
""")


def test_sigkill_mid_save_leaves_latest_restorable(tmp_path):
    """Kill the writer mid-``.tmp`` save: whatever ``latest_step`` then
    reports must restore intact — either the fully-landed step 1, or
    step 2 if its rename won the race; never a half-written tree."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", CHILD, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert "KILLING" in r.stdout

    step = checkpoint.latest_step(str(tmp_path))
    assert step in (1, 2)
    like = _tree(step)
    got = checkpoint.restore(str(tmp_path), step, like)
    assert (np.asarray(got["w"]) == like["w"]).all()
    assert (np.asarray(got["b"]) == like["b"]).all()


# ------------------------------------------------- corrupted manifests ----
def test_latest_step_skips_corrupt_manifest(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree(1), block=True)
    checkpoint.save(str(tmp_path), 2, _tree(2), block=True)
    with open(tmp_path / "step_2" / "manifest.json", "w") as f:
        f.write('{"step": 2, "leav')          # truncated mid-write
    assert checkpoint.latest_step(str(tmp_path)) == 1
    got = checkpoint.restore(str(tmp_path), 1, _tree(1))
    assert (np.asarray(got["w"]) == 1.0).all()


def test_restore_refuses_corrupt_manifest(tmp_path):
    checkpoint.save(str(tmp_path), 3, _tree(3), block=True)
    with open(tmp_path / "step_3" / "manifest.json", "w") as f:
        f.write("not json at all")
    with pytest.raises(ValueError, match="corrupt|manifest"):
        checkpoint.restore(str(tmp_path), 3, _tree(3))


def test_restore_refuses_manifest_without_leaves(tmp_path):
    checkpoint.save(str(tmp_path), 3, _tree(3), block=True)
    checkpoint.save(str(tmp_path), 4, _tree(4), block=True)
    with open(tmp_path / "step_4" / "manifest.json", "w") as f:
        json.dump({"step": 4}, f)             # parses, but no leaves table
    with pytest.raises(ValueError, match="corrupt"):
        checkpoint.restore(str(tmp_path), 4, _tree(4))
    assert checkpoint.latest_step(str(tmp_path)) == 3   # skipped by resume


def test_tmp_dirs_invisible_to_latest_step(tmp_path):
    checkpoint.save(str(tmp_path), 5, _tree(5), block=True)
    os.makedirs(tmp_path / "step_9.tmp12345")
    with open(tmp_path / "step_9.tmp12345" / "manifest.json", "w") as f:
        json.dump({"step": 9, "leaves": {}}, f)
    assert checkpoint.latest_step(str(tmp_path)) == 5
