"""End-to-end trainer tests: loss goes down, checkpoints restart exactly,
and restarts reshard elastically onto a different mesh."""
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from repro.launch.train import train
except ImportError as e:
    # only the documented incompatibility (jax.sharding.AxisType missing on
    # older jax) may skip; any other import breakage must surface
    if "AxisType" not in str(e):
        raise
    pytest.skip(f"trainer import unavailable on this jax: {e}",
                allow_module_level=True)

pytestmark = pytest.mark.slow


def test_train_loss_decreases(tmp_path):
    _, _, losses = train("mamba2-130m", steps=30, batch=8, seq=32,
                         reduced=True, ckpt_dir=str(tmp_path),
                         ckpt_every=10, lr=1e-2)
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_crash_restart_resumes_identically(tmp_path):
    """train 20 steps straight == train 10, 'crash', resume 10 more."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    _, _, l_straight = train("h2o-danube-1.8b", steps=20, batch=4, seq=32,
                             reduced=True, ckpt_dir=d1, ckpt_every=10,
                             lr=1e-2, seed=3, schedule_steps=20)
    train("h2o-danube-1.8b", steps=10, batch=4, seq=32, reduced=True,
          ckpt_dir=d2, ckpt_every=10, lr=1e-2, seed=3, schedule_steps=20)
    _, _, l_resumed = train("h2o-danube-1.8b", steps=20, batch=4, seq=32,
                            reduced=True, ckpt_dir=d2, ckpt_every=10,
                            lr=1e-2, seed=3, resume="auto",
                            schedule_steps=20)
    # the deterministic (seed, step) pipeline makes the tail identical
    np.testing.assert_allclose(l_straight[10:], l_resumed,
                               rtol=2e-3, atol=2e-3)


def test_elastic_restart_new_mesh(tmp_path):
    """Checkpoint written on a 1-device mesh restores onto a 2x1 data mesh
    in a child process with 2 host devices (logical specs reshard freely)."""
    d = str(tmp_path)
    train("mamba2-130m", steps=6, batch=4, seq=32, reduced=True,
          ckpt_dir=d, ckpt_every=3, lr=1e-2, seed=1)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = (
        "from repro.launch.train import train\n"
        f"_,_,l = train('mamba2-130m', steps=9, batch=4, seq=32,"
        f" reduced=True, ckpt_dir={d!r}, ckpt_every=3, lr=1e-2, seed=1,"
        f" n_data=2, n_model=1)\n"
        "print('RESUMED-OK', l[-1])\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "resumed step 6" in r.stdout
    assert "RESUMED-OK" in r.stdout
