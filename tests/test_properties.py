"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.multiqueue import MultiQueueLayout
from repro.core.stencil_spec import get, star_taps, StencilSpec
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------ multi-queue ---
@given(depth=st.integers(1, 12), radius=st.integers(1, 4))
@settings(**SETTINGS)
def test_multiqueue_invariants(depth, radius):
    mq = MultiQueueLayout.make(depth, radius)
    mq.check()
    # pow2 ring ⇒ slot(z) == z % ring for all z (the paper's & trick)
    for z in range(0, 4 * mq.ring + 3):
        assert mq.slot(z) == z % mq.ring
    # live planes never collide with the write slot within one window
    for z in range(mq.ring, 3 * mq.ring):
        window = mq.window(1, mq.producible(1, z))
        slots = {mq.slot(w) for w in window}
        assert len(slots) == len(window), "ring too small: live-plane collision"
        assert mq.slot(z) not in {mq.slot(w) for w in window[:-1]} or True


@given(depth=st.integers(1, 8), radius=st.integers(1, 3),
       z_in=st.integers(0, 100))
@settings(**SETTINGS)
def test_multiqueue_producible_monotone(depth, radius, z_in):
    mq = MultiQueueLayout.make(depth, radius)
    # deeper steps lag by exactly rad per step (the streaming skew)
    for s in range(1, depth + 1):
        assert mq.producible(s, z_in) == z_in - s * radius
        if s > 1:
            assert mq.producible(s, z_in) < mq.producible(s - 1, z_in)


# ------------------------------------------------------- stencil algebra ---
@given(
    h=st.integers(12, 48), w=st.integers(12, 48), t=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_blocked_equals_unblocked_2d(h, w, t, seed):
    """The fundamental contract: temporal blocking is semantics-preserving."""
    spec = get("j2d5pt")
    x = jax.random.normal(jax.random.PRNGKey(seed), (h, w), jnp.float32)
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@given(a=st.floats(-2, 2), b=st.floats(-2, 2), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_linearity(a, b, seed):
    """Jacobi stencils are linear: S(a·x + b·y) == a·S(x) + b·S(y)."""
    spec = get("j2d9pt")
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (24, 24))
    y = jax.random.normal(k2, (24, 24))
    lhs = ops.ebisu_stencil(a * x + b * y, spec, 2, interpret=True)
    rhs = (a * ops.ebisu_stencil(x, spec, 2, interpret=True)
           + b * ops.ebisu_stencil(y, spec, 2, interpret=True))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-3, rtol=1e-3)


@given(shift=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_interior_shift_equivariance(shift, seed):
    """Translating the input translates the output (away from boundaries)."""
    spec = get("j2d5pt")
    t = 2
    pad = t * spec.radius + shift
    x = jax.random.normal(jax.random.PRNGKey(seed), (40, 40))
    big = jnp.zeros((40 + 2 * pad, 40 + 2 * pad)).at[pad:pad + 40, pad:pad + 40].set(x)
    moved = jnp.roll(big, shift, axis=0)
    y1 = ops.ebisu_stencil(big, spec, t, interpret=True)
    y2 = ops.ebisu_stencil(moved, spec, t, interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.roll(y1, shift, axis=0)[2 * pad:-2 * pad, 2 * pad:-2 * pad]),
        np.asarray(y2[2 * pad:-2 * pad, 2 * pad:-2 * pad]),
        atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 6))
@settings(**SETTINGS)
def test_max_principle(seed, t):
    """Convex-combination stencils (weights ≥ 0, sum 1) cannot expand range."""
    spec = get("j3d7pt")
    x = jax.random.uniform(jax.random.PRNGKey(seed), (16, 10, 12))
    y = ops.ebisu_stencil(x, spec, t, interpret=True)
    assert float(y.max()) <= float(x.max()) + 1e-5
    assert float(y.min()) >= min(0.0, float(x.min())) - 1e-5
    assert not bool(jnp.isnan(y).any())


@given(
    radius=st.integers(1, 2), t=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_random_coefficient_stencils(radius, t, seed):
    """Kernels are correct for arbitrary (not just Table-2) tap coefficients."""
    rng = np.random.RandomState(seed)
    taps = tuple((off, float(rng.uniform(-0.2, 0.4))) for off, _
                 in star_taps(2, radius))
    spec = StencilSpec("rand", 2, radius, taps, 2 * len(taps), (64, 64), 6, 4)
    x = jax.random.normal(jax.random.PRNGKey(seed), (40, 44))
    want = ref.reference_unrolled(x, spec, t)
    got = ops.ebisu_stencil(x, spec, t, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
