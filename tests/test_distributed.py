"""Multi-device integration tests (run in a child process so the main test
process keeps the default 1-device view, per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_child(script, n_dev=8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, os.path.join(ROOT, "tests", script)],
                       env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"child failed:\n{r.stdout}\n{r.stderr}"
    assert "ALL-OK" in r.stdout
    return r.stdout


@pytest.mark.slow
def test_distributed_stencil_matches_reference():
    out = _run_child("multidev_stencil_child.py")
    assert out.count("OK maxerr") == 6


@pytest.mark.slow
def test_moe_ep_matches_pjit():
    out = _run_child("multidev_moe_child.py")
    assert "EP-vs-pjit maxerr" in out


@pytest.mark.slow
def test_compressed_gradient_allreduce():
    out = _run_child("multidev_compress_child.py")
    assert "compressed-DP-SGD final loss" in out
